"""Elastic cluster under a bursty arrival ramp: autoscaling vs static.

The live §3.2 control plane (``repro.cluster``) measured end to end on
the event-driven engine. One open-loop workload — a trickle, then a hard
task burst, then a cool-down, with seeded Poisson arrivals — is replayed
over three fleets:

- **static** — a fixed fleet provisioned for the burst peak; it idles
  (and bills) through the quiet phases.
- **autoscaled** — starts at a fraction of peak; the ``Autoscaler``
  daemon grows the fleet from gateway acquire-wait/queue pressure during
  the burst (paying a virtual boot delay per scale-up) and drains it
  afterwards. Capped at the static fleet's size, so the comparison is
  peak-for-peak fair.
- **overcommit** — the static fleet's replica count packed onto hosts
  with far too few cores: the per-host contention tracker inflates every
  operation in virtual time, demonstrating that CPU-bounded packing now
  degrades trajectories/min *live* instead of only in the offline
  cost model.

Asserts (the paper-facing claims of the elastic control plane):

1. the autoscaled cluster holds the same p95 acquire-wait bound the
   static fleet meets,
2. while spending >= 20% fewer replica-days (it spends ~55% fewer), and
3. the overcommitted fleet loses >= 25% trajectories/min to live CPU
   contention (it loses ~45%).

    PYTHONPATH=src python benchmarks/elastic_cluster.py

Emits ``artifacts/bench/BENCH_elastic.json``; ``scripts/check_bench.py``
gates CI on its per-cluster rows and gate block (virtual-time metrics,
deterministic per seed).
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cluster import (AutoscalerConfig, Cluster, MachineSpec,
                           default_specs)
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry
from repro.rollout.writer import TrajectoryWriter

PEAK_REPLICAS = 128          # static provisioning for the burst
MIN_REPLICAS = 16            # autoscaled floor (and starting size)
RUNNERS_PER_NODE = 32
P95_WAIT_BOUND_VS = 30.0     # acquire-wait p95 both fleets must hold
REPLICA_DAY_SAVINGS = 0.20   # autoscaled must save at least this much
OVERCOMMIT_SLOWDOWN = 0.25   # contention must cost at least this much
OVERCOMMIT_CORES = 8         # cores per host in the overcommit config
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_elastic.json")


# ---------------------------------------------------------------- workload
def burst_arrivals(seed: int = 0, *, trickle_rate: float = 0.2,
                   burst_rate: float = 3.0, trickle_n: int = 40,
                   burst_n: int = 600, burst_at_vs: float = 200.0,
                   cooldown_at_vs: float = 500.0) -> list[float]:
    """Seeded Poisson arrival ramp: trickle -> hard burst -> trickle."""
    rng = random.Random(stable_seed(seed, "elastic-arrivals"))
    arrivals: list[float] = []
    t = 0.0
    for _ in range(trickle_n):
        t += rng.expovariate(trickle_rate)
        arrivals.append(t)
    t = max(t, burst_at_vs)
    for _ in range(burst_n):
        t += rng.expovariate(burst_rate)
        arrivals.append(t)
    t = max(t, cooldown_at_vs)
    for _ in range(trickle_n):
        t += rng.expovariate(trickle_rate)
        arrivals.append(t)
    return arrivals


# ------------------------------------------------------------------- runs
def run_cluster(name: str, cluster: Cluster, arrivals: list[float], *,
                seed: int = 0,
                registry: ScenarioRegistry = None) -> dict:
    """Replay the arrival ramp over one cluster; returns its row."""
    registry = registry or get_default_registry()
    t0 = time.monotonic()
    writer = TrajectoryWriter(retain=False, capacity=4096)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           config=RolloutConfig(
                               max_inflight=len(arrivals),
                               acquire_timeout_vs=3000.0))
    tasks = registry.sample(len(arrivals),
                            seed=stable_seed(seed, "elastic-tasks"))
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals)
    waits = cluster.telemetry.summary("acquire_wait_vs")
    auto = cluster.autoscaler
    peak = cluster.peak_placed
    row = {
        "name": name,
        "replicas_start": None,      # filled by caller
        "replicas_peak": peak,
        "completed": report.completed,
        "failed": report.failed,
        "reassignments": report.reassignments,
        "virtual_makespan_s": report.virtual_makespan,
        "traj_per_min": report.trajectories_per_min(peak),
        "acquire_wait_p95_vs": waits.get("p95", 0.0),
        "acquire_wait_mean_vs": waits.get("mean", 0.0),
        "replica_days": cluster.replica_days(),
        "usd_per_day_peak": cluster.price_per_day(),
        "scale_ups": auto.scale_ups if auto else 0,
        "scale_downs": auto.scale_downs if auto else 0,
        "scale_blocked": auto.blocked if auto else 0,
        "wall_seconds": time.monotonic() - t0,
    }
    writer.drain(timeout=30.0)
    writer.close()
    cluster.close()
    return row


def elastic_matrix(seed: int = 0) -> list[dict]:
    """The three-fleet comparison over one common arrival ramp."""
    registry = get_default_registry()
    arrivals = burst_arrivals(seed)
    rows = []

    static = Cluster(default_specs(PEAK_REPLICAS), PEAK_REPLICAS,
                     runners_per_node=RUNNERS_PER_NODE, seed=seed)
    row = run_cluster("static", static, arrivals, seed=seed,
                      registry=registry)
    row["replicas_start"] = PEAK_REPLICAS
    rows.append(row)

    scaler = AutoscalerConfig(min_replicas=MIN_REPLICAS,
                              max_replicas=PEAK_REPLICAS,
                              grow_step=32)
    auto = Cluster(default_specs(PEAK_REPLICAS), MIN_REPLICAS,
                   runners_per_node=RUNNERS_PER_NODE, seed=seed,
                   autoscaler=scaler)
    row = run_cluster("autoscaled", auto, arrivals, seed=seed,
                      registry=registry)
    row["replicas_start"] = MIN_REPLICAS
    rows.append(row)

    tiny = MachineSpec(OVERCOMMIT_CORES, 768, "E5-2699")
    n_hosts = PEAK_REPLICAS // RUNNERS_PER_NODE
    over = Cluster([tiny] * n_hosts, PEAK_REPLICAS,
                   runners_per_node=RUNNERS_PER_NODE, seed=seed)
    row = run_cluster("overcommit", over, arrivals, seed=seed,
                      registry=registry)
    row["replicas_start"] = PEAK_REPLICAS
    rows.append(row)
    return rows


# ----------------------------------------------------------------- asserts
def assert_elastic_claims(rows: list[dict]) -> dict:
    """The benchmark's contract; returns the gate block for the baseline."""
    by = {r["name"]: r for r in rows}
    static, auto, over = by["static"], by["autoscaled"], by["overcommit"]
    n_tasks = static["completed"] + static["failed"]
    for r in rows:
        assert r["completed"] >= 0.95 * n_tasks, (
            f"{r['name']}: only {r['completed']}/{n_tasks} episodes "
            f"completed — the fleet is not keeping up with recovery")

    assert static["acquire_wait_p95_vs"] <= P95_WAIT_BOUND_VS, (
        f"static fleet missed its own p95 bound: "
        f"{static['acquire_wait_p95_vs']:.1f} > {P95_WAIT_BOUND_VS}")
    assert auto["acquire_wait_p95_vs"] <= P95_WAIT_BOUND_VS, (
        f"autoscaled fleet broke the p95 acquire-wait bound: "
        f"{auto['acquire_wait_p95_vs']:.1f} > {P95_WAIT_BOUND_VS}")

    savings = 1.0 - auto["replica_days"] / static["replica_days"]
    assert savings >= REPLICA_DAY_SAVINGS, (
        f"autoscaling saved only {savings:.1%} replica-days "
        f"(static {static['replica_days']:.3f}, autoscaled "
        f"{auto['replica_days']:.3f}); need >= {REPLICA_DAY_SAVINGS:.0%}")
    assert auto["scale_ups"] > 0 and auto["scale_downs"] > 0, (
        "the autoscaler never actually scaled — the ramp should force "
        "both growth and drain")

    slowdown = 1.0 - over["traj_per_min"] / static["traj_per_min"]
    assert slowdown >= OVERCOMMIT_SLOWDOWN, (
        f"overcommitted hosts only cost {slowdown:.1%} traj/min "
        f"(static {static['traj_per_min']:.1f}, overcommit "
        f"{over['traj_per_min']:.1f}); live contention should cost "
        f">= {OVERCOMMIT_SLOWDOWN:.0%}")
    return {
        "autoscaled_meets_p95_bound": True,
        "replica_day_savings_frac": round(savings, 4),
        "overcommit_slowdown_frac": round(slowdown, 4),
        "autoscaled_scale_ups": auto["scale_ups"],
        "autoscaled_scale_downs": auto["scale_downs"],
        "static_traj_per_min": round(static["traj_per_min"], 2),
        "autoscaled_replica_days": round(auto["replica_days"], 4),
    }


# ----------------------------------------------------------------- harness
def elastic_table(seed: int = 0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    rows = elastic_matrix(seed)
    gate = assert_elastic_claims(rows)
    derived = (f"elastic control plane: p95 wait bound held at "
               f"{gate['replica_day_savings_frac']:.0%} fewer replica-days "
               f"than static; overcommit costs "
               f"{gate['overcommit_slowdown_frac']:.0%} traj/min live")
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the whole sweep stays under this "
                         "wall-clock budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_elastic.json")
    args = ap.parse_args()

    t0 = time.monotonic()
    rows = elastic_matrix(args.seed)
    wall = time.monotonic() - t0

    print(f"{'cluster':>11} {'start':>6} {'peak':>5} {'done':>5} "
          f"{'p95 wait':>9} {'traj/min':>9} {'replica-days':>13} "
          f"{'scale +/-':>10}")
    for r in rows:
        print(f"{r['name']:>11} {r['replicas_start']:>6} "
              f"{r['replicas_peak']:>5} {r['completed']:>5} "
              f"{r['acquire_wait_p95_vs']:>9.2f} {r['traj_per_min']:>9.1f} "
              f"{r['replica_days']:>13.4f} "
              f"{r['scale_ups']:>5}/{r['scale_downs']}")

    gate = assert_elastic_claims(rows)
    if args.budget_s is not None:
        assert wall <= args.budget_s, (
            f"elastic sweep took {wall:.1f}s wall > budget "
            f"{args.budget_s}s")

    payload = {
        "benchmark": "elastic cluster control plane under a bursty "
                     "arrival ramp (autoscaled vs static vs overcommit)",
        "metric": "p95 acquire-wait (vs), replica-days, traj/min "
                  "(virtual time)",
        "seed": args.seed,
        "p95_wait_bound_vs": P95_WAIT_BOUND_VS,
        "workload": {
            "arrivals": "seeded Poisson trickle/burst/trickle ramp",
            "n_tasks": len(burst_arrivals(args.seed)),
        },
        "sweep_wall_seconds": round(wall, 2),
        "clusters": rows,
        "gate": gate,
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"autoscaled: {gate['replica_day_savings_frac']:.0%} fewer "
          f"replica-days at the same p95 bound; overcommit costs "
          f"{gate['overcommit_slowdown_frac']:.0%} traj/min; "
          f"sweep {wall:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
