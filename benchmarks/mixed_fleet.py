"""Heterogeneous mixed fleet: four EnvBackends through one gateway.

The tentpole claim of the ``repro.envs`` subsystem, measured live: one
``Cluster`` hosts four calibrated environment backends at once — SimOS
VMs, container-free SWE sandboxes, headless browsers, and device
emulators — each bin-packed at its own RAM/CoW footprint onto dedicated
hosts, and one ``Gateway`` serves a mixed episode stream with
backend-constrained routing (a SWE episode never lands on a browser
pool). At ``t0`` every backend gets a seeded dose of silent corruption
(the §3.4 kernel-limit failure mode), and each backend's *own*
known-answer canary must detect it: the whole L0–L4 recovery ladder is
backend-agnostic, so quarantine and recreation work identically on a
sandbox, a browser, and an emulator. The surviving mixed stream then
feeds one PPO learner through the cross-domain reward shaping
(per-backend ``reward_scale``), whose loss must decrease.

Asserts:

1. every backend completes episodes, and zero episodes are routed to a
   pool of the wrong backend (the routing audit walks every episode's
   node list against the node->backend map);
2. 100% of injected silently-broken runners are detected by their own
   backend's canary and quarantined, and no corrupted trajectory
   reaches the writer after its runner's quarantine — on every backend;
3. the single learner's loss decreases on the mixed four-domain stream.

    PYTHONPATH=src python benchmarks/mixed_fleet.py

Emits ``artifacts/bench/BENCH_mixedfleet.json`` (per-backend rows +
gate); ``scripts/check_bench.py --baseline ... --fresh ...`` gates CI on
it, with a hard wall budget recorded in the baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.envs import get_backend
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import mixed_registry
from repro.rollout.writer import TrajectoryWriter

BACKENDS = ("simos", "swe", "browser", "mobile")
REPLICAS_PER_BACKEND = 32
RUNNERS_PER_NODE = 16
EPISODES_PER_REPLICA = 5
KILL_AT_VS = 30.0            # t0: per-backend silent corruption
SILENT_PER_BACKEND = 4       # silently-broken runners per backend
MAX_UPDATES = 12             # PPO updates on the mixed stream
WALL_BUDGET_S = 120.0        # hard CI budget recorded in the baseline
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_mixedfleet.json")


def run_mixed_fleet_benchmark(seed: int = 0) -> dict:
    """One end-to-end mixed-fleet run; returns the full payload."""
    t_wall = time.monotonic()
    n_total = REPLICAS_PER_BACKEND * len(BACKENDS)
    registry = mixed_registry()
    cluster = Cluster(
        default_specs(n_total, runners_per_node=RUNNERS_PER_NODE),
        n_total, runners_per_node=RUNNERS_PER_NODE, seed=seed,
        backends=[(b, REPLICAS_PER_BACKEND) for b in BACKENDS])
    tele = cluster.telemetry
    # retain trajectories and feed the learner after the run: the virtual
    # clock stays decoupled from jax wall time, so the rollout half is
    # deterministic per seed on any host
    writer = TrajectoryWriter(retain=True, capacity=1024)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           telemetry=tele,
                           config=RolloutConfig(
                               max_inflight=n_total,
                               acquire_timeout_vs=3000.0))
    # an even per-backend task mix: the per-backend rates stay comparable
    # instead of following the Table-3 weights of the SimOS families
    per_backend = REPLICAS_PER_BACKEND * EPISODES_PER_REPLICA
    tasks = []
    for b in BACKENDS:
        tasks.extend(registry.sample(
            per_backend, seed=stable_seed(seed, "mixed-workload", b),
            backends=[b]))
    loop = EventLoop()

    pools = list(cluster.pools)
    ladders = [p.recovery for p in pools]
    by_backend = {b: [p for p in pools if p.backend_name == b]
                  for b in BACKENDS}
    node_backend = {p.node_id: p.backend_name for p in pools}
    injected: dict[str, set] = {b: set() for b in BACKENDS}

    def inject_failures() -> None:
        """t0: silent corruption on every backend at once."""
        rng = random.Random(stable_seed(seed, "mixed-kill"))
        for b in BACKENDS:
            runners = [r for p in by_backend[b] for r in p._all.values()]
            runners.sort(key=lambda r: r.runner_id)
            for r in rng.sample(runners, SILENT_PER_BACKEND):
                r.mark_silent_broken(loop.now)
                injected[b].add(r.runner_id)

    loop.call_later(KILL_AT_VS, inject_failures, daemon=True)
    report = engine.run_event_driven(tasks, loop=loop)
    # pools added after t0 (replacement capacity) still belong to a
    # backend — fold them into the routing audit map
    for p in cluster.pools:
        node_backend.setdefault(p.node_id, p.backend_name)

    # ------------------------------------------------------------ analysis
    detected_at: dict[str, float] = {}
    quarantined_at: dict[str, float] = {}
    for lad in ladders:
        detected_at.update(lad.detected_at)
        quarantined_at.update(lad.quarantined_at)
    all_injected = set().union(*injected.values())
    missed = all_injected - set(detected_at)
    unquarantined = all_injected - set(quarantined_at)
    late_writes = [(rid, vt) for rid, vt in report.corrupted_writes
                   if vt > quarantined_at.get(rid, float("inf")) + 1e-9]

    completed_by = {b: 0 for b in BACKENDS}
    failed_by = {b: 0 for b in BACKENDS}
    violations = []
    for r in report.results:
        b = r.task.get("backend", "simos")
        (completed_by if r.ok else failed_by)[b] += 1
        for node in r.nodes:
            if node_backend.get(node) != b:
                violations.append((r.task["task_id"], node))

    makespan = max(report.virtual_makespan, 1e-9)
    rows = []
    for b in BACKENDS:
        backend = get_backend(b)
        lats = sorted(detected_at[rid] - KILL_AT_VS
                      for rid in injected[b] if rid in detected_at)
        p95 = lats[min(int(0.95 * len(lats)), len(lats) - 1)] if lats else 0.0
        rows.append({
            "name": b,
            "replicas": REPLICAS_PER_BACKEND,
            "hosts": sum(1 for p in by_backend[b]),
            "ram_limit_gb": backend.ram_limit_gb(),
            "reward_scale": backend.reward_scale,
            "completed": completed_by[b],
            "failed": failed_by[b],
            "traj_per_min": round(60.0 * completed_by[b] / makespan, 2),
            "injected_silent": len(injected[b]),
            "silent_detected": len(injected[b] & set(detected_at)),
            "silent_quarantined": len(injected[b] & set(quarantined_at)),
            "detection_p95_vs": round(p95, 2),
        })

    # ------------------------------------------------ learner (mixed stream)
    import jax

    from repro.configs import get_reduced
    from repro.data.replay_buffer import ReplayBuffer
    from repro.models import build_model
    from repro.pipeline.ingest import IngestConfig, TrajectoryIngestor
    from repro.pipeline.learner import LearnerConfig, LearnerLoop
    from repro.pipeline.policy_store import PolicyVersionStore
    from repro.train.ppo import PPOConfig, PPOTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    trainer = PPOTrainer(model, params, cfg=PPOConfig(), seed=seed)
    replay = ReplayBuffer(capacity=4096, seed=stable_seed(seed, "replay"),
                          backend="soa", seq_len=192)
    store = PolicyVersionStore(trainer.params)
    ingestor = TrajectoryIngestor(
        replay, store, registry=registry, trainer=trainer,
        cfg=IngestConfig(seq_len=192, micro_batch=32,
                         flush_wall_s=float("inf"),
                         flush_virtual_s=float("inf")),
        telemetry=tele)
    writer.drain(timeout=30.0)
    for traj in writer.trajectories:
        ingestor(traj)
    ingestor.flush()
    learner = LearnerLoop(trainer, replay, store,
                          cfg=LearnerConfig(algo="ppo", batch_size=8,
                                            seq_len=192),
                          telemetry=tele)
    while learner.ready() and learner.updates < MAX_UPDATES:
        learner.step()
    trend = learner.loss_trend()
    backend_totals = {b: tele.counter(f"backend_total:{b}") for b in BACKENDS}

    # ------------------------------------------------------------- asserts
    n_tasks = len(tasks)
    assert report.completed >= 0.99 * n_tasks, (
        f"only {report.completed}/{n_tasks} episodes completed — the "
        f"mixed fleet did not absorb the load")
    for row in rows:
        assert row["completed"] > 0, (
            f"backend {row['name']} completed no episodes — it is not "
            f"being served through the gateway")
    assert not violations, (
        f"{len(violations)} episodes were routed to a pool of the wrong "
        f"backend: {violations[:5]}")
    assert not missed, (
        f"{len(missed)}/{len(all_injected)} silently-broken runners were "
        f"never detected by their backend's canary: {sorted(missed)[:5]}")
    assert not unquarantined, (
        f"{len(unquarantined)} detected runners were never quarantined")
    assert not late_writes, (
        f"{len(late_writes)} corrupted trajectories reached the writer "
        f"AFTER their runner was quarantined: {late_writes[:5]}")
    assert all(backend_totals[b] > 0 for b in BACKENDS), (
        f"learner stream is missing a backend: {backend_totals}")
    assert learner.updates >= 3, (
        f"only {learner.updates} learner updates — no loss trend")
    assert trend["decreased"], (
        f"learner loss did not decrease on the mixed stream: "
        f"{trend['first_third']:.4f} -> {trend['last_third']:.4f}")

    gate = {
        "completed": report.completed,
        "failed": report.failed,
        "routing_violations": len(violations),
        "all_backends_served": all(r["completed"] > 0 for r in rows),
        "injected_silent": len(all_injected),
        "all_silent_detected": not missed,
        "all_silent_quarantined": not unquarantined,
        "no_corrupt_after_quarantine": not late_writes,
        "corrupted_written": len(report.corrupted_writes),
        "total_traj_per_min": round(60.0 * report.completed / makespan, 2),
        "learner_updates": learner.updates,
        "loss_decreased": trend["decreased"],
    }
    payload = {
        "benchmark": "heterogeneous mixed fleet: four EnvBackends "
                     "(simos/swe/browser/mobile) through one gateway, "
                     "per-backend silent-failure canaries, one PPO "
                     "learner on the mixed stream",
        "metric": "per-backend traj/min, canary detection, routing "
                  "isolation (virtual seconds)",
        "seed": seed,
        "replicas_per_backend": REPLICAS_PER_BACKEND,
        "n_tasks": n_tasks,
        "kill_at_vs": KILL_AT_VS,
        "virtual_makespan_s": round(report.virtual_makespan, 2),
        "reassignments": report.reassignments,
        "backends": rows,
        "learner": {
            "updates": learner.updates,
            "loss_first_third": round(trend["first_third"], 4),
            "loss_last_third": round(trend["last_third"], 4),
            "steps_per_min": round(learner.steps_per_min(), 2),
            "backend_stream_totals": backend_totals,
        },
        "wall_seconds": round(time.monotonic() - t_wall, 2),
        "wall_budget_s": WALL_BUDGET_S,
        "gate": gate,
    }
    writer.close()
    cluster.close()
    return payload


def mixed_fleet_table(seed: int = 0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    payload = run_mixed_fleet_benchmark(seed)
    g = payload["gate"]
    per = ", ".join(f"{r['name']} {r['traj_per_min']:.0f}"
                    for r in payload["backends"])
    derived = (f"{len(payload['backends'])} backends through one gateway: "
               f"{g['completed']} episodes ({per} traj/min), "
               f"{g['routing_violations']} routing violations, "
               f"{g['injected_silent']} silent breaks all canary-detected, "
               f"loss decreased over {g['learner_updates']} PPO updates")
    return [payload], derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the run stays under this wall-clock "
                         "budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_mixedfleet.json")
    args = ap.parse_args()

    payload = run_mixed_fleet_benchmark(args.seed)
    g = payload["gate"]
    print(f"{'backend':>10} {'traj/min':>9} {'completed':>10} "
          f"{'injected':>9} {'detected':>9} {'det p95 (vs)':>13}")
    for r in payload["backends"]:
        print(f"{r['name']:>10} {r['traj_per_min']:>9.1f} "
              f"{r['completed']:>10} {r['injected_silent']:>9} "
              f"{r['silent_detected']:>9} {r['detection_p95_vs']:>13.1f}")
    lrn = payload["learner"]
    print(f"learner: {lrn['updates']} PPO updates on the mixed stream, "
          f"loss {lrn['loss_first_third']:.4f} -> "
          f"{lrn['loss_last_third']:.4f}")
    if args.budget_s is not None:
        assert payload["wall_seconds"] <= args.budget_s, (
            f"mixed-fleet benchmark took {payload['wall_seconds']:.1f}s "
            f"wall > budget {args.budget_s}s")
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"{g['completed']} episodes across {len(payload['backends'])} "
          f"backends, {g['routing_violations']} routing violations, "
          f"all {g['injected_silent']} silent breaks detected; "
          f"{payload['wall_seconds']:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
