"""Geo-distributed federation benchmark: outage survival, DiLoCo WAN
bytes, and spot-placement economics.

Three regions of 1024 replicas each (regional Table-1 price sheets) run
live load through the federated gateway, and the benchmark measures the
three geo-layer claims end to end:

- **(a) regional outage** — the most expensive region goes dark at
  ``t0`` (full brownout: unreachable + every in-flight episode killed).
  Its homed episodes spill to the cheapest healthy region over metered
  WAN control rounds and their trajectories ship home as WAN bytes.
  Gate: global throughput through the outage window stays >= 60% of the
  pre-outage steady state, and *all three* regional learner replicas —
  including the dark region's, fed by trajectories shipped home from
  spilled episodes — still show decreasing loss.
- **(b) DiLoCo vs per-step streaming** — the same regional rollout data
  drives two learner sync modes over the same metered WAN topology:
  DiLoCo outer steps every ``H`` inner steps (int8 parameter deltas) vs
  per-inner-step bf16 delta streaming (ring all-reduce bytes). Both
  modes run for the same number of inner steps; bytes are metered on the
  wire per region and must agree *exactly* with
  ``repro.distributed.diloco.cross_pod_bytes_per_cycle``. Gate: DiLoCo
  moves >= 10x fewer WAN bytes.
- **(c) spot vs on-demand** — the same workload runs twice on a small
  region: all on-demand, then spot-heavy (90% of hosts at the spot
  discount but carrying the ``preempt`` fault class — VMs reclaimed
  mid-episode, episodes retried through L2 recovery + failover). Gate:
  USD per trajectory is lower on spot despite the preemption retries.

    PYTHONPATH=src python benchmarks/federation.py

Emits ``artifacts/bench/BENCH_federation.json`` (per-region rows + gate
block); ``scripts/check_bench.py`` gates CI on it (counts and bytes on
the tight deterministic band, USD and wall on wide bands, WAN-byte and
USD metrics labeled lower-is-better, plus a hard wall budget).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.federation import Federation, FederatedLearners, RegionLearner, RegionSpec
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter

# --------------------------------------------------------------- phase (a)
# (name, regional price multiplier): the outage region is the priciest,
# so spill lands on the cheapest healthy peer by the routing rule
REGION_SHEET = (("us", 1.0), ("eu", 1.12), ("ap", 1.25))
N_PER_REGION = 1024
RUNNERS_PER_NODE = 64
EPISODES_PER_REPLICA = 3
OUTAGE_REGION = "ap"
OUTAGE_AT_VS = 60.0          # t0: full regional brownout
STEADY_WINDOW_VS = 40.0      # pre-outage window for the steady rate
OUTAGE_WINDOW_VS = 60.0      # post-t0 window for the survival rate
MIN_OUTAGE_THROUGHPUT = 0.60

# --------------------------------------------------------------- phase (b)
LEARNER_TRAJS_PER_REGION = 48
LEARNER_SEQ_LEN = 64
DILOCO_H = 10                # inner steps per outer sync
DILOCO_CYCLES = 2
MIN_WAN_REDUCTION_X = 10.0

# --------------------------------------------------------------- phase (c)
COST_REPLICAS = 256
COST_RUNNERS_PER_NODE = 32
COST_EPISODES = 512
SPOT_FRAC = 0.9
SPOT_DISCOUNT = 0.35
PREEMPT_RATE = 0.02

WALL_BUDGET_S = 120.0        # hard CI wall budget recorded in the baseline

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_federation.json")


def _tiny_trainer(seed: int):
    """One shared PPO trainer on the minimal reduced config: every
    regional learner swaps params through it, so the whole benchmark
    pays exactly one XLA compile for the train step and one for ingest."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train.ppo import PPOConfig, PPOTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264, d_model=32,
                      n_layers=1, n_heads=2, n_kv_heads=2, head_dim=16,
                      d_ff=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4), seed=seed)


def _regional_learners(trainer, registry, kept: dict, telemetry):
    """One RegionLearner per region, fed that region's homed
    trajectories. Must be called while ``trainer.params`` still holds
    the shared init snapshot (RegionLearner copies it as its anchor)."""
    from repro.data.replay_buffer import ReplayBuffer
    from repro.pipeline import (IngestConfig, LearnerConfig,
                                PolicyVersionStore, TrajectoryIngestor)

    learners = []
    for i, (name, trajs) in enumerate(sorted(kept.items())):
        replay = ReplayBuffer(capacity=512, seed=i, backend="soa",
                              seq_len=LEARNER_SEQ_LEN)
        store = PolicyVersionStore(trainer.params)
        ingest = TrajectoryIngestor(
            replay, store, registry=registry, trainer=trainer,
            cfg=IngestConfig(seq_len=LEARNER_SEQ_LEN, micro_batch=16),
            telemetry=telemetry)
        for t in trajs:
            ingest(t)
        ingest.flush()
        # wide staleness bound: this phase replays a fixed trajectory set
        # through many policy versions (stream mode publishes twice per
        # step) — off-policy eviction is not what it measures
        learners.append(RegionLearner(
            name, trainer, replay, store,
            cfg=LearnerConfig(batch_size=4, seq_len=LEARNER_SEQ_LEN,
                              staleness_bound=8 * DILOCO_CYCLES * DILOCO_H),
            telemetry=telemetry))
    return learners


def run_outage_phase(seed: int) -> dict:
    """Phase (a): 3 x 1024 replicas, full brownout of one region at t0.
    Returns rates, spill/WAN accounting, per-region rows, and the homed
    trajectories kept back for the learner phase."""
    registry = get_default_registry()
    specs = [RegionSpec(name, N_PER_REGION,
                        runners_per_node=RUNNERS_PER_NODE,
                        price_multiplier=mult)
             for name, mult in REGION_SHEET]
    fed = Federation(specs, seed=seed)
    tele = fed.telemetry
    names = [s.name for s in specs]

    tasks = [t.to_dict() for t in registry.sample(
        3 * N_PER_REGION * EPISODES_PER_REPLICA,
        seed=stable_seed(seed, "federation-workload"))]
    fed.assign(tasks)
    homed = {n: sum(1 for t in tasks if t["region"] == n) for n in names}

    # keep the first K trajectories homed to each region for the learner
    # phase — the dark region's arrive over the metered WAN from spilled
    # episodes, which is exactly the property phase (a) gates on
    kept: dict[str, list] = {n: [] for n in names}
    # queue sized to the fleet: a first completion wave of ~3N episodes
    # must not trip the high-water backpressure stall
    writer = TrajectoryWriter(retain=False, capacity=4 * N_PER_REGION)
    orig_write = writer.write

    def keeping_write(traj, timeout=None):
        lst = kept[fed.home_region(traj.task_id).name]
        if len(lst) < LEARNER_TRAJS_PER_REGION:
            lst.append(traj)
        return orig_write(traj, timeout)

    writer.write = keeping_write

    engine = RolloutEngine(fed, writer, registry=registry, telemetry=tele,
                           config=RolloutConfig(
                               max_inflight=3 * N_PER_REGION,
                               acquire_timeout_vs=3000.0))
    loop = EventLoop()
    killed: list[int] = []
    loop.call_later(OUTAGE_AT_VS,
                    lambda: killed.append(fed.brownout(OUTAGE_REGION)),
                    daemon=True)
    report = engine.run_event_driven(tasks, loop=loop)

    completions = sorted(tele.series("completion_vt"))
    steady_rate = sum(1 for t in completions
                      if OUTAGE_AT_VS - STEADY_WINDOW_VS <= t < OUTAGE_AT_VS
                      ) / STEADY_WINDOW_VS
    outage_rate = sum(1 for t in completions
                      if OUTAGE_AT_VS <= t < OUTAGE_AT_VS + OUTAGE_WINDOW_VS
                      ) / OUTAGE_WINDOW_VS

    spilled_by_pair = tele.counters("episodes_spilled:")
    ledger = fed.wan.ledger()
    by_kind = fed.wan.bytes_by_kind()
    rows = []
    for name, mult in REGION_SHEET:
        rows.append({
            "name": name,
            "replicas": N_PER_REGION,
            "price_multiplier": mult,
            "homed_tasks": homed[name],
            "spilled_out": sum(v for k, v in spilled_by_pair.items()
                               if k.startswith(f"{name}->")),
            "wan_bytes_out": sum(v for k, v in ledger.items()
                                 if k.startswith(f"{name}->")),
            "usd_per_day": round(fed.region(name).price_per_day(), 2),
        })
    writer.drain(timeout=30.0)
    writer.close()
    fed.close()
    return {
        "report": report,
        "rows": rows,
        "kept": kept,
        "registry": registry,
        "n_tasks": len(tasks),
        "killed_at_t0": killed[0] if killed else 0,
        "steady_rate": steady_rate,
        "outage_rate": outage_rate,
        "episodes_spilled": tele.counter("episodes_spilled"),
        "spill_attempts": tele.counter("spill_attempts"),
        "wan_trajectories": tele.counter("wan_trajectories"),
        "wan_bytes_total": fed.wan.total_bytes(),
        "wan_bytes_traj": by_kind.get("traj", 0),
        "wan_bytes_control": by_kind.get("control", 0),
        "wan_ledger": ledger,
        "virtual_makespan_s": round(report.virtual_makespan, 2),
    }


def run_sync_phase(kept: dict, registry, seed: int) -> dict:
    """Phase (b): the same regional trajectories drive both learner sync
    modes over one metered WAN topology; bytes must match the
    closed-form accounting exactly."""
    from repro.core.telemetry import Telemetry
    from repro.distributed.diloco import (DiLoCoConfig,
                                          cross_pod_bytes_per_cycle)
    from repro.federation import WanTopology

    tele = Telemetry()
    names = sorted(kept)
    wan = WanTopology.seeded(names, seed=stable_seed(seed, "wan"),
                             telemetry=tele)
    trainer = _tiny_trainer(seed)
    cfg = DiLoCoConfig(inner_steps=DILOCO_H)
    # both planes snapshot the same init params: build before stepping
    diloco_lrs = _regional_learners(trainer, registry, kept, tele)
    stream_lrs = _regional_learners(trainer, registry, kept, tele)
    diloco = FederatedLearners(diloco_lrs, cfg=cfg, wan=wan, telemetry=tele)
    stream = FederatedLearners(stream_lrs, cfg=cfg, wan=wan, telemetry=tele)

    inner_total = DILOCO_CYCLES * DILOCO_H
    for _ in range(DILOCO_CYCLES):
        for _ in range(DILOCO_H):
            for lr in diloco_lrs:
                assert lr.step() is not None, \
                    f"diloco learner {lr.name} had no batch ready"
        diloco.maybe_sync()
    for _ in range(inner_total):
        for lr in stream_lrs:
            assert lr.step() is not None, \
                f"stream learner {lr.name} had no batch ready"
        stream.stream_sync()

    acc = cross_pod_bytes_per_cycle(diloco.n_params, cfg)
    diloco_bytes = tele.counter("wan_bytes_kind:diloco")
    stream_bytes = tele.counter("wan_bytes_kind:stream")
    exact = (
        diloco_bytes
        == acc["diloco_bytes_per_H_steps"] * len(names) * DILOCO_CYCLES
        and stream_bytes
        == acc["baseline_bytes_per_H_steps"] * len(names) * DILOCO_CYCLES)
    trends = {lr.name: lr.loss_trend() for lr in diloco_lrs}
    return {
        "n_params": diloco.n_params,
        "inner_steps_per_region": inner_total,
        "outer_syncs": diloco.syncs,
        "wan_bytes_diloco": diloco_bytes,
        "wan_bytes_stream": stream_bytes,
        "wan_reduction_x": round(stream_bytes / diloco_bytes, 2),
        "bytes_accounting_exact": exact,
        "accounting": acc,
        "loss_trends": trends,
    }


def _cost_run(spec: RegionSpec, seed: int):
    """One small single-region run; returns (usd_per_traj, telemetry,
    report)."""
    registry = get_default_registry()
    fed = Federation([spec], seed=seed)
    tele = fed.telemetry
    writer = TrajectoryWriter(retain=False, capacity=512)
    engine = RolloutEngine(fed, writer, registry=registry, telemetry=tele,
                           config=RolloutConfig(
                               max_inflight=COST_REPLICAS,
                               acquire_timeout_vs=3000.0))
    tasks = [t.to_dict() for t in registry.sample(
        COST_EPISODES, seed=stable_seed(seed, "cost-workload"))]
    report = engine.run_event_driven(tasks, loop=EventLoop())
    usd = (fed.price_per_day() * report.virtual_makespan / 86400.0
           / max(report.completed, 1))
    writer.drain(timeout=30.0)
    writer.close()
    fed.close()
    return usd, tele, report


def run_cost_phase(seed: int) -> dict:
    """Phase (c): identical workload on-demand vs spot-heavy; spot must
    be cheaper per trajectory despite preemption retries."""
    od_usd, od_tele, od_rep = _cost_run(
        RegionSpec("ondemand", COST_REPLICAS,
                   runners_per_node=COST_RUNNERS_PER_NODE), seed)
    sp_usd, sp_tele, sp_rep = _cost_run(
        RegionSpec("spot", COST_REPLICAS,
                   runners_per_node=COST_RUNNERS_PER_NODE,
                   spot_frac=SPOT_FRAC, spot_discount=SPOT_DISCOUNT,
                   preempt_rate=PREEMPT_RATE), seed)
    return {
        "episodes": COST_EPISODES,
        "ondemand_usd_per_traj": round(od_usd, 6),
        "spot_usd_per_traj": round(sp_usd, 6),
        "spot_saving_frac": round(1.0 - sp_usd / od_usd, 4),
        "preemptions": sp_tele.counter("preemptions"),
        "ondemand_preemptions": od_tele.counter("preemptions"),
        "spot_reassignments": sp_rep.reassignments,
        "ondemand_completed": od_rep.completed,
        "spot_completed": sp_rep.completed,
    }


def run_federation_benchmark(seed: int = 0) -> dict:
    """All three phases; returns the full payload (rows + gate)."""
    t_wall = time.monotonic()
    a = run_outage_phase(seed)
    b = run_sync_phase(a["kept"], a["registry"], seed)
    c = run_cost_phase(seed)

    report = a["report"]
    outage_frac = (a["outage_rate"] / a["steady_rate"]
                   if a["steady_rate"] else 0.0)
    losses_ok = all(t["decreased"] for t in b["loss_trends"].values())
    dark_kept = len(a["kept"][OUTAGE_REGION])

    # ------------------------------------------------------------- asserts
    # A full regional kill catches a slice of episodes outside the step
    # phase (configure / reset / evaluate), where the baseline engine does
    # not fail over — those episodes fail honestly, exactly as the
    # recovery benchmark records them. Empirically ~1% of the backlog;
    # gate at 98.5% so a real routing regression still trips the assert.
    assert report.completed >= 0.985 * a["n_tasks"], (
        f"only {report.completed}/{a['n_tasks']} episodes completed — "
        f"the federation did not absorb the regional outage")
    assert a["killed_at_t0"] > 0, "brownout killed no in-flight episodes"
    assert a["episodes_spilled"] > 0 and a["wan_trajectories"] > 0, (
        "the outage produced no spill traffic — the WAN path never ran")
    assert outage_frac >= MIN_OUTAGE_THROUGHPUT, (
        f"global throughput through the outage window "
        f"({a['outage_rate'] * 60:.1f} traj/min) fell below "
        f"{MIN_OUTAGE_THROUGHPUT:.0%} of steady state "
        f"({a['steady_rate'] * 60:.1f} traj/min)")
    assert dark_kept > 0, (
        f"no {OUTAGE_REGION}-homed trajectories reached its learner")
    assert losses_ok, f"regional learner loss not decreasing: " \
                      f"{b['loss_trends']}"
    assert b["bytes_accounting_exact"], (
        f"metered WAN bytes disagree with cross_pod_bytes_per_cycle: "
        f"diloco {b['wan_bytes_diloco']}, stream {b['wan_bytes_stream']}, "
        f"accounting {b['accounting']}")
    assert b["wan_reduction_x"] >= MIN_WAN_REDUCTION_X, (
        f"DiLoCo moved only {b['wan_reduction_x']:.1f}x fewer WAN bytes "
        f"than streaming (need >= {MIN_WAN_REDUCTION_X:.0f}x)")
    assert c["preemptions"] > 0, "spot run saw no preemptions"
    assert c["ondemand_preemptions"] == 0, (
        "on-demand run saw preemptions — spot tiering leaked")
    assert c["spot_usd_per_traj"] < c["ondemand_usd_per_traj"], (
        f"spot placement is not cheaper: "
        f"{c['spot_usd_per_traj']:.6f} vs {c['ondemand_usd_per_traj']:.6f} "
        f"USD/traj")

    gate = {
        "completed": report.completed,
        "failed": report.failed,
        "killed_at_t0": a["killed_at_t0"],
        "episodes_spilled": a["episodes_spilled"],
        "wan_trajectories": a["wan_trajectories"],
        "wan_bytes_traj": a["wan_bytes_traj"],
        "wan_bytes_control": a["wan_bytes_control"],
        "steady_traj_per_min": round(a["steady_rate"] * 60.0, 1),
        "outage_traj_per_min": round(a["outage_rate"] * 60.0, 1),
        "outage_throughput_frac": round(outage_frac, 4),
        "outage_survived": outage_frac >= MIN_OUTAGE_THROUGHPUT,
        "learner_losses_decreasing": losses_ok,
        "wan_bytes_diloco": b["wan_bytes_diloco"],
        "wan_bytes_stream": b["wan_bytes_stream"],
        "wan_reduction_x": b["wan_reduction_x"],
        "bytes_accounting_exact": b["bytes_accounting_exact"],
        "ondemand_usd_per_traj": c["ondemand_usd_per_traj"],
        "spot_usd_per_traj": c["spot_usd_per_traj"],
        "spot_cheaper": c["spot_usd_per_traj"] < c["ondemand_usd_per_traj"],
        "preemptions": c["preemptions"],
    }
    return {
        "benchmark": "geo-distributed federation: full regional outage "
                     "under load, DiLoCo vs per-step streaming WAN "
                     "bytes, spot vs on-demand USD/traj",
        "metric": "outage-window throughput fraction, WAN bytes per sync "
                  "mode, USD per trajectory (virtual time)",
        "seed": seed,
        "regions": [dict(r) for r in a["rows"]],
        "outage": {
            "region": OUTAGE_REGION,
            "at_vs": OUTAGE_AT_VS,
            "window_vs": OUTAGE_WINDOW_VS,
            "wan_ledger": a["wan_ledger"],
            "spill_attempts": a["spill_attempts"],
        },
        "sync": {k: b[k] for k in
                 ("n_params", "inner_steps_per_region", "outer_syncs",
                  "accounting")},
        "cost": dict(c),
        "n_tasks": a["n_tasks"],
        "virtual_makespan_s": a["virtual_makespan_s"],
        "reassignments": report.reassignments,
        "wall_seconds": round(time.monotonic() - t_wall, 2),
        "wall_budget_s": WALL_BUDGET_S,
        "gate": gate,
    }


def federation_table(seed: int = 0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    payload = run_federation_benchmark(seed)
    g = payload["gate"]
    derived = (f"3x{N_PER_REGION} replicas: full {OUTAGE_REGION} outage "
               f"survived at {g['outage_throughput_frac']:.0%} steady "
               f"throughput ({g['episodes_spilled']} episodes spilled); "
               f"DiLoCo moved {g['wan_reduction_x']:.0f}x fewer WAN bytes "
               f"than streaming; spot placement "
               f"{payload['cost']['spot_saving_frac']:.0%} cheaper per "
               f"trajectory despite {g['preemptions']} preemptions")
    return [payload], derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the run stays under this wall-clock "
                         "budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_federation.json")
    args = ap.parse_args()

    payload = run_federation_benchmark(args.seed)
    g = payload["gate"]
    print(f"{'region':>10} {'homed':>7} {'spilled':>8} {'wan MB out':>11} "
          f"{'USD/day':>9}")
    for row in payload["regions"]:
        print(f"{row['name']:>10} {row['homed_tasks']:>7} "
              f"{row['spilled_out']:>8} "
              f"{row['wan_bytes_out'] / 1e6:>11.2f} "
              f"{row['usd_per_day']:>9.2f}")
    print(f"outage: {g['steady_traj_per_min']:.0f} -> "
          f"{g['outage_traj_per_min']:.0f} traj/min "
          f"({g['outage_throughput_frac']:.0%} of steady, "
          f"survived={g['outage_survived']})")
    print(f"sync:   diloco {g['wan_bytes_diloco'] / 1e3:.1f} KB vs stream "
          f"{g['wan_bytes_stream'] / 1e3:.1f} KB = "
          f"{g['wan_reduction_x']:.0f}x fewer bytes "
          f"(exact={g['bytes_accounting_exact']})")
    print(f"cost:   spot {g['spot_usd_per_traj']:.6f} vs on-demand "
          f"{g['ondemand_usd_per_traj']:.6f} USD/traj "
          f"({payload['cost']['spot_saving_frac']:.0%} saved, "
          f"{g['preemptions']} preemptions)")
    if args.budget_s is not None:
        assert payload["wall_seconds"] <= args.budget_s, (
            f"federation benchmark took {payload['wall_seconds']:.1f}s "
            f"wall > budget {args.budget_s}s")
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"{payload['wall_seconds']:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
