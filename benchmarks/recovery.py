"""Fig. 6 (right) live: mass-failure recovery of the real event-driven fleet.

The offline simulation (``core/simulation.run_recovery``) priced a full
fleet crash analytically; this benchmark runs the actual stack — a 1024
replica ``Cluster`` under live load from the ``RolloutEngine`` — through a
compound §3.4 failure at ``t0`` and records the recovery curve with the
multi-layer ladder (``repro.recovery``) doing the repairs:

- **30% fleet kill** — 30% of all runners crash mid-episode. In-flight
  episodes abort and fail over; the runners come back through L1 in-place
  recovery (release path + health sweeps).
- **silent corruption** — a set of runners is silently broken (the
  kernel-limit failure mode: every observation turns to garbage, nothing
  raises). Only the canary's known-answer checksum can see this; detected
  runners are quarantined and recreated on fresh VM allocations (L3).
- **one exhausted node** — every runner on one host is silently broken
  *and* the host's kernel limits are zeroed, so L3 recreations come back
  broken too. The ladder gives up on the node (L4): the cluster evicts it
  and replaces its capacity on the remaining hosts.

Asserts (the §3.4 robustness claims, measured live):

1. the fleet fully recovers — healthy capacity returns to the 1024
   target — while sustaining >= 50% of the pre-kill steady-state
   trajectory rate through the recovery window;
2. 100% of injected silently-broken runners are detected by the canary
   and quarantined, and no corrupted trajectory reaches the writer after
   its runner's quarantine (the in-flight one being written at the
   detection instant is the honest cost of detection latency);
3. exactly one node is evicted and its capacity is replaced.

    PYTHONPATH=src python benchmarks/recovery.py

Emits ``artifacts/bench/BENCH_recovery.json`` (recovery curve, per-layer
MTTR from telemetry, detection latencies, gate block);
``scripts/check_bench.py`` gates CI on it with direction-aware labels
(MTTR / detection / recovery-time are lower-is-better).
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter

N_REPLICAS = 1024
RUNNERS_PER_NODE = 64
EPISODES_PER_REPLICA = 5
KILL_AT_VS = 60.0            # t0: compound failure injection
KILL_FRAC = 0.30             # fraction of the fleet crashed at t0
SILENT_SCATTERED = 32        # silently-broken runners on healthy hosts
EVICT_HOST_IDX = 3           # host whose kernel limits are exhausted
CURVE_RESOLUTION_VS = 2.5
STEADY_WINDOW_VS = 40.0      # pre-kill window for the steady-state rate
MIN_RECOVERY_THROUGHPUT = 0.50
DETECTION_P95_BOUND_VS = 90.0   # canary interval + one full lease, slack
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_recovery.json")


def fleet_healthy(cluster: Cluster) -> int:
    """Live, uncorrupted replicas across the routed fleet."""
    return sum(p.health()["healthy"] for p in cluster.pools)


def run_recovery_benchmark(seed: int = 0) -> dict:
    """One end-to-end run; returns the full payload (rows + gate)."""
    t_wall = time.monotonic()
    registry = get_default_registry()
    cluster = Cluster(default_specs(N_REPLICAS,
                                    runners_per_node=RUNNERS_PER_NODE),
                      N_REPLICAS, runners_per_node=RUNNERS_PER_NODE,
                      seed=seed)
    tele = cluster.telemetry
    writer = TrajectoryWriter(retain=False, capacity=1024)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           telemetry=tele,
                           config=RolloutConfig(
                               max_inflight=N_REPLICAS,
                               acquire_timeout_vs=3000.0))
    tasks = registry.sample(N_REPLICAS * EPISODES_PER_REPLICA,
                            seed=stable_seed(seed, "recovery-workload"))
    loop = EventLoop()

    # ladder handles outlive eviction (the evicted host drops its pool
    # reference) — snapshot them up front for the detection audit
    pools = list(cluster.pools)
    ladders = [p.recovery for p in pools]
    evict_pool = pools[EVICT_HOST_IDX]
    evict_host = cluster.hosts[EVICT_HOST_IDX]

    injected: set[str] = set()
    killed: list[str] = []
    curve: list[tuple[float, int]] = []

    def inject_failures() -> None:
        """t0: the compound §3.4 failure event."""
        rng = random.Random(stable_seed(seed, "recovery-kill"))
        # exhausted node: zero its kernel limits so recreations are born
        # broken, and silently break every runner it is serving with
        for k in evict_host.sim.limits:
            evict_host.sim.limits[k] = 0
        for r in evict_pool._all.values():
            r.mark_silent_broken(loop.now)
            injected.add(r.runner_id)
        # scattered silent corruption on healthy hosts
        healthy_runners = [r for p in pools if p is not evict_pool
                           for r in p._all.values()]
        healthy_runners.sort(key=lambda r: r.runner_id)
        for r in rng.sample(healthy_runners, SILENT_SCATTERED):
            r.mark_silent_broken(loop.now)
            injected.add(r.runner_id)
        # 30% fleet kill (disjoint from the injected set)
        candidates = [r for r in healthy_runners
                      if r.runner_id not in injected]
        for r in rng.sample(candidates, int(KILL_FRAC * N_REPLICAS)):
            r.manager.replica.crash()
            killed.append(r.runner_id)

    def sample_curve() -> None:
        curve.append((round(loop.now, 2), fleet_healthy(cluster)))
        loop.call_later(CURVE_RESOLUTION_VS, sample_curve, daemon=True)

    loop.call_later(KILL_AT_VS, inject_failures, daemon=True)
    loop.call_later(0.0, sample_curve, daemon=True)

    report = engine.run_event_driven(tasks, loop=loop)
    curve.append((round(loop.now, 2), fleet_healthy(cluster)))

    # ------------------------------------------------------------ analysis
    completions = sorted(tele.series("completion_vt"))
    steady_rate = sum(1 for t in completions
                      if KILL_AT_VS - STEADY_WINDOW_VS <= t < KILL_AT_VS
                      ) / STEADY_WINDOW_VS
    lost_at_t0 = N_REPLICAS - min(h for t, h in curve if t >= KILL_AT_VS)
    t_full = next((t for t, h in curve
                   if t > KILL_AT_VS and h >= N_REPLICAS), None)
    t_half = next((t for t, h in curve
                   if t > KILL_AT_VS and h >= N_REPLICAS - lost_at_t0 // 2),
                  None)
    recovery_window = (t_full - KILL_AT_VS) if t_full else 0.0
    recovery_rate = (sum(1 for t in completions
                         if KILL_AT_VS <= t < t_full) / recovery_window
                     if t_full and recovery_window > 0 else 0.0)

    detected_at: dict[str, float] = {}
    quarantined_at: dict[str, float] = {}
    for lad in ladders:
        detected_at.update(lad.detected_at)
        quarantined_at.update(lad.quarantined_at)
    missed = injected - set(detected_at)
    unquarantined = injected - set(quarantined_at)
    late_writes = [(rid, vt) for rid, vt in report.corrupted_writes
                   if vt > quarantined_at.get(rid, float("inf")) + 1e-9]

    mttr = tele.summaries("recovery_mttr_vs:")
    detection = tele.summary("silent_detection_latency_vs")

    # ------------------------------------------------------------- asserts
    n_tasks = len(tasks)
    assert report.completed >= 0.99 * n_tasks, (
        f"only {report.completed}/{n_tasks} episodes completed — the "
        f"fleet did not absorb the failure event")
    assert t_full is not None, (
        f"fleet never recovered to {N_REPLICAS} healthy replicas "
        f"(final: {curve[-1][1]})")
    assert not missed, (
        f"{len(missed)}/{len(injected)} silently-broken runners were "
        f"never detected by the canary: {sorted(missed)[:5]}...")
    assert not unquarantined, (
        f"{len(unquarantined)} detected runners were never quarantined")
    assert not late_writes, (
        f"{len(late_writes)} corrupted trajectories reached the writer "
        f"AFTER their runner was quarantined: {late_writes[:5]}")
    assert recovery_rate >= MIN_RECOVERY_THROUGHPUT * steady_rate, (
        f"throughput during recovery ({recovery_rate * 60:.1f} traj/min) "
        f"fell below {MIN_RECOVERY_THROUGHPUT:.0%} of steady state "
        f"({steady_rate * 60:.1f} traj/min)")
    evicted = tele.counter("cluster_nodes_evicted")
    assert evicted == 1, f"expected exactly 1 node eviction, got {evicted}"
    assert detection.get("p95", 0.0) <= DETECTION_P95_BOUND_VS, (
        f"silent-failure detection p95 {detection['p95']:.1f}s exceeds "
        f"the canary bound {DETECTION_P95_BOUND_VS}s")
    for layer in ("l0", "l1", "l2", "l3"):
        assert mttr.get(layer, {}).get("n", 0) > 0, (
            f"recovery layer {layer} never fired — the ladder is not "
            f"exercising every layer")

    gate = {
        "killed": len(killed),
        "injected_silent": len(injected),
        "silent_detected": len(detected_at.keys() & injected),
        "silent_quarantined": len(quarantined_at.keys() & injected),
        "all_silent_detected": not missed,
        "no_corrupt_after_quarantine": not late_writes,
        "corrupted_written": len(report.corrupted_writes),
        "nodes_evicted": evicted,
        "full_recovery_vs": round(t_full - KILL_AT_VS, 2),
        "t50_vs": round(t_half - KILL_AT_VS, 2) if t_half else None,
        "detection_p95_vs": round(detection.get("p95", 0.0), 2),
        "steady_traj_per_min": round(steady_rate * 60.0, 1),
        "recovery_traj_per_min": round(recovery_rate * 60.0, 1),
        "recovery_throughput_frac": round(
            recovery_rate / steady_rate, 4) if steady_rate else 0.0,
        "mttr_l1_mean_vs": round(mttr["l1"]["mean"], 3),
        "mttr_l2_mean_vs": round(mttr["l2"]["mean"], 3),
        "mttr_l3_mean_vs": round(mttr["l3"]["mean"], 3),
        "completed": report.completed,
        "failed": report.failed,
    }
    payload = {
        "benchmark": "Fig. 6 recovery, live: 30% fleet kill + silent "
                     "corruption + one exhausted node at t0 under load, "
                     "multi-layer ladder recovery on the event-driven "
                     "engine",
        "metric": "healthy-replica recovery curve, per-layer MTTR, "
                  "silent-failure detection latency (virtual seconds)",
        "seed": seed,
        "replicas": N_REPLICAS,
        "kill_at_vs": KILL_AT_VS,
        "kill_frac": KILL_FRAC,
        "n_tasks": n_tasks,
        "virtual_makespan_s": round(report.virtual_makespan, 2),
        "reassignments": report.reassignments,
        "reflink_clones": cluster.store.reflink_clones,
        "recovery_curve": [[t, h] for t, h in curve],
        "mttr": mttr,
        "detection_latency": detection,
        "layer_events": {
            layer: sum(lad.layer_events[layer] for lad in ladders)
            for layer in ("l0", "l1", "l2", "l3", "l4")},
        "wall_seconds": round(time.monotonic() - t_wall, 2),
        "gate": gate,
    }
    writer.drain(timeout=30.0)
    writer.close()
    cluster.close()
    return payload


def recovery_table(seed: int = 0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    payload = run_recovery_benchmark(seed)
    g = payload["gate"]
    derived = (f"30% kill of {N_REPLICAS} replicas: full recovery in "
               f"{g['full_recovery_vs']:.0f}s (t50 {g['t50_vs']:.0f}s) at "
               f"{g['recovery_throughput_frac']:.0%} steady throughput; "
               f"{g['silent_detected']}/{g['injected_silent']} silent "
               f"failures canary-detected (p95 {g['detection_p95_vs']:.0f}s)"
               f", {g['nodes_evicted']} node evicted+replaced")
    return [payload], derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the run stays under this wall-clock "
                         "budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_recovery.json")
    args = ap.parse_args()

    payload = run_recovery_benchmark(args.seed)
    g = payload["gate"]
    print(f"{'phase':>22} {'value':>12}")
    print(f"{'steady traj/min':>22} {g['steady_traj_per_min']:>12.1f}")
    print(f"{'recovery traj/min':>22} {g['recovery_traj_per_min']:>12.1f}")
    print(f"{'full recovery (vs)':>22} {g['full_recovery_vs']:>12.1f}")
    print(f"{'t50 (vs)':>22} {g['t50_vs']:>12.1f}")
    print(f"{'detection p95 (vs)':>22} {g['detection_p95_vs']:>12.1f}")
    print(f"{'silent detected':>22} "
          f"{g['silent_detected']:>9}/{g['injected_silent']}")
    print(f"{'corrupted written':>22} {g['corrupted_written']:>12}")
    print(f"{'nodes evicted':>22} {g['nodes_evicted']:>12}")
    for layer, s in payload["mttr"].items():
        print(f"{'MTTR ' + layer + ' (vs)':>22} {s['mean']:>12.2f} "
              f"(n={s['n']})")
    if args.budget_s is not None:
        assert payload["wall_seconds"] <= args.budget_s, (
            f"recovery benchmark took {payload['wall_seconds']:.1f}s wall "
            f"> budget {args.budget_s}s")
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"full recovery of a {KILL_FRAC:.0%} kill in "
          f"{g['full_recovery_vs']:.0f} virtual seconds at "
          f"{g['recovery_throughput_frac']:.0%} of steady throughput; "
          f"{payload['wall_seconds']:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
