"""Roofline report: reads the dry-run artifacts and renders the per-cell
three-term table (§Roofline of EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str = "16_16", tag: str = "") -> list[dict]:
    """Baseline artifacts are <arch>--<shape>--<mesh>.json; hillclimb
    variants carry a -<tag> suffix and are excluded unless requested."""
    suffix = f"-{tag}" if tag else ""
    out = []
    for fn in sorted(glob.glob(os.path.join(ARTIFACTS,
                                            f"*--{mesh}{suffix}.json"))):
        base = os.path.basename(fn)
        parts = base[:-5].split("--")
        if len(parts) != 3 or parts[2] != mesh + suffix:
            continue
        with open(fn) as f:
            out.append(json.load(f))
    return out


def cell_row(c: dict) -> dict:
    if c.get("status") == "skipped":
        return {"arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
                "status": "skipped (documented)"}
    r = c["roofline"]
    total = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return {
        "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
        "compute_ms": round(r["compute_s"] * 1e3, 2),
        "memory_ms": round(r["memory_s"] * 1e3, 2),
        "collective_ms": round(r["collective_s"] * 1e3, 2),
        "dominant": r["dominant"].replace("_s", ""),
        "roofline_fraction": round(r["compute_s"] / total, 3) if total else 0,
        "useful_flops_ratio": round(c["useful_flops_ratio"], 2),
        "peak_gb": round(c["memory"]["tpu_adjusted_peak_bytes"] / 1e9, 2),
        "fits_16gb": c["fits_hbm"],
    }


def report(mesh: str = "16_16"):
    cells = load_cells(mesh)
    rows = [cell_row(c) for c in cells]
    rows.sort(key=lambda r: (r["arch"],
                             SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    ok = [r for r in rows if "roofline_fraction" in r]
    derived = (f"{len(rows)} cells on {mesh}; "
               f"{sum(1 for r in ok if r['dominant'] == 'compute')} compute-"
               f"bound, {sum(1 for r in ok if r['dominant'] == 'memory')} "
               f"memory-bound, "
               f"{sum(1 for r in ok if r['dominant'] == 'collective')} "
               f"collective-bound; median roofline fraction "
               f"{sorted(r['roofline_fraction'] for r in ok)[len(ok)//2] if ok else 0}")
    return rows, derived


def markdown_table(mesh: str = "16_16") -> str:
    rows, _ = report(mesh)
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | frac | useful | peak GB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if "roofline_fraction" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — | n/a |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
            f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
            f"{r['roofline_fraction']} | {r['useful_flops_ratio']} | "
            f"{r['peak_gb']} | {'y' if r['fits_16gb'] else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for mesh in ("16_16", "2_16_16"):
        print(f"\n== mesh {mesh} ==")
        print(markdown_table(mesh))
