"""Event-kernel scaling: the batched time wheel vs the scalar heap oracle.

The batched kernel (``repro.core.event_loop.BatchedEventLoop``) exists to
lift the fleet ceiling from ~1k replicas to 65k: one sort per time-wheel
bucket instead of one heap interaction per event. This sweep measures that
claim two ways and gates both:

- **kernel tier** — a pure timer workload: ``lanes`` independent chains of
  ``hops_per_lane`` lognormal hop latencies, pre-drawn as one numpy matrix
  consumed by *both* kernels. The scalar oracle drives it as generator
  tasks (one ``Sleep`` per hop); the batched kernel as a single
  ``VecTimer`` family chaining array schedules. Per-lane completion times
  are the same left-to-right float additions on both sides, so the
  ``done_at`` arrays must be **bit-identical** — asserted at every size —
  while the events/sec ratio isolates kernel cost from replica-model cost.
  The acceptance gate: >= 10x events/sec over the scalar kernel at 8k+.
- **engine tier** — the real ``RolloutEngine.run_event_driven`` over a
  paper-shaped fleet (64-runner nodes, stochastic faults, failover, health
  sweeps, writer backpressure) at 1k -> 8k -> 65k replicas on the batched
  kernel, with short-horizon tasks so the 65k run stays inside the CI wall
  budget. At 1024 replicas the same run is replayed on the scalar oracle
  and the reports must agree exactly (completed / failed / reassignments /
  virtual seconds / makespan / events processed) — the bit-exact parity
  contract, enforced in the live stack, not just in unit tests.

    PYTHONPATH=src python benchmarks/kernel_scaling.py --sizes 1024 8192 65536

The committed baseline ``artifacts/bench/BENCH_kernel.json`` records both
tiers plus a ``gate`` block (parity + speedup booleans, deterministic
counts) and the sweep's wall budget; ``scripts/check_bench.py`` compares
fresh runs against it in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.core.event_loop import EventLoop, Sleep
from repro.core.seeding import stable_seed
from repro.core.tasks import TaskSpec
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter

from throughput import build_fleet

DEFAULT_SIZES = (1024, 8192, 65536)
DEFAULT_HOPS = 8                 # timer chain length per lane (kernel tier)
SHORT_HORIZON = 3                # engine-tier steps/episode: bounds the 65k
#                                  run's wall cost without changing the stack
SPEEDUP_FLOOR = 10.0             # batched must beat scalar by this factor...
SPEEDUP_FROM = 8192              # ...from this lane count up (ISSUE 6 gate)
ENGINE_PARITY_MAX = 1024         # replay the engine on the oracle up to here
DEFAULT_BUDGET_S = 900.0         # CI wall budget for the whole sweep
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_kernel.json")

# engine-report fields that must agree exactly between kernels: event
# order determines every one of them, so a single reordered event shows up
ENGINE_PARITY_KEYS = ("completed", "failed", "reassignments", "total_steps",
                      "virtual_seconds", "virtual_makespan_s",
                      "events_processed")


# ------------------------------------------------------------- kernel tier
def lane_hops(n_lanes: int, n_hops: int, seed: int = 0) -> np.ndarray:
    """The shared workload: one lognormal hop-latency matrix, drawn once.

    Both kernels consume these exact values, so per-lane completion times
    (left-to-right cumulative sums) are bit-comparable across kernels."""
    rng = np.random.default_rng(stable_seed(seed, n_lanes, "kernel-hops"))
    return rng.lognormal(mean=0.5, sigma=0.4, size=(n_lanes, n_hops))


def run_lanes_scalar(hops: np.ndarray) -> tuple[np.ndarray, float, EventLoop]:
    """Oracle: one generator task per lane, one heap event per hop."""
    n, _n_hops = hops.shape
    loop = EventLoop(kernel="scalar")
    done_at = np.zeros(n)
    rows = hops.tolist()    # plain floats: per-event numpy indexing would
    #                         charge array-access cost to the kernel

    def lane(i: int, row: list):
        for dt in row:
            yield Sleep(dt)
        done_at[i] = loop.now

    t0 = time.perf_counter()
    for i in range(n):
        loop.spawn(lane(i, rows[i]), name=f"lane{i}")
    loop.run()
    return done_at, time.perf_counter() - t0, loop


def run_lanes_batched(hops: np.ndarray
                      ) -> tuple[np.ndarray, float, EventLoop, int]:
    """Batched: one ``VecTimer`` family chains every lane's hops by
    scheduling the continuing lanes' next hop times as one array per
    delivered bucket — a handful of Python callbacks for the whole run."""
    n, n_hops = hops.shape
    loop = EventLoop(kernel="batched")
    done_at = np.zeros(n)
    hop_no = np.zeros(n, dtype=np.int64)
    calls = 0

    def on_fire(ats: np.ndarray, idx: np.ndarray) -> None:
        nonlocal calls
        calls += 1
        h = hop_no[idx]
        last = h == n_hops - 1
        if last.any():
            done_at[idx[last]] = ats[last]
        cont = ~last
        if cont.any():
            nxt = idx[cont]
            vt.schedule(ats[cont] + hops[nxt, h[cont] + 1], nxt)
        hop_no[idx] = h + 1

    vt = loop.vec_timer(on_fire)
    t0 = time.perf_counter()
    vt.schedule(hops[:, 0], np.arange(n, dtype=np.int64))
    loop.run()
    return done_at, time.perf_counter() - t0, loop, calls


def run_lane_row(n_lanes: int, n_hops: int, seed: int = 0) -> dict:
    hops = lane_hops(n_lanes, n_hops, seed)
    events = n_lanes * n_hops
    done_s, wall_s, _loop_s = run_lanes_scalar(hops)
    done_b, wall_b, loop_b, calls = run_lanes_batched(hops)
    return {
        "lanes": n_lanes,
        "hops_per_lane": n_hops,
        "events": events,
        "scalar_events_per_s": events / wall_s,
        "batched_events_per_s": events / wall_b,
        "speedup": wall_s / wall_b,
        "scalar_wall_s": wall_s,
        "batched_wall_s": wall_b,
        "batched_callbacks": calls,
        "batched_buckets": loop_b.n_batches,
        # deterministic: max over identical float cumsums on both kernels
        "virtual_makespan_s": float(done_b.max()),
        "parity_bit_identical": done_s.tobytes() == done_b.tobytes(),
    }


# ------------------------------------------------------------- engine tier
def short_tasks(n: int, seed: int = 0) -> tuple[list[dict], object]:
    """The default scenario mix with every horizon clamped short, so the
    65k engine run exercises the full stack without a 65k-episode wall
    bill dominated by the replica model rather than the kernel."""
    registry = get_default_registry()
    tasks = []
    for t in registry.sample(n, seed=stable_seed(seed, n, "kernel-workload")):
        d = t.to_dict() if isinstance(t, TaskSpec) else dict(t)
        d["horizon"] = SHORT_HORIZON
        tasks.append(d)
    return tasks, registry


def run_engine(n_replicas: int, kernel: str, *, seed: int = 0) -> dict:
    """One end-to-end run of the real engine on the chosen kernel."""
    t0 = time.monotonic()
    tasks, registry = short_tasks(n_replicas, seed)
    gateway, _pools = build_fleet(n_replicas, seed=seed)
    writer = TrajectoryWriter(capacity=256, retain=False)
    engine = RolloutEngine(gateway, writer, registry=registry,
                           config=RolloutConfig(
                               max_inflight=n_replicas,
                               # fast virtual consumer: the drain tail of
                               # 65k writes must not dominate the makespan
                               # (and with it the daemon health sweeps)
                               writer_consume_vs=0.001))
    loop = EventLoop(kernel=kernel)
    report = engine.run_event_driven(tasks, loop=loop)
    writer.drain(timeout=60.0)
    writer.close()
    gateway.stop()
    row = {
        "replicas": n_replicas,
        "kernel": kernel,
        "completed": report.completed,
        "failed": report.failed,
        "reassignments": report.reassignments,
        "total_steps": report.total_steps,
        "events_processed": loop.n_processed,
        # engine-tier rate: replica-model Python cost is included, so this
        # understates the pure kernel ratio (the kernel-tier rows gate that)
        "events_per_s": loop.n_processed / max(report.wall_seconds, 1e-9),
        "virtual_seconds": report.virtual_seconds,
        "virtual_makespan_s": report.virtual_makespan,
        "traj_per_min": report.trajectories_per_min(n_replicas),
        "horizon": SHORT_HORIZON,
        "run_wall_seconds": report.wall_seconds,
        "wall_seconds": time.monotonic() - t0,
    }
    if kernel == "batched":
        row["n_batches"] = loop.n_batches
    return row


def engine_parity_ok(rows: list[dict]) -> bool:
    """True when every (replicas) pair of kernel rows agrees exactly."""
    by = {}
    for r in rows:
        by.setdefault(r["replicas"], {})[r["kernel"]] = r
    for pair in by.values():
        if "scalar" not in pair or "batched" not in pair:
            continue
        for key in ENGINE_PARITY_KEYS:
            if pair["scalar"][key] != pair["batched"][key]:
                return False
    return True


# ----------------------------------------------------------------- asserts
def assert_lane_parity(kernel_rows: list[dict]) -> None:
    for r in kernel_rows:
        assert r["parity_bit_identical"], (
            f"batched kernel diverged from the scalar oracle at "
            f"{r['lanes']} lanes — per-lane completion times not "
            f"bit-identical")


def assert_speedup(kernel_rows: list[dict]) -> None:
    for r in kernel_rows:
        if r["lanes"] >= SPEEDUP_FROM:
            assert r["speedup"] >= SPEEDUP_FLOOR, (
                f"batched kernel only {r['speedup']:.1f}x the scalar "
                f"oracle at {r['lanes']} lanes (floor {SPEEDUP_FLOOR}x)")


def assert_engine_parity(engine_rows: list[dict]) -> None:
    by = {}
    for r in engine_rows:
        by.setdefault(r["replicas"], {})[r["kernel"]] = r
    for n, pair in sorted(by.items()):
        if "scalar" not in pair or "batched" not in pair:
            continue
        for key in ENGINE_PARITY_KEYS:
            s, b = pair["scalar"][key], pair["batched"][key]
            assert s == b, (
                f"engine parity broke at {n} replicas: {key} scalar={s!r} "
                f"batched={b!r}")


# ----------------------------------------------------------------- harness
def sweep(sizes, n_hops: int = DEFAULT_HOPS, *, seed: int = 0
          ) -> tuple[list[dict], list[dict]]:
    kernel_rows = []
    engine_rows = []
    for n in sizes:
        kernel_rows.append(run_lane_row(n, n_hops, seed))
        r = kernel_rows[-1]
        print(f"kernel {n:>6} lanes: scalar "
              f"{r['scalar_events_per_s']:>10,.0f} ev/s, batched "
              f"{r['batched_events_per_s']:>12,.0f} ev/s "
              f"({r['speedup']:.1f}x, parity={r['parity_bit_identical']})")
    for n in sizes:
        engine_rows.append(run_engine(n, "batched", seed=seed))
        r = engine_rows[-1]
        print(f"engine {n:>6} replicas [batched]: {r['completed']} done, "
              f"{r['events_processed']} events, "
              f"{r['events_per_s']:,.0f} ev/s, {r['wall_seconds']:.1f}s wall")
        if n <= ENGINE_PARITY_MAX:
            engine_rows.append(run_engine(n, "scalar", seed=seed))
            r = engine_rows[-1]
            print(f"engine {n:>6} replicas [scalar]:  {r['completed']} done, "
                  f"{r['events_processed']} events, "
                  f"{r['events_per_s']:,.0f} ev/s, "
                  f"{r['wall_seconds']:.1f}s wall")
    return kernel_rows, engine_rows


def build_gate(kernel_rows: list[dict], engine_rows: list[dict]) -> dict:
    """Machine-independent gate: parity/speedup booleans plus exact
    deterministic counts at the largest swept size. Wall-clock rates stay
    *outside* the gate — check_bench compares them with a wide band and
    enforces the wall budget separately."""
    gate = {
        "lane_parity_bit_identical": all(
            r["parity_bit_identical"] for r in kernel_rows),
        "engine_parity_bit_identical": engine_parity_ok(engine_rows),
    }
    for r in kernel_rows:
        if r["lanes"] >= SPEEDUP_FROM:
            gate[f"speedup_{r['lanes']}_ge_{SPEEDUP_FLOOR:.0f}x"] = (
                r["speedup"] >= SPEEDUP_FLOOR)
    big_k = max(kernel_rows, key=lambda r: r["lanes"])
    gate[f"kernel_events_{big_k['lanes']}"] = big_k["events"]
    gate[f"lane_makespan_{big_k['lanes']}_s"] = big_k["virtual_makespan_s"]
    batched = [r for r in engine_rows if r["kernel"] == "batched"]
    big_e = max(batched, key=lambda r: r["replicas"])
    n = big_e["replicas"]
    gate[f"engine_completed_{n}"] = big_e["completed"]
    gate[f"engine_failed_{n}"] = big_e["failed"]
    gate[f"engine_events_{n}"] = big_e["events_processed"]
    gate[f"engine_makespan_{n}_s"] = big_e["virtual_makespan_s"]
    return gate


def write_baseline(path: str, kernel_rows: list[dict],
                   engine_rows: list[dict], gate: dict, *, sizes,
                   n_hops: int, budget_s: float,
                   wall_seconds: float) -> None:
    payload = {
        "benchmark": "event-kernel scaling: batched time wheel vs scalar "
                     "heap oracle, kernel-tier lanes + live RolloutEngine",
        "metric": "events per second (wall); parity and counts are "
                  "deterministic, rates are machine-dependent",
        "sizes": list(sizes),
        "hops_per_lane": n_hops,
        "short_horizon": SHORT_HORIZON,
        "speedup_floor": SPEEDUP_FLOOR,
        "speedup_from_lanes": SPEEDUP_FROM,
        "wall_budget_s": budget_s,
        "sweep_wall_seconds": round(wall_seconds, 2),
        "kernel": kernel_rows,
        "engine_sweep": engine_rows,
        "gate": gate,
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=list(DEFAULT_SIZES))
    ap.add_argument("--hops", type=int, default=DEFAULT_HOPS,
                    help="timer-chain length per lane in the kernel tier")
    ap.add_argument("--budget-s", type=float, default=DEFAULT_BUDGET_S,
                    help="assert the whole sweep stays under this wall "
                         "budget (CI guard, recorded in the baseline)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_kernel.json")
    args = ap.parse_args()

    t0 = time.monotonic()
    kernel_rows, engine_rows = sweep(tuple(args.sizes), args.hops)
    wall = time.monotonic() - t0

    assert_lane_parity(kernel_rows)
    assert_speedup(kernel_rows)
    assert_engine_parity(engine_rows)
    assert wall <= args.budget_s, (
        f"sweep took {wall:.1f}s wall > budget {args.budget_s}s")

    gate = build_gate(kernel_rows, engine_rows)
    write_baseline(args.out, kernel_rows, engine_rows, gate,
                   sizes=args.sizes, n_hops=args.hops,
                   budget_s=args.budget_s, wall_seconds=wall)
    big = max(kernel_rows, key=lambda r: r["lanes"])
    print(f"batched kernel: {big['batched_events_per_s']:,.0f} events/s at "
          f"{big['lanes']} lanes ({big['speedup']:.1f}x scalar, parity "
          f"bit-identical); sweep took {wall:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
