"""One benchmark per paper table/figure. Each returns (rows, derived-summary)
and is invoked by benchmarks.run."""
from __future__ import annotations

import math
import random
import statistics

from repro.core.cow_store import CowStore, DiskImage
from repro.core.orchestrator import table1 as _table1, fig3_sweep
from repro.core.replica import LatencyModel
from repro.core.simulation import sweep_throughput, recovery_stats
from repro.core.tasks import TABLE3_ROWS


# ------------------------------------------------------------ Fig 6 (left/mid)
def fig6_scalability(seeds: int = 10):
    rows = sweep_throughput(seeds=seeds)
    dec = {r["replicas"]: r for r in rows if r["design"] == "decentralized"}
    lin = (dec[1024]["steps_per_s_mean"]
           / (dec[16]["steps_per_s_mean"] * 64))
    derived = (f"decentralized 1024-replica scaling efficiency "
               f"{lin*100:.1f}% of ideal; latency "
               f"{dec[1024]['latency_mean_s']:.2f}s vs "
               f"{dec[16]['latency_mean_s']:.2f}s at 16")
    return rows, derived


# ------------------------------------------------------------ Fig 6 (right)
def fig6_recovery(seeds: int = 10):
    stats = recovery_stats(1024, seeds=seeds)
    derived = (f"1024-replica full-crash self-recovery in "
               f"{stats['full_recovery_mean_s']:.0f}"
               f"±{stats['full_recovery_std_s']:.0f}s "
               f"(t50 {stats['t50_mean_s']:.0f}s)")
    return [stats], derived


# ----------------------------------------------------------------- Fig 3
def fig3_orchestration(seeds: int = 10):
    rows = fig3_sweep(128, seeds=seeds)
    k1 = next(r for r in rows if r["K"] == 1)
    k64 = next(r for r in rows if r["K"] == 64)
    derived = (f"K=1: ${k1['usd_per_day']:.0f}/day cpu-bound "
               f"(overload {k1['overload_frac_mean']:.2f}); K=64: "
               f"${k64['usd_per_day']:.0f}/day ram-bound — "
               f"{k1['usd_per_day']/k64['usd_per_day']:.1f}x cheaper "
               f"(paper: ~300 -> ~30)")
    return rows, derived


# ---------------------------------------------------------------- Table 1
def table1_cost():
    rows = _table1()
    best = min(rows, key=lambda r: r["usd_per_replica_day"])
    derived = (f"best machine {best['cpu']} at "
               f"${best['usd_per_replica_day']:.2f}/replica/day "
               f"(paper: $0.23); 90% cheaper than "
               f"{max(r['usd_per_replica_day'] for r in rows):.2f}")
    return rows, derived


# ---------------------------------------------------------------- Table 2
def table2_cow(n_vms: int = 128, dirty_blocks_per_vm: int = 670):
    """128 VMs from one 24 GB base image, paper-calibrated write workload."""
    store = CowStore()                           # 4 MiB blocks
    base = DiskImage.create_base(store, "ubuntu", 24 * 10**9)
    rng = random.Random(0)

    vms, reflink_times = [], []
    for i in range(n_vms):
        vm, t = base.clone(f"vm{i}")
        reflink_times.append(t)
        vms.append(vm)
    for vm in vms:                               # run the workload
        for w in range(dirty_blocks_per_vm):
            vm.write_block(rng.randrange(len(vm.blocks)), f"w{w}")
    physical = store.physical_bytes()
    logical = base.logical_bytes()
    naive = (n_vms + 1) * logical
    _, full_copy_time = base.full_copy("naive-probe")
    rows = [{
        "per_vm_provision_reflink_s": round(statistics.fmean(reflink_times), 2),
        "per_vm_provision_full_s": round(full_copy_time, 1),
        "speedup_x": round(full_copy_time / statistics.fmean(reflink_times), 1),
        "physical_gb_reflink": round(physical / 1e9, 1),
        "physical_gb_naive": round(naive / 1e9, 1),
        "reduction_pct": round(100 * (1 - physical / naive), 1),
        "logical_gb_per_vm": round(logical / 1e9, 1),
    }]
    r = rows[0]
    derived = (f"{r['reduction_pct']}% physical-disk reduction "
               f"(paper: 88%), {r['speedup_x']}x faster provisioning "
               f"(paper: 37x), logical {r['logical_gb_per_vm']} GB intact")
    for vm in vms:
        vm.close()
    return rows, derived


# ---------------------------------------------------------------- Table 3
def table3_datagen(n_replicas: int = 1024, seeds: int = 3):
    """Reproduce the Table-3 dataset (2863 trajectories) generation times."""
    lat = LatencyModel()
    rng = random.Random(0)
    total_traj = sum(r[3] for r in TABLE3_ROWS)
    total_steps = sum(r[4] for r in TABLE3_ROWS)

    def traj_time(steps: int) -> float:
        return (lat.sample(rng, lat.configure_s)
                + lat.sample(rng, lat.reset_s)
                + sum(lat.sample(rng, lat.step_s) for _ in range(steps))
                + lat.sample(rng, lat.evaluate_s))

    serial = []
    for ttype, domain, desc, n_traj, n_steps in TABLE3_ROWS:
        per = n_steps / n_traj
        serial.append(sum(traj_time(round(per)) for _ in range(n_traj)))
    serial_total = sum(serial)
    # parallel makespan: greedy longest-processing-time over replicas
    lanes = [0.0] * n_replicas
    jobs = []
    for ttype, domain, desc, n_traj, n_steps in TABLE3_ROWS:
        jobs += [traj_time(round(n_steps / n_traj)) for _ in range(n_traj)]
    for j in sorted(jobs, reverse=True):
        i = min(range(n_replicas), key=lanes.__getitem__)
        lanes[i] += j
    parallel_total = max(lanes)
    rate = total_traj / (parallel_total / 60.0)
    # cloud cost: 8 E5-2699 machines, hourly billing, ~4h session incl. setup
    machines = math.ceil(n_replicas / 128)
    usd_day = 29.46
    session_h = 4.0
    cost = machines * usd_day / 24 * session_h
    rows = [{"task_type": t, "domain": d, "description": de,
             "trajectories": tr, "steps": st}
            for t, d, de, tr, st in TABLE3_ROWS]
    rows.append({"net_time_serial_s": round(serial_total),
                 "net_time_parallel_s": round(parallel_total),
                 "traj_per_min": round(rate),
                 "cloud_cost_usd": round(cost, 1)})
    derived = (f"{total_traj} trajectories / {total_steps} steps; serial "
               f"{serial_total:,.0f}s (paper: 115,654s) vs {n_replicas}-"
               f"replica parallel {parallel_total:.0f}s (paper: 121s) = "
               f"{rate:,.0f} traj/min (paper: ~1420); session cost "
               f"~${cost:.0f} (paper: $43)")
    return rows, derived
