"""Multi-tenant serving plane under open-loop tenant streams: fairness,
burst isolation, and admission control measured end to end.

The control plane (``repro.tenancy``) between job submission and the
gateway, exercised on the event-driven engine with seeded Poisson
arrival streams from hundreds of simulated tenants. Three scenarios:

- **steady** — ~160 equal-weight tenants trickle jobs at a fleet the
  capacity planner sized correctly; every tenant's submit->runner p99
  must sit inside the acquire-wait SLO.
- **burst** — the same quiet population plus one noisy tenant firing a
  10x spike through a tight token bucket. The spike must be *throttled
  at the door* (explicit ``AdmissionDecision``, not silent queue
  growth), no quiet tenant may be throttled, the quiet p99 must stay
  inside the SLO, and the Jain fairness index over quiet-tenant service
  must stay >= 0.9 — a noisy neighbor cannot move a quiet tail.
- **weighted** — three tenants with weights 1:2:4 saturating a small
  fleet until a virtual deadline; weighted DRR must split completed
  episodes proportionally to weight.

Every scenario also audits **zero cross-tenant trajectory leakage** by
construction: each completed episode's task is checked against the
submission-time tenant map (strictly per-tenant queues mean no episode
can ever be accounted to another tenant).

    PYTHONPATH=src python benchmarks/multitenant.py

Emits ``artifacts/bench/BENCH_multitenant.json``;
``scripts/check_bench.py`` gates CI on its per-scenario rows and gate
block (virtual-time metrics, deterministic per seed).
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.core.telemetry import p99
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter
from repro.tenancy import FairShareScheduler, Tenant, jain_index

N_STEADY_TENANTS = 160       # quiet tenants in the steady scenario
N_BURST_QUIET = 80           # quiet tenants sharing the fleet with a spike
JOBS_PER_TENANT = 4          # open-loop jobs per quiet tenant
BURST_MULTIPLIER = 10        # noisy tenant sends 10x a quiet tenant's jobs
SLO_WAIT_P99_VS = 120.0      # per-tenant submit->runner p99 target
JAIN_BOUND = 0.9             # quiet-tenant fairness floor under the burst
RUNNERS_PER_NODE = 32
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_multitenant.json")


# ---------------------------------------------------------------- workload
def tenant_streams(n_tenants: int, jobs_each: int, *, seed: int,
                   rate: float, label: str,
                   start_vs: float = 0.0) -> list[tuple[float, str]]:
    """Seeded per-tenant Poisson streams merged into one arrival-ordered
    ``(arrival_vs, tenant_id)`` list. Each tenant draws its own stream
    from a stable per-tenant seed, so adding tenants never perturbs an
    existing tenant's arrivals."""
    events: list[tuple[float, str]] = []
    for i in range(n_tenants):
        tid = f"{label}{i:03d}"
        rng = random.Random(stable_seed(seed, f"mt-{label}-{i}"))
        t = start_vs
        for _ in range(jobs_each):
            t += rng.expovariate(rate)
            events.append((t, tid))
    events.sort()
    return events


def build_tasks(events: list[tuple[float, str]], *, seed: int):
    """Scenario tasks for one merged arrival stream, tenant-stamped."""
    registry = get_default_registry()
    specs = registry.sample(len(events), seed=stable_seed(seed, "mt-tasks"))
    arrivals, tasks = [], []
    for spec, (at, tid) in zip(specs, events):
        d = spec.to_dict()
        d["tenant"] = tid
        arrivals.append(at)
        tasks.append(d)
    return registry, arrivals, tasks


# ------------------------------------------------------------------- runs
def run_scenario(name: str, tenants: list[Tenant],
                 events: list[tuple[float, str]], *, seed: int,
                 n_replicas: int, deadline_vs: float = None) -> dict:
    """Replay one merged tenant stream through the fair-share plane."""
    t0 = time.monotonic()
    registry, arrivals, tasks = build_tasks(events, seed=seed)
    submitted_by = {t["task_id"]: t["tenant"] for t in tasks}
    cluster = Cluster(default_specs(n_replicas), n_replicas,
                      runners_per_node=RUNNERS_PER_NODE, seed=seed)
    sched = FairShareScheduler(tenants, telemetry=cluster.telemetry)
    writer = TrajectoryWriter(retain=False, capacity=8192)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           telemetry=cluster.telemetry,
                           config=RolloutConfig(
                               max_inflight=n_replicas,
                               acquire_timeout_vs=3000.0,
                               virtual_deadline_s=deadline_vs))
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals, scheduler=sched)

    # zero cross-tenant leakage by construction: every settled episode's
    # task must still carry the tenant it was submitted under
    leaks = sum(1 for r in report.results
                if r.task.get("tenant") != submitted_by.get(
                    str(r.task.get("task_id"))))
    stats = sched.stats()
    throttled = sum(s.throttled for s in stats.values())
    wait_p99_by = {tid: p99(s.wait_vs) for tid, s in stats.items()
                   if s.wait_vs}
    row = {
        "name": name,
        "n_tenants": len(tenants),
        "n_jobs": len(tasks),
        "completed": report.completed,
        "failed": report.failed,
        "throttled": throttled,
        "dropped_at_stop": sum(s.queued_at_stop for s in stats.values()),
        "wait_p99_max_vs": max(wait_p99_by.values(), default=0.0),
        "virtual_makespan_s": report.virtual_makespan,
        "cross_tenant_leaks": leaks,
        "wall_seconds": time.monotonic() - t0,
    }
    writer.drain(timeout=30.0)
    writer.close()
    cluster.close()
    return row, stats, wait_p99_by


def multitenant_matrix(seed: int = 0) -> tuple[list[dict], dict]:
    """The three-scenario sweep; returns (rows, gate block)."""
    rows: list[dict] = []
    gate: dict = {"slo_wait_p99_vs": SLO_WAIT_P99_VS}

    # -- steady: a correctly sized fleet serves everyone inside the SLO
    quiet = [Tenant(f"q{i:03d}", slo_wait_p95_vs=SLO_WAIT_P99_VS)
             for i in range(N_STEADY_TENANTS)]
    events = tenant_streams(N_STEADY_TENANTS, JOBS_PER_TENANT, seed=seed,
                            rate=1.0 / 90.0, label="q")
    row, _stats, p99_by = run_scenario(
        "steady", quiet, events, seed=seed, n_replicas=64)
    assert row["completed"] == row["n_jobs"], (
        f"steady: {row['completed']}/{row['n_jobs']} completed — a "
        f"correctly sized fleet must serve the whole stream")
    assert row["throttled"] == 0, (
        f"steady: {row['throttled']} submissions throttled with capacity "
        f"to spare")
    assert row["wait_p99_max_vs"] <= SLO_WAIT_P99_VS, (
        f"steady: worst tenant p99 {row['wait_p99_max_vs']:.1f}vs > SLO "
        f"{SLO_WAIT_P99_VS}vs")
    gate["steady_wait_p99_vs"] = round(row["wait_p99_max_vs"], 3)
    rows.append(row)

    # -- burst: one noisy tenant's 10x spike vs a quiet population
    quiet = [Tenant(f"q{i:03d}", slo_wait_p95_vs=SLO_WAIT_P99_VS)
             for i in range(N_BURST_QUIET)]
    noisy = Tenant("noisy", burst_tokens=24.0, refill_per_vs=0.05,
                   max_queued=64)
    events = tenant_streams(N_BURST_QUIET, JOBS_PER_TENANT, seed=seed,
                            rate=1.0 / 90.0, label="q")
    spike = tenant_streams(1, BURST_MULTIPLIER * JOBS_PER_TENANT * 8,
                           seed=seed, rate=4.0, label="noisy",
                           start_vs=60.0)
    spike = [(at, "noisy") for at, _ in spike]
    merged = sorted(events + spike)
    row, stats, p99_by = run_scenario(
        "burst", quiet + [noisy], merged, seed=seed, n_replicas=64)
    quiet_p99 = max((p99_by[t.tenant_id] for t in quiet
                     if t.tenant_id in p99_by), default=0.0)
    quiet_throttled = sum(stats[t.tenant_id].throttled for t in quiet)
    noisy_throttled = stats["noisy"].throttled
    # fairness over the quiet population's delivered service: with equal
    # demand, any quiet tenant starved by the spike drags the index down
    jain = jain_index([stats[t.tenant_id].completed for t in quiet])
    assert quiet_p99 <= SLO_WAIT_P99_VS, (
        f"burst moved a quiet tenant's tail: p99 {quiet_p99:.1f}vs > SLO "
        f"{SLO_WAIT_P99_VS}vs")
    assert quiet_throttled == 0, (
        f"{quiet_throttled} quiet submissions throttled — the noisy "
        f"tenant's budget must absorb its own spike")
    assert noisy_throttled > 0, (
        "the 10x spike was never throttled — admission control is not "
        "engaging")
    assert jain >= JAIN_BOUND, (
        f"Jain fairness over quiet tenants {jain:.3f} < {JAIN_BOUND}")
    row["quiet_wait_p99_vs"] = round(quiet_p99, 3)
    row["jain_index"] = round(jain, 4)
    row["noisy_throttled"] = noisy_throttled
    gate.update({
        "burst_quiet_wait_p99_vs": round(quiet_p99, 3),
        "burst_jain_index": round(jain, 4),
        "burst_noisy_throttled": noisy_throttled,
        "burst_quiet_throttled": quiet_throttled,
    })
    rows.append(row)

    # -- weighted: DRR splits a saturated fleet 1:2:4 by weight
    weights = {"bronze": 1.0, "silver": 2.0, "gold": 4.0}
    tenants = [Tenant(tid, weight=w, max_inflight=64, max_queued=4096,
                      burst_tokens=512.0, refill_per_vs=8.0)
               for tid, w in weights.items()]
    events = []
    for tid in weights:
        events += [(at, tid) for at, _ in tenant_streams(
            1, 300, seed=seed, rate=8.0, label=tid)]
    events.sort()
    row, stats, _ = run_scenario(
        "weighted", tenants, events, seed=seed, n_replicas=32,
        deadline_vs=400.0)
    done = {tid: stats[tid].completed for tid in weights}
    assert min(done.values()) > 0, f"a tenant was starved outright: {done}"
    ratio_silver = done["silver"] / done["bronze"]
    ratio_gold = done["gold"] / done["bronze"]
    assert 1.4 <= ratio_silver <= 2.6, (
        f"weight-2 tenant got {ratio_silver:.2f}x the weight-1 share "
        f"(want ~2x): {done}")
    assert 2.8 <= ratio_gold <= 5.2, (
        f"weight-4 tenant got {ratio_gold:.2f}x the weight-1 share "
        f"(want ~4x): {done}")
    row["completed_by_tenant"] = done
    row["share_ratio_silver"] = round(ratio_silver, 3)
    row["share_ratio_gold"] = round(ratio_gold, 3)
    gate.update({
        "weighted_ratio_silver": round(ratio_silver, 3),
        "weighted_ratio_gold": round(ratio_gold, 3),
    })
    rows.append(row)

    leaks = sum(r["cross_tenant_leaks"] for r in rows)
    assert leaks == 0, f"{leaks} episodes leaked across tenants"
    gate["zero_cross_tenant_leakage"] = True
    return rows, gate


# ----------------------------------------------------------------- harness
def multitenant_table(seed: int = 0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    rows, gate = multitenant_matrix(seed)
    derived = (f"multi-tenant plane: quiet p99 {gate['burst_quiet_wait_p99_vs']}vs "
               f"under a 10x spike (SLO {SLO_WAIT_P99_VS:.0f}vs), Jain "
               f"{gate['burst_jain_index']}, DRR split "
               f"1:{gate['weighted_ratio_silver']}:{gate['weighted_ratio_gold']}")
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the whole sweep stays under this "
                         "wall-clock budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_multitenant.json")
    args = ap.parse_args()

    t0 = time.monotonic()
    rows, gate = multitenant_matrix(args.seed)
    wall = time.monotonic() - t0

    print(f"{'scenario':>9} {'tenants':>8} {'jobs':>6} {'done':>6} "
          f"{'throttled':>9} {'p99 wait':>9} {'makespan':>9}")
    for r in rows:
        print(f"{r['name']:>9} {r['n_tenants']:>8} {r['n_jobs']:>6} "
              f"{r['completed']:>6} {r['throttled']:>9} "
              f"{r['wait_p99_max_vs']:>9.2f} {r['virtual_makespan_s']:>9.1f}")

    if args.budget_s is not None:
        assert wall <= args.budget_s, (
            f"multitenant sweep took {wall:.1f}s wall > budget "
            f"{args.budget_s}s")

    payload = {
        "benchmark": "multi-tenant serving plane under open-loop tenant "
                     "streams (steady / 10x burst / weighted DRR)",
        "metric": "per-tenant submit->runner wait p99 (vs), Jain "
                  "fairness, throttle counts (virtual time)",
        "seed": args.seed,
        "slo_wait_p99_vs": SLO_WAIT_P99_VS,
        "workload": {
            "arrivals": "seeded per-tenant Poisson streams, merged",
            "n_tenants_total": sum(r["n_tenants"] for r in rows),
        },
        "sweep_wall_seconds": round(wall, 2),
        # hard CI guard: a fresh run must finish inside this wall budget
        # (the sweep takes ~3s locally; the budget absorbs slow CI hosts)
        "wall_budget_s": 120.0,
        "scenarios": rows,
        "gate": gate,
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"quiet p99 {gate['burst_quiet_wait_p99_vs']}vs under a 10x "
          f"spike (SLO {SLO_WAIT_P99_VS:.0f}vs); Jain "
          f"{gate['burst_jain_index']}; noisy throttled "
          f"{gate['burst_noisy_throttled']}; DRR split "
          f"1:{gate['weighted_ratio_silver']}:{gate['weighted_ratio_gold']}; "
          f"sweep {wall:.1f}s wall; baseline -> "
          f"{os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
