"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
benchmark computation itself)."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (e2e_pipeline, elastic_cluster, federation,
                        mixed_fleet, multitenant, paper_tables, recovery,
                        roofline, throughput)

OUTDIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def main() -> None:
    os.makedirs(OUTDIR, exist_ok=True)
    benches = [
        ("fig6_scalability", paper_tables.fig6_scalability),
        # live engine recovery (repro.recovery ladder); the offline
        # analytic walk is kept alongside as a cross-check
        ("fig6_recovery", recovery.recovery_table),
        ("fig6_recovery_sim", paper_tables.fig6_recovery),
        ("fig3_orchestration", paper_tables.fig3_orchestration),
        ("table1_cost", paper_tables.table1_cost),
        ("table2_cow", paper_tables.table2_cow),
        ("table3_datagen", paper_tables.table3_datagen),
        ("rollout_throughput",
         lambda: throughput.throughput_table(seeds=1)),
        ("elastic_cluster", elastic_cluster.elastic_table),
        ("multitenant", multitenant.multitenant_table),
        ("e2e_pipeline", e2e_pipeline.pipeline_table),
        ("federation", federation.federation_table),
        ("mixed_fleet", mixed_fleet.mixed_fleet_table),
        ("roofline_single_pod", lambda: roofline.report("16_16")),
        ("roofline_multi_pod", lambda: roofline.report("2_16_16")),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            rows, derived = fn()
            us = (time.time() - t0) * 1e6
            with open(os.path.join(OUTDIR, f"{name}.json"), "w") as f:
                json.dump(rows, f, indent=1, default=str)
            print(f'{name},{us:.0f},"{derived}"')
        except Exception as e:  # pragma: no cover
            print(f'{name},-1,"FAILED: {e!r}"')
    print("# artifacts in", os.path.abspath(OUTDIR), file=sys.stderr)


if __name__ == "__main__":
    main()
