"""End-to-end online RL pipeline benchmark: rollouts → replay → learner.

Runs the full closed loop on CPU — the event-driven ``RolloutEngine``
generating scenario episodes over a faulted fleet, the
``TrajectoryIngestor`` shaping scenario outcomes into rewards, and the
``LearnerLoop`` running real jitted PPO (or SFT) update steps on the
reduced ``qwen3-1.7b`` config — and reports the three paper-facing rates
side by side:

- trajectories/min (virtual-time, fleet-projected — the §5 data-plane
  number),
- learner update steps/min (wall-clock — the training-plane number),
- rollout→learner latency (wall seconds from episode ingest to the update
  that consumed it),

plus staleness accounting (samples reweighted/dropped by the off-policy
bound) and the learner's loss trend, which must decrease over the run.

    PYTHONPATH=src python benchmarks/e2e_pipeline.py --updates-per-round 4

Emits ``artifacts/bench/BENCH_e2e.json``; ``scripts/check_bench.py``
gates CI on the machine-independent metrics in its ``gate`` block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_e2e.json")


def run_pipeline(*, algo: str = "ppo", replicas: int = 16, rounds: int = 4,
                 tasks_per_round: int = 16, updates_per_round: int = 4,
                 seed: int = 0, lr: float = 3e-4):
    """One deterministic interleaved run; returns the PipelineReport."""
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.pipeline import (IngestConfig, LearnerConfig, OnlinePipeline,
                                PipelineConfig, build_fleet)
    from repro.train.ppo import PPOConfig, PPOTrainer
    from repro.train.sft import SFTTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264)
    model = build_model(cfg)
    if algo == "ppo":
        params = model.init(jax.random.PRNGKey(seed))
        trainer = PPOTrainer(model, params, cfg=PPOConfig(lr=lr), seed=seed)
    else:
        trainer = SFTTrainer(model, seed=seed)
    cluster = build_fleet(replicas, seed=seed)
    pipe = OnlinePipeline(
        cluster, replicas, trainer,
        pipe_cfg=PipelineConfig(rounds=rounds,
                                tasks_per_round=tasks_per_round,
                                updates_per_round=updates_per_round,
                                max_inflight=replicas, seed=seed),
        learner_cfg=LearnerConfig(algo=algo, batch_size=8, seq_len=192,
                                  staleness_bound=4,
                                  staleness_policy="reweight"),
        # interleaved mode consumes nothing mid-round, so deadline flushes
        # buy no latency — flush at round barriers only, with the fused
        # scoring width matched to the round's episode count (every flush
        # is one full fused call; no padding, no per-sample dispatch)
        ingest_cfg=IngestConfig(seq_len=192, micro_batch=tasks_per_round,
                                flush_wall_s=float("inf"),
                                flush_virtual_s=float("inf")))
    try:
        report = pipe.run_interleaved()
    finally:
        pipe.close()
        cluster.close()
    return report


def check_report(report, *, rounds: int, tasks_per_round: int) -> None:
    total = rounds * tasks_per_round
    assert report.rollout_completed >= 0.8 * total, (
        f"only {report.rollout_completed}/{total} episodes completed — "
        f"fault recovery is not keeping the pipeline fed")
    assert report.updates > 0, "learner never ran an update"
    assert report.loss_decreased, (
        f"learner loss did not decrease: first third "
        f"{report.loss_first_third:.4f} -> last third "
        f"{report.loss_last_third:.4f}")
    assert report.rollout_to_learner_s.get("n", 0) > 0, (
        "no rollout->learner latency was measured")


def pipeline_table():
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    report = run_pipeline(algo="ppo", replicas=8, rounds=2,
                          tasks_per_round=8, updates_per_round=2)
    rows = [report.to_dict()]
    derived = (f"online pipeline: {report.rollout_completed} traj -> "
               f"{report.updates} PPO updates, loss "
               f"{report.loss_first_third:.3f}->{report.loss_last_third:.3f}, "
               f"{report.stale_reweighted + report.stale_dropped} stale "
               f"samples handled")
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--algo", choices=("ppo", "sft"), default="ppo")
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--tasks-per-round", type=int, default=16)
    ap.add_argument("--updates-per-round", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert the whole run stays under this wall "
                         "budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    t0 = time.monotonic()
    report = run_pipeline(
        algo=args.algo, replicas=args.replicas, rounds=args.rounds,
        tasks_per_round=args.tasks_per_round,
        updates_per_round=args.updates_per_round, seed=args.seed)
    wall = time.monotonic() - t0

    check_report(report, rounds=args.rounds,
                 tasks_per_round=args.tasks_per_round)
    if args.budget_s is not None:
        assert wall <= args.budget_s, (
            f"e2e pipeline took {wall:.1f}s wall > budget {args.budget_s}s")

    lat = report.rollout_to_learner_s
    print(f"e2e pipeline ({args.algo}, {args.replicas} replicas): "
          f"{report.rollout_completed} trajectories "
          f"({report.rollout_failed} failed, "
          f"{report.reassignments} reassignments), "
          f"{report.updates} learner updates")
    print(f"  rollout: {report.rollout_traj_per_min:.1f} traj/min "
          f"(virtual, fleet-projected)")
    print(f"  learner: {report.learner_steps_per_min:.1f} update steps/min "
          f"(wall)")
    print(f"  rollout->learner latency: p50 {lat.get('p50', 0):.2f}s "
          f"p95 {lat.get('p95', 0):.2f}s (wall)")
    print(f"  loss: {report.loss_first_third:.4f} -> "
          f"{report.loss_last_third:.4f} "
          f"(decreased={report.loss_decreased})")
    print(f"  staleness: {report.stale_reweighted} reweighted, "
          f"{report.stale_dropped} dropped "
          f"(mean {report.staleness.get('mean', 0):.1f} versions)")
    print(f"  success rate: {report.success_rate:.0%}; wall {wall:.1f}s")

    payload = {
        "benchmark": "end-to-end online RL pipeline "
                     "(event-driven rollouts -> replay -> learner)",
        "algo": args.algo,
        "config": {
            "replicas": args.replicas, "rounds": args.rounds,
            "tasks_per_round": args.tasks_per_round,
            "updates_per_round": args.updates_per_round,
            "seed": args.seed, "model": "qwen3-1.7b (reduced)",
        },
        # machine-independent metrics the CI regression gate compares
        "gate": {
            "rollout_completed": report.rollout_completed,
            "rollout_traj_per_min": report.rollout_traj_per_min,
            "success_rate": report.success_rate,
            "updates": report.updates,
            "loss_decreased": report.loss_decreased,
        },
        # wall-clock metrics — informational (machine-dependent)
        "info": {
            "learner_steps_per_min": report.learner_steps_per_min,
            "rollout_to_learner_s": lat,
            "wall_seconds": round(wall, 2),
        },
        "report": report.to_dict(),
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"baseline -> {os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
