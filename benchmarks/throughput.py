"""Trajectory-generation throughput in virtual time (§4, Figure 6 left).

Measures **trajectories per minute** versus replica count for the three
state-management designs. Episodes are structured by the scenario
registry's per-family profiles (configure/reset/evaluate overhead, horizon
range, step latency), so the workload mix matches Table 3 rather than one
synthetic task. Dispatcher queueing for the centralized / semi baselines
reuses the M/M/1 model calibrated in ``core/simulation.py``; the run is
entirely in virtual time, so 1024 replicas simulate in seconds on one CPU.

Designs are compared with common random numbers: the same workload stream
(scenario draws, horizons, per-step base latencies) is priced under each
design, so the measured difference is exactly the dispatch overhead, not
sampling noise.

    PYTHONPATH=src python benchmarks/throughput.py --sizes 64 256 1024

The module asserts the paper's headline ordering: the decentralized design
strictly outperforms the centralized baseline at every fleet size.
"""
from __future__ import annotations

import argparse
import os
import random
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.simulation import SimConfig, dispatch_extra
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry

DESIGNS = ("centralized", "semi", "decentralized")
DEFAULT_SIZES = (64, 256, 1024)


def _lane_workload(wl: random.Random, registry: ScenarioRegistry,
                   sim_seconds: float) -> list[tuple[float, list[float], str]]:
    """One replica's episode stream: (overhead_s, per-step base latencies,
    scenario family). Design-independent — dispatch extras are priced later.
    Generates enough episodes to cover the window even with zero overhead."""
    scenarios = list(registry)
    weights = [s.weight for s in scenarios]
    episodes = []
    floor = 0.0                   # minimum possible time consumed so far
    while floor < sim_seconds:
        s = wl.choices(scenarios, weights=weights, k=1)[0]
        p = s.profile
        overhead = ((p.configure_s + p.reset_s + p.evaluate_s)
                    * wl.lognormvariate(0, p.step_sigma))
        steps = [p.step_mean_s * wl.lognormvariate(0, p.step_sigma)
                 for _ in range(wl.randint(*p.horizon))]
        episodes.append((overhead, steps, s.family))
        floor += overhead + sum(steps)
    return episodes


def _price(episodes, design: str, *, n_replicas: int,
           per_replica_rate: float, cfg: SimConfig, dx: random.Random,
           sim_seconds: float) -> tuple[int, list[float]]:
    """Walk one lane's workload under a design; return (completed within the
    window, all episode durations)."""
    completed = 0
    durations = []
    t = 0.0
    for overhead, steps, _family in episodes:
        dur = overhead
        for base in steps:
            dur += base + dispatch_extra(design, n_replicas,
                                         per_replica_rate, cfg, dx)
        durations.append(dur)
        t += dur
        if t < sim_seconds:
            completed += 1
    return completed, durations


def run_throughput_matrix(n_replicas: int, *, sim_seconds: float = 300.0,
                          seed: int = 0,
                          registry: ScenarioRegistry = None,
                          cfg: SimConfig = None,
                          designs=DESIGNS) -> dict[str, dict]:
    """Price one common workload under every design. Returns design -> row."""
    registry = registry or get_default_registry()
    cfg = cfg or SimConfig()
    wl = random.Random((seed, n_replicas).__hash__() & 0x7FFFFFFF)
    lanes = [_lane_workload(wl, registry, sim_seconds)
             for _ in range(n_replicas)]
    # each replica issues one op per (mean episode seconds / mean steps
    # per episode); dispatch_extra scales this to the fleet or group
    per_replica_rate = (registry.mean_steps_per_trajectory()
                        / registry.mean_trajectory_s())
    out = {}
    for design in designs:
        dx = random.Random((seed, n_replicas, design).__hash__() & 0x7FFFFFFF)
        total_completed = 0
        all_durations = []
        for lane in lanes:
            done, durs = _price(lane, design, n_replicas=n_replicas,
                                per_replica_rate=per_replica_rate, cfg=cfg,
                                dx=dx, sim_seconds=sim_seconds)
            total_completed += done
            all_durations.extend(durs)
        mean_ep = statistics.fmean(all_durations)
        out[design] = {
            "design": design, "replicas": n_replicas,
            # steady-state rate: every lane completes one episode per mean_ep
            "traj_per_min": n_replicas * 60.0 / mean_ep,
            "completed_in_window": total_completed,
            "episode_mean_s": mean_ep,
        }
    return out


def sweep(sizes=DEFAULT_SIZES, designs=DESIGNS, *, seeds: int = 3,
          sim_seconds: float = 300.0,
          registry: ScenarioRegistry = None) -> list[dict]:
    registry = registry or get_default_registry()
    rows = []
    for n in sizes:
        runs = [run_throughput_matrix(n, seed=s, sim_seconds=sim_seconds,
                                      registry=registry, designs=designs)
                for s in range(seeds)]
        for design in designs:
            per = [r[design] for r in runs]
            rows.append({
                "design": design, "replicas": n,
                "traj_per_min_mean": statistics.fmean(
                    r["traj_per_min"] for r in per),
                "traj_per_min_std": statistics.pstdev(
                    [r["traj_per_min"] for r in per]),
                "episode_mean_s": statistics.fmean(
                    r["episode_mean_s"] for r in per),
                "completed_in_window": sum(
                    r["completed_in_window"] for r in per),
            })
    return rows


def assert_decentralized_wins(rows: list[dict]) -> None:
    """The paper's headline claim, checked at every fleet size."""
    by = {(r["design"], r["replicas"]): r["traj_per_min_mean"] for r in rows}
    sizes = sorted({r["replicas"] for r in rows})
    for n in sizes:
        dec, cen = by[("decentralized", n)], by[("centralized", n)]
        assert dec > cen, (
            f"decentralized ({dec:.1f} traj/min) must beat centralized "
            f"({cen:.1f}) at {n} replicas")
        semi = by.get(("semi", n))
        if semi is not None:
            assert dec > semi, (
                f"decentralized ({dec:.1f}) must beat semi ({semi:.1f}) "
                f"at {n} replicas")


def throughput_table(sizes=DEFAULT_SIZES, seeds: int = 3,
                     sim_seconds: float = 300.0):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    rows = sweep(sizes, seeds=seeds, sim_seconds=sim_seconds)
    assert_decentralized_wins(rows)
    by = {(r["design"], r["replicas"]): r for r in rows}
    top = by[("decentralized", max(sizes))]
    cen = by[("centralized", max(sizes))]
    derived = (f"decentralized {top['traj_per_min_mean']:,.0f} traj/min at "
               f"{top['replicas']} replicas (paper: ~1420) — "
               f"{top['traj_per_min_mean'] / cen['traj_per_min_mean']:.1f}x "
               f"the centralized baseline")
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", type=int, nargs="+", default=list(DEFAULT_SIZES))
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--sim-seconds", type=float, default=300.0)
    args = ap.parse_args()
    assert len(args.sizes) >= 3, "report at least 3 replica-count settings"

    rows, derived = throughput_table(tuple(args.sizes), seeds=args.seeds,
                                     sim_seconds=args.sim_seconds)
    print(f"{'design':>14} {'replicas':>9} {'traj/min':>10} "
          f"{'±std':>7} {'episode_s':>10}")
    for r in rows:
        print(f"{r['design']:>14} {r['replicas']:>9} "
              f"{r['traj_per_min_mean']:>10.1f} "
              f"{r['traj_per_min_std']:>7.1f} "
              f"{r['episode_mean_s']:>10.1f}")
    print(derived)


if __name__ == "__main__":
    main()
