"""Trajectory throughput of the **real rollout stack** in virtual time.

The paper's headline numbers — 1000+ managed OS replicas, ~1420 multi-turn
trajectories/min — are measured here against the *live* engine: the
``RolloutEngine`` drives the ``Gateway`` / ``RunnerPool`` /
``ReplicaStateManager`` stack end-to-end on the discrete-event virtual-time
kernel (``repro.core.event_loop``), with stochastic faults, retry,
failover-with-node-exclusion, autonomous recovery, leaked-runner
reclamation, health sweeps, and writer backpressure all active. Episodes
are cooperative tasks, so a 1024-replica fleet completes thousands of
episodes in a few wall-seconds on one CPU.

Manager designs are priced with the shared
``state_manager.design_dispatch_overhead`` calibration (per-op dispatcher
cost: fleet-wide queueing for centralized, per-group + sync for semi,
constant for decentralized) injected via ``RolloutConfig.op_overhead`` —
the replica latency model is identical across designs, so the measured
difference is exactly the coordination cost.

The closed-form analytical walk the seed repo used (scenario-profile lane
workloads priced under the M/M/1 dispatcher model from
``core/simulation.py``) is kept as a cross-check; the committed baseline
``BENCH_throughput.json`` records both, plus the wall-clock cost of the
sweep.

    PYTHONPATH=src python benchmarks/throughput.py --sizes 64 256 1024

Asserts the paper's ordering — decentralized > semi > centralized at every
fleet size — and, when 1024 replicas are swept, that the decentralized
design delivers >= 1420 trajectories/min.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import random
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.cow_store import CowStore, DiskImage
from repro.core.event_loop import EventLoop
from repro.core.faults import FaultInjector
from repro.core.gateway import Gateway
from repro.core.runner_pool import RunnerPool
from repro.core.seeding import lognorm_jitter, stable_seed
from repro.core.simulation import SimConfig, dispatch_extra
from repro.core.state_manager import design_dispatch_overhead
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry
from repro.rollout.writer import TrajectoryWriter

DESIGNS = ("centralized", "semi", "decentralized")
DEFAULT_SIZES = (64, 256, 1024)
PAPER_TARGET_TRAJ_PER_MIN = 1420.0
RUNNERS_PER_NODE = 64            # executor-node granularity for the fleet
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "bench", "BENCH_throughput.json")


# --------------------------------------------------------- live-engine sweep
def build_fleet(n_replicas: int, *, seed: int = 0
                ) -> tuple[Gateway, list[RunnerPool]]:
    """A paper-shaped fleet: ``n_replicas`` runners across 64-runner
    executor nodes, default (tuned) kernel limits, stochastic faults on."""
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    n_nodes = math.ceil(n_replicas / RUNNERS_PER_NODE)
    pools = []
    for i in range(n_nodes):
        size = min(RUNNERS_PER_NODE, n_replicas - i * RUNNERS_PER_NODE)
        pools.append(RunnerPool(
            f"node{i}", base, size=size,
            faults=FaultInjector(seed=stable_seed(seed, n_replicas, i)),
            seed=stable_seed(seed, "pool", i)))
    return Gateway(pools), pools


def run_engine_throughput(n_replicas: int, design: str, *, seed: int = 0,
                          episodes_per_replica: int = 2,
                          registry: ScenarioRegistry = None) -> dict:
    """One end-to-end run of the real engine for one (fleet size, design).

    Entirely deterministic per seed: the event loop is single-threaded and
    tie-breaks by sequence number, every RNG is blake2b-seeded."""
    registry = registry or get_default_registry()
    t0 = time.monotonic()
    gateway, _pools = build_fleet(n_replicas, seed=seed)
    writer = TrajectoryWriter(capacity=256, retain=False)
    overhead = design_dispatch_overhead(design, n_replicas)
    engine = RolloutEngine(gateway, writer, registry=registry,
                           config=RolloutConfig(
                               max_inflight=n_replicas,
                               op_overhead=lambda: overhead))
    tasks = registry.sample(n_replicas * episodes_per_replica,
                            seed=stable_seed(seed, n_replicas, "workload"))
    report = engine.run_event_driven(tasks, loop=EventLoop())
    writer.drain(timeout=30.0)
    writer.close()
    gateway.stop()
    return {
        "design": design, "replicas": n_replicas,
        # steady-state rate (fully-packed lanes); the paper's session-rate
        # metric. Concurrency honesty is enforced separately by
        # assert_fleet_concurrency on the measured makespan.
        "traj_per_min": report.trajectories_per_min(n_replicas),
        # raw makespan rate of this short run — includes ramp-up, the
        # lognormal straggler tail, and backpressure stalls, so it
        # understates a long session; recorded for transparency
        "traj_per_min_makespan": (60.0 * report.completed
                                  / max(report.virtual_makespan, 1e-9)),
        "completed": report.completed, "failed": report.failed,
        "reassignments": report.reassignments,
        "backpressure_waits": report.backpressure_waits,
        "episode_mean_s": report.virtual_seconds / max(report.completed, 1),
        "virtual_makespan_s": report.virtual_makespan,
        "episodes_per_replica": episodes_per_replica,
        "op_overhead_s": overhead,
        "wall_seconds": time.monotonic() - t0,
    }


def engine_sweep(sizes=DEFAULT_SIZES, designs=DESIGNS, *, seeds: int = 1,
                 episodes_per_replica: int = 2,
                 registry: ScenarioRegistry = None) -> list[dict]:
    registry = registry or get_default_registry()
    rows = []
    for n in sizes:
        for design in designs:
            runs = [run_engine_throughput(
                n, design, seed=s, episodes_per_replica=episodes_per_replica,
                registry=registry) for s in range(seeds)]
            tpms = [r["traj_per_min"] for r in runs]
            # rates/durations are seed-averaged, counts are seed-summed,
            # and the makespan keeps the worst seed so the concurrency
            # guard validates every run, not just seed 0
            rows.append({
                "design": design, "replicas": n, "seeds": seeds,
                "traj_per_min": statistics.fmean(tpms),
                "traj_per_min_std": statistics.pstdev(tpms),
                "traj_per_min_makespan": statistics.fmean(
                    r["traj_per_min_makespan"] for r in runs),
                "completed": sum(r["completed"] for r in runs),
                "failed": sum(r["failed"] for r in runs),
                "reassignments": sum(r["reassignments"] for r in runs),
                "backpressure_waits": sum(
                    r["backpressure_waits"] for r in runs),
                "episode_mean_s": statistics.fmean(
                    r["episode_mean_s"] for r in runs),
                "virtual_makespan_s": max(
                    r["virtual_makespan_s"] for r in runs),
                "episodes_per_replica": episodes_per_replica,
                "op_overhead_s": runs[0]["op_overhead_s"],
                "wall_seconds": sum(r["wall_seconds"] for r in runs),
                "max_run_wall_seconds": max(
                    r["wall_seconds"] for r in runs),
            })
    return rows


SEMI_PAYS_OFF_AT = 64   # below this, semi's fixed inter-group sync cost
#                         outweighs centralized's per-replica queueing —
#                         a property of the overhead calibration, not a
#                         regression, so the full ordering is only
#                         asserted from here up (the benched sizes)


def assert_design_ordering(rows: list[dict],
                           key: str = "traj_per_min") -> None:
    """The paper's headline claim: decentralized > semi > centralized
    throughput at every fleet size (decentralized must win outright even
    below SEMI_PAYS_OFF_AT, where semi vs centralized is calibration-
    dependent)."""
    by = {(r["design"], r["replicas"]): r[key] for r in rows}
    for n in sorted({r["replicas"] for r in rows}):
        dec = by[("decentralized", n)]
        semi = by.get(("semi", n))
        cen = by[("centralized", n)]
        if semi is not None and n >= SEMI_PAYS_OFF_AT:
            assert dec > semi > cen, (
                f"expected decentralized > semi > centralized at {n} "
                f"replicas, got {dec:.1f} / {semi:.1f} / {cen:.1f}")
        else:
            assert dec > cen and (semi is None or dec > semi), (
                f"decentralized ({dec:.1f}) must beat every baseline at "
                f"{n} replicas (semi {semi}, centralized {cen:.1f})")


def assert_fleet_concurrency(rows: list[dict],
                             slack: float = 3.0) -> None:
    """The steady-state traj/min projection is insensitive to scheduling
    (it sums per-episode time), so guard it: the measured virtual makespan
    of ``episodes_per_replica`` waves must stay within ``slack``× the
    perfectly-packed lower bound. A serialized engine (e.g. a regression
    capping in-flight at 1) blows this by ~n_replicas×."""
    for r in rows:
        packed = r["episodes_per_replica"] * r["episode_mean_s"]
        assert r["virtual_makespan_s"] <= packed * slack, (
            f"{r['design']}@{r['replicas']}: makespan "
            f"{r['virtual_makespan_s']:.0f}s vs packed bound {packed:.0f}s "
            f"— the fleet is not actually running concurrently")


def assert_paper_target(rows: list[dict]) -> None:
    for r in rows:
        if r["design"] == "decentralized" and r["replicas"] == 1024:
            assert r["traj_per_min"] >= PAPER_TARGET_TRAJ_PER_MIN, (
                f"decentralized at 1024 replicas delivered "
                f"{r['traj_per_min']:.1f} traj/min < paper target "
                f"{PAPER_TARGET_TRAJ_PER_MIN}")


# ------------------------------------------------- analytical cross-check
def _lane_workload(wl: random.Random, registry: ScenarioRegistry,
                   sim_seconds: float) -> list[tuple[float, list[float], str]]:
    """One replica's episode stream: (overhead_s, per-step base latencies,
    scenario family). Design-independent — dispatch extras are priced later.
    Generates enough episodes to cover the window even with zero overhead."""
    scenarios = list(registry)
    weights = [s.weight for s in scenarios]
    episodes = []
    floor = 0.0                   # minimum possible time consumed so far
    while floor < sim_seconds:
        s = wl.choices(scenarios, weights=weights, k=1)[0]
        p = s.profile
        overhead = ((p.configure_s + p.reset_s + p.evaluate_s)
                    * lognorm_jitter(wl, p.step_sigma))
        steps = [p.step_mean_s * lognorm_jitter(wl, p.step_sigma)
                 for _ in range(wl.randint(*p.horizon))]
        episodes.append((overhead, steps, s.family))
        floor += overhead + sum(steps)
    return episodes


def _price(episodes, design: str, *, n_replicas: int,
           per_replica_rate: float, cfg: SimConfig, dx: random.Random,
           sim_seconds: float) -> tuple[int, list[float]]:
    """Walk one lane's workload under a design; return (completed within the
    window, all episode durations)."""
    completed = 0
    durations = []
    t = 0.0
    for overhead, steps, _family in episodes:
        dur = overhead
        for base in steps:
            dur += base + dispatch_extra(design, n_replicas,
                                         per_replica_rate, cfg, dx)
        durations.append(dur)
        t += dur
        if t < sim_seconds:
            completed += 1
    return completed, durations


def run_analytical_matrix(n_replicas: int, *, sim_seconds: float = 300.0,
                          seed: int = 0,
                          registry: ScenarioRegistry = None,
                          cfg: SimConfig = None,
                          designs=DESIGNS) -> dict[str, dict]:
    """Closed-form cross-check: price one common workload (common random
    numbers) under every design's M/M/1 dispatcher model. No engine, no
    faults — the fault-free upper bound the live numbers should track."""
    registry = registry or get_default_registry()
    cfg = cfg or SimConfig()
    wl = random.Random(stable_seed(seed, n_replicas))
    lanes = [_lane_workload(wl, registry, sim_seconds)
             for _ in range(n_replicas)]
    # each replica issues one op per (mean episode seconds / mean steps
    # per episode); dispatch_extra scales this to the fleet or group
    per_replica_rate = (registry.mean_steps_per_trajectory()
                        / registry.mean_trajectory_s())
    out = {}
    for design in designs:
        dx = random.Random(stable_seed(seed, n_replicas, design))
        total_completed = 0
        all_durations = []
        for lane in lanes:
            done, durs = _price(lane, design, n_replicas=n_replicas,
                                per_replica_rate=per_replica_rate, cfg=cfg,
                                dx=dx, sim_seconds=sim_seconds)
            total_completed += done
            all_durations.extend(durs)
        mean_ep = statistics.fmean(all_durations)
        out[design] = {
            "design": design, "replicas": n_replicas,
            # steady-state rate: every lane completes one episode per mean_ep
            "traj_per_min": n_replicas * 60.0 / mean_ep,
            "completed_in_window": total_completed,
            "episode_mean_s": mean_ep,
        }
    return out


def analytical_sweep(sizes=DEFAULT_SIZES, designs=DESIGNS, *, seeds: int = 2,
                     sim_seconds: float = 120.0,
                     registry: ScenarioRegistry = None) -> list[dict]:
    registry = registry or get_default_registry()
    rows = []
    for n in sizes:
        runs = [run_analytical_matrix(n, seed=s, sim_seconds=sim_seconds,
                                      registry=registry, designs=designs)
                for s in range(seeds)]
        for design in designs:
            per = [r[design] for r in runs]
            rows.append({
                "design": design, "replicas": n,
                "traj_per_min": statistics.fmean(
                    r["traj_per_min"] for r in per),
                "episode_mean_s": statistics.fmean(
                    r["episode_mean_s"] for r in per),
            })
    return rows


def assert_analytical_cross_check(engine_rows: list[dict],
                                  analytical_rows: list[dict]) -> None:
    """The fault-free closed form must upper-bound the live decentralized
    engine and stay within 25% of it: live overhead (faults, recovery,
    failover re-runs) costs something, but not more than a quarter. Only
    meaningful from SEMI_PAYS_OFF_AT up — tiny fleets run so few episodes
    that sampling noise swamps the bound."""
    ana = {(r["design"], r["replicas"]): r["traj_per_min"]
           for r in analytical_rows}
    for r in engine_rows:
        if r["design"] != "decentralized" \
                or r["replicas"] < SEMI_PAYS_OFF_AT:
            continue
        bound = ana.get((r["design"], r["replicas"]))
        if bound is None:
            continue
        live = r["traj_per_min"]
        assert live <= bound * 1.02, (
            f"live engine ({live:.1f}) cannot beat the fault-free "
            f"analytical bound ({bound:.1f}) at {r['replicas']} replicas")
        assert live >= bound * 0.75, (
            f"live engine ({live:.1f}) fell >25% below the analytical "
            f"cross-check ({bound:.1f}) at {r['replicas']} replicas")


# ----------------------------------------------------------------- harness
def throughput_table(sizes=DEFAULT_SIZES, seeds: int = 1):
    """(rows, derived) in the paper_tables convention for benchmarks/run.py."""
    rows = engine_sweep(sizes, seeds=seeds)
    assert_design_ordering(rows)
    assert_fleet_concurrency(rows)
    assert_paper_target(rows)
    by = {(r["design"], r["replicas"]): r for r in rows}
    top = by[("decentralized", max(sizes))]
    cen = by[("centralized", max(sizes))]
    derived = (f"live engine: decentralized {top['traj_per_min']:,.0f} "
               f"traj/min at {top['replicas']} replicas (paper: ~1420) — "
               f"{top['traj_per_min'] / cen['traj_per_min']:.1f}x the "
               f"centralized baseline, {top['wall_seconds']:.1f}s wall")
    return rows, derived


def write_baseline(path: str, engine_rows: list[dict],
                   analytical_rows: list[dict], *, sizes, seeds: int,
                   episodes_per_replica: int, wall_seconds: float) -> None:
    payload = {
        "benchmark": "trajectory throughput, live RolloutEngine on the "
                     "event-driven virtual-time kernel",
        "metric": "trajectories per minute (virtual time)",
        "paper_target_traj_per_min": PAPER_TARGET_TRAJ_PER_MIN,
        "sizes": list(sizes),
        "seeds": seeds,
        "episodes_per_replica": episodes_per_replica,
        "faults": "default stochastic rates (crash/hang/connection/"
                  "timeout/runtime), failover + recovery active",
        "sweep_wall_seconds": round(wall_seconds, 2),
        "engine": engine_rows,
        "analytical_cross_check": analytical_rows,
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=list(DEFAULT_SIZES))
    ap.add_argument("--seeds", type=int, default=1,
                    help="engine runs per (size, design); runs are "
                         "deterministic per seed")
    ap.add_argument("--episodes-per-replica", type=int, default=2)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="assert every single engine run stays under this "
                         "wall-clock budget (CI guard)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_throughput.json")
    args = ap.parse_args()
    assert len(args.sizes) >= 3, "report at least 3 replica-count settings"

    t0 = time.monotonic()
    engine_rows = engine_sweep(
        tuple(args.sizes), seeds=args.seeds,
        episodes_per_replica=args.episodes_per_replica)
    analytical_rows = analytical_sweep(tuple(args.sizes))
    wall = time.monotonic() - t0

    print(f"{'design':>14} {'replicas':>9} {'traj/min':>10} {'failed':>7} "
          f"{'reassign':>9} {'episode_s':>10} {'wall_s':>7}")
    for r in engine_rows:
        print(f"{r['design']:>14} {r['replicas']:>9} "
              f"{r['traj_per_min']:>10.1f} {r['failed']:>7} "
              f"{r['reassignments']:>9} {r['episode_mean_s']:>10.1f} "
              f"{r['wall_seconds']:>7.1f}")

    assert_design_ordering(engine_rows)
    assert_fleet_concurrency(engine_rows)
    # the M/M/1 closed form only supports the weaker dec > cen claim at
    # small fleets (an underloaded central dispatcher is nearly free in
    # that model — no per-replica bookkeeping cost), which is why the live
    # engine, priced on design_dispatch_overhead, is the headline number
    assert_design_ordering([r for r in analytical_rows
                            if r["design"] != "semi"])
    assert_analytical_cross_check(engine_rows, analytical_rows)
    if 1024 in args.sizes:
        assert_paper_target(engine_rows)
    if args.budget_s is not None:
        worst = max(engine_rows, key=lambda r: r["max_run_wall_seconds"])
        assert worst["max_run_wall_seconds"] <= args.budget_s, (
            f"{worst['design']}@{worst['replicas']} took "
            f"{worst['max_run_wall_seconds']:.1f}s wall for one run "
            f"> budget {args.budget_s}s")

    write_baseline(args.out, engine_rows, analytical_rows,
                   sizes=args.sizes, seeds=args.seeds,
                   episodes_per_replica=args.episodes_per_replica,
                   wall_seconds=wall)
    by = {(r["design"], r["replicas"]): r for r in engine_rows}
    top = by[("decentralized", max(args.sizes))]
    print(f"live decentralized: {top['traj_per_min']:,.1f} traj/min at "
          f"{top['replicas']} replicas (paper ~1420); sweep took "
          f"{wall:.1f}s wall; baseline -> {os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
