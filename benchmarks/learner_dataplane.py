"""Rollout→learner data-plane benchmark: batched ingest + fused learner.

Measures the two halves of the vectorized data plane against their
per-sample parity oracles, asserting bit-exactness before timing:

- **ingest** — the same episode stream is scored through the per-sample
  oracle (``micro_batch=1``, batch-size-1 jitted forwards into a
  dict-list buffer) and the micro-batched plane (``micro_batch=32``
  fused forward+log-softmax+gather flushes into the SoA arena). Every
  replay row must match the oracle bit for bit (including a remainder
  flush), then both planes are timed on a tiny model where per-sample
  dispatch overhead — the thing micro-batching deletes — dominates.
- **learner** — steady-state fused ``LearnerLoop`` update rate on the
  reduced e2e model (columns sampling, one numpy staleness pass,
  ``make_batch_columns`` assembly), compared against the learner rate of
  the committed end-to-end baseline the scalar plane produced.

    PYTHONPATH=src python benchmarks/learner_dataplane.py

Emits ``artifacts/bench/BENCH_dataplane.json``; ``scripts/check_bench.py``
gates CI on its ``gate`` block (parity booleans strict, deterministic
counts tight, wall-clock rates wide-banded).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "bench", "BENCH_dataplane.json"
)

# learner steps/min of the committed BENCH_e2e baseline (scalar data
# plane, compile included) at the time the fused-plane gate was set; the
# steady-state fused rate must clear 2x this. Pinned rather than read
# from BENCH_e2e.json so regenerating the e2e baseline on the fused
# plane cannot move this bar.
E2E_BASELINE_STEPS_PER_MIN = 174.4165349759431

INGEST_SEQ = 128
MICRO_BATCH = 32
# parity stream: two full flushes + one remainder flush (32 + 32 + 6)
PARITY_TRAJS = 70
TIMED_TRAJS = 96
LEARNER_STEPS = 16


def _trajectories(n: int, seed: int = 0):
    """Episodes with varied step counts and text, so sample lengths are
    ragged across a flush (the remainder-padding parity case)."""
    from repro.data.pipeline import Trajectory, TrajectoryStep

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        n_steps = int(rng.integers(2, 7))
        steps = [
            TrajectoryStep(
                rng.integers(0, 255, (8, 8, 3), np.uint8),
                f"thought {i}-{k} " + "x" * int(rng.integers(0, 12)),
                f"click({i}, {k})",
            )
            for k in range(n_steps)
        ]
        score = float(rng.uniform(0.0, 1.0))
        out.append(Trajectory(f"terminal_os-{i}", "configure the system", steps, score))
    return out


def build_trainer(*, tiny: bool, seed: int = 0):
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train.ppo import PPOConfig, PPOTrainer

    over = dict(vocab_size=264)
    if tiny:
        # small enough that a batch-size-1 forward is dispatch-bound on
        # CPU — the regime the paper's data plane batches away
        over.update(d_model=32, n_layers=1, n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64)
    cfg = get_reduced("qwen3-1.7b", **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4), seed=seed)


def make_ingestor(trainer, micro_batch: int, *, seq_len: int = INGEST_SEQ):
    from repro.core.telemetry import Telemetry
    from repro.data.replay_buffer import ReplayBuffer
    from repro.pipeline import IngestConfig, PolicyVersionStore, TrajectoryIngestor

    replay = ReplayBuffer(
        capacity=4096,
        seed=0,
        backend="soa" if micro_batch > 1 else "list",
        seq_len=seq_len if micro_batch > 1 else None,
    )
    store = PolicyVersionStore(trainer.params)
    ing = TrajectoryIngestor(
        replay,
        store,
        trainer=trainer,
        # wall deadline off: flushes here come from batch fill + flush()
        cfg=IngestConfig(
            seq_len=seq_len, micro_batch=micro_batch, flush_wall_s=float("inf")
        ),
        telemetry=Telemetry(),
    )
    return replay, ing


_EXACT_KEYS = (
    "tokens",
    "actions",
    "action_mask",
    "rewards",
    "old_logp",
    "values",
    "tokens_full",
    "loss_mask_full",
)


def assert_parity(oracle_rows: list, batched_rows: list) -> None:
    """Every batched-plane replay row must equal the oracle's, bit for bit
    (``ingest_wall`` excepted — it is a wall-clock stamp)."""
    assert len(oracle_rows) == len(batched_rows), (
        f"row count diverged: oracle {len(oracle_rows)} vs "
        f"batched {len(batched_rows)}"
    )
    for i, (a, b) in enumerate(zip(oracle_rows, batched_rows)):
        for key in _EXACT_KEYS:
            assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), (
                f"row {i} field {key!r} diverged between the per-sample "
                f"oracle and the micro-batched plane"
            )
        assert a["version"] == b["version"], (i, a["version"], b["version"])
        for key in ("task_id", "scenario", "family", "score", "success",
                    "n_steps", "episode_return"):
            assert a[key] == b[key], (i, key, a[key], b[key])


def run_ingest_bench(seed: int = 0) -> dict:
    trainer = build_trainer(tiny=True, seed=seed)

    # --- parity: same stream through both planes, compare every row
    trajs = _trajectories(PARITY_TRAJS, seed=seed)
    replay_s, ing_s = make_ingestor(trainer, 1)
    replay_b, ing_b = make_ingestor(trainer, MICRO_BATCH)
    for t in trajs:
        ing_s(t)
    for t in trajs:
        ing_b(t)
    flushed = ing_b.flush()  # remainder flush (PARITY_TRAJS % MICRO_BATCH rows)
    assert flushed == PARITY_TRAJS % MICRO_BATCH, flushed
    assert_parity(replay_s.snapshot(), replay_b.snapshot())
    print(f"  parity: {PARITY_TRAJS} samples bit-identical across planes "
          f"(remainder flush of {flushed})")

    # --- timing: both planes are already compiled (the parity pass warmed
    # them); feed a fresh stream through each and time the full ingest
    timed = _trajectories(TIMED_TRAJS, seed=seed + 1)
    t0 = time.monotonic()
    for t in timed:
        ing_s(t)
    wall_scalar = time.monotonic() - t0
    t0 = time.monotonic()
    for t in timed:
        ing_b(t)
    ing_b.flush()
    wall_batched = time.monotonic() - t0

    speedup = wall_scalar / wall_batched
    per_s_scalar = TIMED_TRAJS / wall_scalar
    per_s_batched = TIMED_TRAJS / wall_batched
    print(f"  ingest: scalar {per_s_scalar:.1f} samples/s, "
          f"batched (B={MICRO_BATCH}) {per_s_batched:.1f} samples/s "
          f"-> {speedup:.1f}x")
    return {
        "micro_batch": MICRO_BATCH,
        "seq_len": INGEST_SEQ,
        "parity_samples": PARITY_TRAJS,
        "timed_samples": TIMED_TRAJS,
        "samples_per_s_scalar": per_s_scalar,
        "samples_per_s_batched": per_s_batched,
        "speedup": speedup,
        "parity_bit_identical": True,  # assert_parity would have raised
    }


def run_learner_bench(seed: int = 0) -> dict:
    """Steady-state fused learner rate on the e2e reduced model: fill the
    arena through the batched ingest plane, warm one step, time the rest."""
    from repro.pipeline import LearnerConfig, LearnerLoop

    trainer = build_trainer(tiny=False, seed=seed)
    replay, ing = make_ingestor(trainer, MICRO_BATCH, seq_len=192)
    for t in _trajectories(64, seed=seed + 2):
        ing(t)
    ing.flush()
    learner = LearnerLoop(
        trainer,
        replay,
        ing.store,
        # a large bound keeps this a throughput measurement: version
        # churn over 1 + LEARNER_STEPS updates never evicts the arena
        cfg=LearnerConfig(algo="ppo", batch_size=8, seq_len=192, staleness_bound=64),
    )
    assert learner.step() is not None  # compile + warm
    t0 = time.monotonic()
    for _ in range(LEARNER_STEPS):
        metrics = learner.step()
        assert metrics is not None, "learner starved mid-measurement"
    wall = time.monotonic() - t0
    steps_per_min = 60.0 * LEARNER_STEPS / wall
    ratio = steps_per_min / E2E_BASELINE_STEPS_PER_MIN
    print(f"  learner: {steps_per_min:.1f} fused steps/min steady-state "
          f"({ratio:.2f}x the committed e2e baseline "
          f"{E2E_BASELINE_STEPS_PER_MIN:.1f}/min)")
    return {
        "steps_timed": LEARNER_STEPS,
        "batch_size": 8,
        "seq_len": 192,
        "steps_per_min": steps_per_min,
        "e2e_baseline_steps_per_min": E2E_BASELINE_STEPS_PER_MIN,
        "ratio_vs_e2e": ratio,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--budget-s",
        type=float,
        default=None,
        help="assert the whole run stays under this wall budget (CI guard)",
    )
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    t0 = time.monotonic()
    print("ingest plane (tiny model, dispatch-bound):")
    ingest = run_ingest_bench(seed=args.seed)
    print("learner plane (reduced e2e model):")
    learner = run_learner_bench(seed=args.seed)
    wall = time.monotonic() - t0

    gate = {
        "ingest_parity_bit_identical": ingest["parity_bit_identical"],
        "ingest_speedup_ge_5x": ingest["speedup"] >= 5.0,
        "learner_ge_2x_e2e": learner["ratio_vs_e2e"] >= 2.0,
        "samples": ingest["timed_samples"],
        "parity_samples": ingest["parity_samples"],
        "ingest_speedup": ingest["speedup"],
        "learner_steps_per_min": learner["steps_per_min"],
    }
    assert gate["ingest_parity_bit_identical"]
    assert gate["ingest_speedup_ge_5x"], (
        f"micro-batched ingest speedup {ingest['speedup']:.2f}x < 5x"
    )
    assert gate["learner_ge_2x_e2e"], (
        f"fused learner {learner['steps_per_min']:.1f} steps/min < 2x the "
        f"e2e baseline {E2E_BASELINE_STEPS_PER_MIN:.1f}"
    )
    if args.budget_s is not None:
        assert wall <= args.budget_s, (
            f"dataplane bench took {wall:.1f}s wall > budget {args.budget_s}s"
        )

    payload = {
        "benchmark": "rollout->learner data plane "
        "(micro-batched ingest -> SoA arena -> fused learner)",
        "config": {"seed": args.seed, "model": "qwen3-1.7b (reduced + tiny)"},
        "ingest": ingest,
        "learner": learner,
        "gate": gate,
        # hard CI wall ceiling for a fresh run of this benchmark
        "wall_budget_s": 300.0,
        "bench_wall_seconds": round(wall, 2),
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wall {wall:.1f}s; baseline -> {os.path.relpath(args.out)}")


if __name__ == "__main__":
    main()
