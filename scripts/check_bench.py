"""Bench-regression gate: compare fresh benchmark results to baselines.

CI regenerates the benchmark artifacts on every run; this script compares
them against the committed baselines and fails the job when a
machine-independent metric drifts outside the tolerance band (default
±10%). Virtual-time metrics are deterministic per seed, so drift in
either direction is a signal: a drop is a throughput regression, a rise
means the committed baseline is stale and must be regenerated
(`python benchmarks/throughput.py`, `python benchmarks/e2e_pipeline.py`)
and committed with the change that moved it.

    python scripts/check_bench.py \
        --baseline artifacts/bench/BENCH_throughput.json \
        --fresh /tmp/BENCH_throughput.json

The benchmark kind is auto-detected from the payload shape: kernel
baselines carry per-lane-count `kernel` rows, throughput baselines carry
per-(design, fleet-size) `engine` rows, elastic-cluster baselines carry
per-cluster `clusters` rows, recovery baselines carry a
`recovery_curve`, data-plane baselines carry `ingest` + `learner`
blocks, multi-tenant baselines carry per-scenario `scenarios` rows,
federation baselines carry per-region `regions` rows, mixed-fleet
baselines carry per-backend `backends` rows, e2e baselines carry a
bare `gate` block. Gate metrics are direction-aware: MTTR /
detection-latency / recovery-time / wait-p99 / WAN-byte / USD-per-traj
names are recognized as lower-is-better, so a *rise* there is the
regression and a drop flags a stale baseline. Kernel, data-plane,
multi-tenant, and federation baselines additionally enforce a hard wall
budget: the fresh run must have finished inside the `wall_budget_s`
recorded in the committed baseline.
"""

from __future__ import annotations

import argparse
import json
import sys


def rel_dev(base: float, fresh: float) -> float:
    """Signed relative deviation of fresh vs base (0.0 when both zero)."""
    if base == 0.0:
        return 0.0 if fresh == 0.0 else float("inf")
    return (fresh - base) / abs(base)


def compare_value(
    name: str, base: float, fresh: float, tol: float, *, lower_is_better: bool = False
) -> list[str]:
    """Band check with direction-aware labels: for a higher-is-better
    metric a drop is the regression; for a lower-is-better one (cost,
    latency) a rise is — the other direction means the committed
    baseline is stale. Either way, out-of-band fails."""
    dev = rel_dev(base, fresh)
    if abs(dev) <= tol:
        return []
    worsened = dev > tol if lower_is_better else dev < -tol
    direction = "above" if dev > 0 else "below"
    if worsened:
        msg = (
            f"REGRESSION {name}: {fresh:.3f} is {abs(dev):.1%} {direction} "
            f"baseline {base:.3f} (tolerance {tol:.0%})"
        )
    else:
        msg = (
            f"STALE BASELINE {name}: {fresh:.3f} is {abs(dev):.1%} {direction} "
            f"baseline {base:.3f} — regenerate and commit the baseline"
        )
    return [msg]


def check_throughput(base: dict, fresh: dict, tol: float) -> list[str]:
    """Per-(design, fleet size) traj/min comparison of the engine rows."""
    problems: list[str] = []
    fresh_rows = {}
    for row in fresh.get("engine", []):
        fresh_rows[(row["design"], row["replicas"])] = row
    for row in base.get("engine", []):
        key = (row["design"], row["replicas"])
        other = fresh_rows.get(key)
        name = f"traj/min[{key[0]}@{key[1]}]"
        if other is None:
            problems.append(f"MISSING {name}: not in fresh results")
            continue
        problems += compare_value(
            name, row["traj_per_min"], other["traj_per_min"], tol
        )
    if not base.get("engine"):
        problems.append("MALFORMED baseline: no engine rows")
    return problems


# (metric, lower_is_better): replica-days and acquire-wait are costs
ELASTIC_METRICS = (
    ("traj_per_min", False),
    ("replica_days", True),
    ("acquire_wait_p95_vs", True),
)


def check_elastic(base: dict, fresh: dict, tol: float) -> list[str]:
    """Per-cluster comparison of the elastic rows, plus the gate block."""
    problems: list[str] = []
    fresh_rows = {row["name"]: row for row in fresh.get("clusters", [])}
    base_rows = base.get("clusters", [])
    if not base_rows:
        problems.append("MALFORMED baseline: no cluster rows")
    for row in base_rows:
        other = fresh_rows.get(row["name"])
        if other is None:
            problems.append(f"MISSING cluster[{row['name']}]: not in fresh results")
            continue
        for metric, lower_is_better in ELASTIC_METRICS:
            name = f"{metric}[{row['name']}]"
            problems += compare_value(
                name, row[metric], other[metric], tol, lower_is_better=lower_is_better
            )
    problems += check_gate(base, fresh, tol)
    return problems


def check_e2e(base: dict, fresh: dict, tol: float) -> list[str]:
    """Gate-block comparison: booleans must hold, numbers stay in band."""
    return check_gate(base, fresh, tol)


# gate-metric names matching any of these substrings are costs: a rise is
# the regression (repair slower, detection later, more corruption)
LOWER_IS_BETTER_HINTS = (
    "mttr",
    "latency",
    "detection",
    "recovery_vs",
    "t50",
    "corrupted",
    "failed",
    "replica_days",
    "wait_p99",
    "throttled",
    "wan_bytes",
    "usd_per_traj",
    "violations",
)


def gate_metric_is_cost(name: str) -> bool:
    return any(h in name for h in LOWER_IS_BETTER_HINTS)


# kernel events/sec rows are wall-clock rates: raw rates swing with CI
# host speed and load (>= 50% observed on one machine), so they get a
# very wide sanity band; the batched/scalar speedup ratio cancels host
# speed and gets a tighter one. Event counts, virtual makespans, and
# the gate block stay on the normal (deterministic) band.
KERNEL_RATE_TOL_FLOOR = 0.80
KERNEL_WALL_TOL_FLOOR = 0.50


def check_kernel(base: dict, fresh: dict, tol: float) -> list[str]:
    """Kernel-scaling baselines: per-lane-count events/sec rows (wide,
    host-dependent band), deterministic counts (normal band), the gate
    block, and the hard wall budget."""
    problems: list[str] = []
    rate_tol = max(tol, KERNEL_RATE_TOL_FLOOR)
    wall_tol = max(tol, KERNEL_WALL_TOL_FLOOR)
    base_rows = base.get("kernel", [])
    if not base_rows:
        problems.append("MALFORMED baseline: no kernel rows")
    fresh_rows = {row["lanes"]: row for row in fresh.get("kernel", [])}
    for row in base_rows:
        sfx = f"[{row['lanes']} lanes]"
        other = fresh_rows.get(row["lanes"])
        if other is None:
            problems.append(f"MISSING kernel{sfx}: not in fresh results")
            continue
        for metric, band in (
            ("events", tol),
            ("virtual_makespan_s", tol),
            ("batched_events_per_s", rate_tol),
            ("speedup", wall_tol),
        ):
            problems += compare_value(
                f"{metric}{sfx}", row[metric], other[metric], band
            )
    budget = base.get("wall_budget_s")
    if budget is not None:
        wall = fresh.get("sweep_wall_seconds")
        if wall is None:
            problems.append("MISSING sweep_wall_seconds: not in fresh results")
        elif wall > budget:
            problems.append(
                f"REGRESSION sweep_wall_seconds: {wall:.1f}s exceeds the "
                f"baseline wall budget {budget:.1f}s"
            )
    problems += check_gate(base, fresh, tol)
    return problems


# data-plane band assignment mirrors the kernel rationale: samples/sec
# and steps/min are wall-clock rates (host-dependent, wide band);
# batched-vs-scalar speedup is a same-host ratio (medium band); parity
# booleans and sample counts are deterministic (strict / normal band).
DATAPLANE_METRICS = {
    "ingest": (
        ("parity_samples", "det"),
        ("timed_samples", "det"),
        ("samples_per_s_scalar", "rate"),
        ("samples_per_s_batched", "rate"),
        ("speedup", "ratio"),
    ),
    "learner": (
        ("steps_timed", "det"),
        ("steps_per_min", "rate"),
        ("ratio_vs_e2e", "ratio"),
    ),
}
DATAPLANE_GATE_BANDS = {"ingest_speedup": "ratio", "learner_steps_per_min": "rate"}


def check_dataplane(base: dict, fresh: dict, tol: float) -> list[str]:
    """Data-plane baselines: ingest + learner blocks (rates wide-banded,
    counts tight), strict gate booleans, and the hard wall budget."""
    problems: list[str] = []
    bands = {
        "det": tol,
        "rate": max(tol, KERNEL_RATE_TOL_FLOOR),
        "ratio": max(tol, KERNEL_WALL_TOL_FLOOR),
    }
    for block, metrics in DATAPLANE_METRICS.items():
        base_block = base.get(block)
        fresh_block = fresh.get(block)
        if not base_block:
            problems.append(f"MALFORMED baseline: no {block} block")
            continue
        if not fresh_block:
            problems.append(f"MISSING {block}: not in fresh results")
            continue
        for metric, band in metrics:
            name = f"{block}.{metric}"
            if metric not in base_block:
                continue
            if metric not in fresh_block:
                problems.append(f"MISSING {name}: not in fresh results")
                continue
            problems += compare_value(
                name, base_block[metric], fresh_block[metric], bands[band]
            )
    base_gate = base.get("gate", {})
    fresh_gate = fresh.get("gate", {})
    if not base_gate:
        problems.append("MALFORMED baseline: no gate block")
    for name, expected in base_gate.items():
        if name not in fresh_gate:
            problems.append(f"MISSING gate.{name}: not in fresh results")
            continue
        got = fresh_gate[name]
        if isinstance(expected, bool):
            if got != expected:
                problems.append(
                    f"REGRESSION gate.{name}: expected {expected}, got {got}"
                )
        else:
            band = bands[DATAPLANE_GATE_BANDS.get(name, "det")]
            problems += compare_value(f"gate.{name}", float(expected), float(got), band)
    budget = base.get("wall_budget_s")
    if budget is not None:
        wall = fresh.get("bench_wall_seconds")
        if wall is None:
            problems.append("MISSING bench_wall_seconds: not in fresh results")
        elif wall > budget:
            problems.append(
                f"REGRESSION bench_wall_seconds: {wall:.1f}s exceeds the "
                f"baseline wall budget {budget:.1f}s"
            )
    return problems


def check_recovery(base: dict, fresh: dict, tol: float) -> list[str]:
    """Recovery baselines: the gate block plus a curve sanity check."""
    problems: list[str] = []
    if not base.get("recovery_curve"):
        problems.append("MALFORMED baseline: empty recovery_curve")
    if base.get("recovery_curve") and not fresh.get("recovery_curve"):
        problems.append("MISSING recovery_curve: not in fresh results")
    problems += check_gate(base, fresh, tol)
    return problems


# multi-tenant scenario rows are all virtual-time deterministic per seed;
# wait p99 and throttle/drop counts are costs (a rise is the regression)
MULTITENANT_METRICS = (
    ("completed", False),
    ("throttled", True),
    ("dropped_at_stop", True),
    ("wait_p99_max_vs", True),
    ("virtual_makespan_s", False),
)


def check_multitenant(base: dict, fresh: dict, tol: float) -> list[str]:
    """Multi-tenant baselines: per-scenario fairness/SLO rows, the gate
    block (Jain index, per-tenant p99s, throttle counts), and the hard
    wall budget."""
    problems: list[str] = []
    base_rows = base.get("scenarios", [])
    if not base_rows:
        problems.append("MALFORMED baseline: no scenario rows")
    fresh_rows = {row["name"]: row for row in fresh.get("scenarios", [])}
    for row in base_rows:
        other = fresh_rows.get(row["name"])
        if other is None:
            problems.append(f"MISSING scenario[{row['name']}]: not in fresh results")
            continue
        for metric, lower_is_better in MULTITENANT_METRICS:
            if metric not in row:
                continue
            name = f"{metric}[{row['name']}]"
            if metric not in other:
                problems.append(f"MISSING {name}: not in fresh results")
                continue
            problems += compare_value(
                name, row[metric], other[metric], tol, lower_is_better=lower_is_better
            )
        if row.get("cross_tenant_leaks", 0) == 0 and other.get("cross_tenant_leaks"):
            problems.append(
                f"REGRESSION cross_tenant_leaks[{row['name']}]: "
                f"{other['cross_tenant_leaks']} episodes leaked across tenants"
            )
    budget = base.get("wall_budget_s")
    if budget is not None:
        wall = fresh.get("sweep_wall_seconds")
        if wall is None:
            problems.append("MISSING sweep_wall_seconds: not in fresh results")
        elif wall > budget:
            problems.append(
                f"REGRESSION sweep_wall_seconds: {wall:.1f}s exceeds the "
                f"baseline wall budget {budget:.1f}s"
            )
    problems += check_gate(base, fresh, tol)
    return problems


# federation region rows are all virtual-time deterministic per seed:
# homed/spilled episode counts and metered WAN bytes keep the tight band
# (spill volume and cross-region bytes are costs — a rise is the
# regression); per-region USD/day folds in the price sheet and makespan,
# and the USD metrics share the wide same-host ratio band so honest
# price-sheet tweaks upstream don't flap the gate.
FEDERATION_REGION_METRICS = (
    ("replicas", False, "det"),
    ("homed_tasks", False, "det"),
    ("spilled_out", True, "det"),
    ("wan_bytes_out", True, "det"),
    ("usd_per_day", True, "usd"),
)


def check_federation(base: dict, fresh: dict, tol: float) -> list[str]:
    """Federation baselines: per-region routing/WAN/price rows, the gate
    block (WAN byte totals and USD/traj are costs, DiLoCo reduction and
    outage-throughput fraction are higher-is-better), and the hard wall
    budget."""
    problems: list[str] = []
    usd_tol = max(tol, KERNEL_WALL_TOL_FLOOR)
    base_rows = base.get("regions", [])
    if not base_rows:
        problems.append("MALFORMED baseline: no region rows")
    fresh_rows = {row["name"]: row for row in fresh.get("regions", [])}
    for row in base_rows:
        other = fresh_rows.get(row["name"])
        if other is None:
            problems.append(f"MISSING region[{row['name']}]: not in fresh results")
            continue
        for metric, lower_is_better, band in FEDERATION_REGION_METRICS:
            if metric not in row:
                continue
            name = f"{metric}[{row['name']}]"
            if metric not in other:
                problems.append(f"MISSING {name}: not in fresh results")
                continue
            problems += compare_value(
                name,
                row[metric],
                other[metric],
                usd_tol if band == "usd" else tol,
                lower_is_better=lower_is_better,
            )
    base_gate = base.get("gate", {})
    fresh_gate = fresh.get("gate", {})
    if not base_gate:
        problems.append("MALFORMED baseline: no gate block")
    for name, expected in base_gate.items():
        if name not in fresh_gate:
            problems.append(f"MISSING gate.{name}: not in fresh results")
            continue
        got = fresh_gate[name]
        if isinstance(expected, bool):
            if got != expected:
                problems.append(
                    f"REGRESSION gate.{name}: expected {expected}, got {got}"
                )
        else:
            band = usd_tol if "usd" in name else tol
            problems += compare_value(
                f"gate.{name}",
                float(expected),
                float(got),
                band,
                lower_is_better=gate_metric_is_cost(name),
            )
    budget = base.get("wall_budget_s")
    if budget is not None:
        wall = fresh.get("wall_seconds")
        if wall is None:
            problems.append("MISSING wall_seconds: not in fresh results")
        elif wall > budget:
            problems.append(
                f"REGRESSION wall_seconds: {wall:.1f}s exceeds the "
                f"baseline wall budget {budget:.1f}s"
            )
    return problems


# mixed-fleet backend rows are virtual-time deterministic per seed:
# completion counts and traj/min keep the tight band; failure counts and
# detection latency are costs (a rise is the regression). The canary
# counters (injected / detected / quarantined) are seeded constants, so
# any drift at all is a broken gate.
MIXEDFLEET_METRICS = (
    ("completed", False),
    ("failed", True),
    ("traj_per_min", False),
    ("injected_silent", False),
    ("silent_detected", False),
    ("silent_quarantined", False),
    ("detection_p95_vs", True),
)


def check_mixedfleet(base: dict, fresh: dict, tol: float) -> list[str]:
    """Mixed-fleet baselines: per-backend serving/canary rows, strict
    gate booleans (routing isolation, full canary detection, zero
    post-quarantine corruption, learner loss decrease), the
    host-dependent learner rate on a wide band, and the hard wall
    budget."""
    problems: list[str] = []
    base_rows = base.get("backends", [])
    if not base_rows:
        problems.append("MALFORMED baseline: no backend rows")
    fresh_rows = {row["name"]: row for row in fresh.get("backends", [])}
    for row in base_rows:
        other = fresh_rows.get(row["name"])
        if other is None:
            problems.append(
                f"MISSING backend[{row['name']}]: not in fresh results")
            continue
        for metric, lower_is_better in MIXEDFLEET_METRICS:
            if metric not in row:
                continue
            name = f"{metric}[{row['name']}]"
            if metric not in other:
                problems.append(f"MISSING {name}: not in fresh results")
                continue
            problems += compare_value(
                name, row[metric], other[metric], tol,
                lower_is_better=lower_is_better,
            )
    base_lrn = base.get("learner", {})
    fresh_lrn = fresh.get("learner", {})
    if base_lrn:
        rate_tol = max(tol, KERNEL_RATE_TOL_FLOOR)
        if "updates" in base_lrn:
            if "updates" not in fresh_lrn:
                problems.append("MISSING learner.updates: not in fresh results")
            else:
                problems += compare_value(
                    "learner.updates", base_lrn["updates"],
                    fresh_lrn["updates"], tol)
        if "steps_per_min" in base_lrn and "steps_per_min" in fresh_lrn:
            problems += compare_value(
                "learner.steps_per_min", base_lrn["steps_per_min"],
                fresh_lrn["steps_per_min"], rate_tol)
    budget = base.get("wall_budget_s")
    if budget is not None:
        wall = fresh.get("wall_seconds")
        if wall is None:
            problems.append("MISSING wall_seconds: not in fresh results")
        elif wall > budget:
            problems.append(
                f"REGRESSION wall_seconds: {wall:.1f}s exceeds the "
                f"baseline wall budget {budget:.1f}s"
            )
    problems += check_gate(base, fresh, tol)
    return problems


def check_gate(base: dict, fresh: dict, tol: float) -> list[str]:
    problems: list[str] = []
    base_gate = base.get("gate", {})
    fresh_gate = fresh.get("gate", {})
    if not base_gate:
        return ["MALFORMED baseline: no gate block"]
    for name, expected in base_gate.items():
        if name not in fresh_gate:
            problems.append(f"MISSING gate.{name}: not in fresh results")
            continue
        got = fresh_gate[name]
        if isinstance(expected, bool):
            if got != expected:
                problems.append(
                    f"REGRESSION gate.{name}: expected {expected}, got {got}"
                )
        else:
            problems += compare_value(
                f"gate.{name}",
                float(expected),
                float(got),
                tol,
                lower_is_better=gate_metric_is_cost(name),
            )
    return problems


def check(baseline: dict, fresh: dict, tol: float) -> list[str]:
    # "kernel" before "engine": kernel baselines also carry engine-tier
    # rows (under "engine_sweep"), but the lane rows are the gated shape
    if "kernel" in baseline:
        return check_kernel(baseline, fresh, tol)
    if "engine" in baseline:
        return check_throughput(baseline, fresh, tol)
    if "clusters" in baseline:
        return check_elastic(baseline, fresh, tol)
    if "recovery_curve" in baseline:
        return check_recovery(baseline, fresh, tol)
    if "ingest" in baseline and "learner" in baseline:
        return check_dataplane(baseline, fresh, tol)
    if "scenarios" in baseline:
        return check_multitenant(baseline, fresh, tol)
    if "regions" in baseline:
        return check_federation(baseline, fresh, tol)
    if "backends" in baseline:
        return check_mixedfleet(baseline, fresh, tol)
    if "gate" in baseline:
        return check_e2e(baseline, fresh, tol)
    return ["MALFORMED baseline: neither engine rows nor a gate block"]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--fresh", required=True, help="freshly generated JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.10,
        help="allowed relative deviation per metric (default 0.10 = ±10%%)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    problems = check(baseline, fresh, args.tolerance)
    if problems:
        print(f"bench check FAILED against {args.baseline}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(
        f"bench check OK: {args.fresh} within ±{args.tolerance:.0%} "
        f"of {args.baseline}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
