"""§Perf hillclimb driver: run a named variant of a cell and diff it against
the baseline artifact.

    PYTHONPATH=src python scripts/hillclimb.py <arch> <shape> <mesh> <tag> \
        [--moe-ep] [--remat X] [--microbatches N] [--optimizer X]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell, ARTIFACTS  # noqa: E402 (sets XLA_FLAGS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("mesh", choices=["single", "multi"])
    ap.add_argument("tag")
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--no-tp", action="store_true",
                    help="train: pure-FSDP/ZeRO (batch over both axes, "
                         "no tensor parallelism)")
    ap.add_argument("--tp-only", action="store_true",
                    help="serve: weights resident on the model axis only "
                         "(no FSDP over data -> no weight gathers)")
    ap.add_argument("--cache-seq-tp", action="store_true",
                    help="serve: shard the KV cache over the model axis by "
                         "sequence (flash-decoding layout)")
    args = ap.parse_args()

    overrides = {}
    if args.no_tp or args.tp_only or args.cache_seq_tp:
        from repro.launch.mesh import make_production_mesh
        from repro.distributed.sharding import train_rules, serve_rules
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        if args.no_tp:
            dp = ("pod", "data", "model") if args.mesh == "multi" else                  ("data", "model")
            rules = train_rules(mesh).with_overrides(
                batch=dp, q_dim=(), kv_dim=(), heads=(), mlp=(),
                expert_mlp=(), ssm_inner=(), groups=("data", "model"))
        else:
            rules = serve_rules(
                mesh, long_context=(args.shape == "long_500k"))
            if args.tp_only:
                rules = rules.with_overrides(embed=(), frontend=(),
                                             lm_embed=())
            if args.cache_seq_tp:
                rules = rules.with_overrides(cache_seq=("model",))
        overrides["rules"] = rules
    if args.moe_ep:
        overrides["moe_ep"] = True
    if args.remat is not None:
        overrides["remat"] = None if args.remat == "none" else args.remat
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    if args.optimizer:
        overrides["optimizer"] = args.optimizer

    r = run_cell(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                 overrides=overrides, tag=args.tag)

    mesh_tag = "2_16_16" if args.mesh == "multi" else "16_16"
    base_fn = os.path.join(ARTIFACTS,
                           f"{args.arch}--{args.shape}--{mesh_tag}.json")
    if os.path.exists(base_fn):
        with open(base_fn) as f:
            base = json.load(f)
        b, v = base["roofline"], r["roofline"]
        print(f"\n{'term':<14}{'baseline':>12}{'variant':>12}{'delta':>9}")
        for k in ("compute_s", "memory_s", "collective_s"):
            d = (v[k] - b[k]) / max(b[k], 1e-12) * 100
            print(f"{k:<14}{b[k]*1e3:>10.1f}ms{v[k]*1e3:>10.1f}ms"
                  f"{d:>+8.1f}%")
        pb = base["memory"]["tpu_adjusted_peak_bytes"] / 1e9
        pv = r["memory"]["tpu_adjusted_peak_bytes"] / 1e9
        print(f"{'peak GB (adj)':<14}{pb:>12.2f}{pv:>12.2f}")
        print(f"{'useful flops':<14}{base['useful_flops_ratio']:>12.2f}"
              f"{r['useful_flops_ratio']:>12.2f}")


if __name__ == "__main__":
    main()
