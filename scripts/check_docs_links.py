"""Docs-link checker: fail CI on dead relative links in the doc suite.

Scans ``README.md`` and ``docs/*.md`` for Markdown links and bare
inline-code path references, resolves every *relative* link against the
containing file, and exits non-zero listing each target that does not
exist. External links (http/https/mailto) and pure in-page anchors are
skipped; a ``path#anchor`` link is checked for the path only.

    python scripts/check_docs_links.py

The doc files themselves cross-link heavily (README -> docs/*.md ->
benchmarks/ and src/), so a rename that strands a reader is caught at CI
time instead of by the reader.
"""

from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# [text](target) — excluding images' inner brackets is not needed since
# ![alt](target) still matches on the (target) part we care about
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files() -> list[str]:
    files = [os.path.join(ROOT, "README.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.isfile(f)]


def dead_links(path: str) -> list[str]:
    """Relative link targets in ``path`` that do not resolve to a file
    or directory in the repo."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(path)
    problems = []
    for target in LINK_RE.findall(text):
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            problems.append(target)
    return problems


def main() -> int:
    bad = 0
    files = doc_files()
    for path in files:
        for target in dead_links(path):
            print(f"DEAD LINK {os.path.relpath(path, ROOT)}: ({target})")
            bad += 1
    if bad:
        print(f"docs-link check FAILED: {bad} dead relative link(s)")
        return 1
    print(f"docs-link check OK: {len(files)} files, no dead relative links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
