"""Multi-layer fault recovery (§3.4): fault-injector determinism and
validation, deterministic reclaim tie-breaks, one test per ladder layer
(L0-L4), and the end-to-end canary contract — a silently-broken runner is
detected within one probe interval and never serves a trajectory after
quarantine."""
import pytest

from repro.core import (CowStore, DiskImage, FaultInjector, FaultType,
                        Gateway, RunnerPool, Telemetry)
from repro.core.event_loop import EventLoop, Sleep
from repro.core.replica import expected_observation
from repro.core.runner_pool import HostSpec, SimHost
from repro.recovery import MTTR_PREFIX, probe_runner
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)


def _base(store=None):
    store = store or CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", 64 << 20)


def _gateway(n_nodes=2, size=4, faults=None, base=None, telemetry=None,
             **kw):
    base = base or _base()
    pools = [RunnerPool(f"n{i}", base, size=size,
                        faults=faults[i] if faults else None, seed=i)
             for i in range(n_nodes)]
    return Gateway(pools, telemetry=telemetry, **kw), pools


# ------------------------------------------------- fault injector satellites
def test_fault_injector_scaled_is_cross_order_deterministic():
    """Child fault streams must not depend on when siblings are created
    or on the parent's own sampling — the old implementation drew child
    seeds from the parent RNG, so prewarm vs grow() orders diverged."""
    def stream(inj, n=50):
        return [inj.sample() for _ in range(n)]

    # order A: two children up front
    pa = FaultInjector(seed=7)
    a0, a1 = pa.scaled(1.0), pa.scaled(1.0)
    # order B: parent samples in between child creations
    pb = FaultInjector(seed=7)
    b0 = pb.scaled(1.0)
    interleaved = stream(pb, 30)
    b1 = pb.scaled(1.0)
    assert stream(a0) == stream(b0)
    assert stream(a1) == stream(b1)
    # and the parent's own stream is unperturbed by scaled() calls
    pc = FaultInjector(seed=7)
    assert interleaved == stream(pc, 30)


def test_fault_injector_rates_validation_boundary():
    FaultInjector(rates={FaultType.CRASH: 1.0})        # exactly 1.0: legal
    with pytest.raises(ValueError, match="sum"):
        FaultInjector(rates={FaultType.CRASH: 0.7,
                             FaultType.HANG: 0.4})
    with pytest.raises(ValueError, match="negative"):
        FaultInjector(rates={FaultType.CRASH: -0.1})
    # a large scaled() factor saturating the table is an explicit error
    # now, not a silent truncation of the tail faults
    parent = FaultInjector(rates={FaultType.CONNECTION: 0.3,
                                  FaultType.CRASH: 0.2})
    parent.scaled(2.0)                                 # sums to 1.0: legal
    with pytest.raises(ValueError, match="sum"):
        parent.scaled(3.0)


# ---------------------------------------------- deterministic reclaim ties
def test_release_exactly_at_deadline_loses_to_reclamation():
    """A release landing on the exact reclaim deadline must resolve
    deterministically: the reclaim timer (armed at acquire) carries the
    earlier sequence number, fires first, and the late release degrades
    to a stale no-op — the runner is issued to exactly one new task."""
    loop = EventLoop()
    pool = RunnerPool("n0", _base(), size=1, task_timeout_vs=20.0)
    pool.attach_loop(loop)
    trace = []

    def edge_case():
        r = yield from pool.acquire_ev("task-A")
        yield Sleep(20.0)               # wakes exactly at the deadline
        trace.append(("release", pool.release(r, task_id="task-A"),
                      pool.n_free))

    def waiter():
        r = yield from pool.acquire_ev("task-B")
        trace.append(("acquired", loop.now, r.task_id))
        pool.release(r, task_id="task-B")

    loop.spawn(edge_case())
    loop.spawn(waiter())
    loop.run()
    # reclamation won the tie: task-B got the runner at vt=20, and the
    # zombie release returned 0.0 without double-freeing
    assert ("acquired", 20.0, "task-B") in trace
    assert ("release", 0.0, 0) in trace or ("release", 0.0, 1) in trace
    assert pool.n_free == 1


def test_threaded_reclaim_at_exact_deadline():
    pool = RunnerPool("n1", _base(), size=1, task_timeout_vs=10.0)
    pool.acquire("leaky")
    pool.advance_time(10.0)             # exactly the timeout, not past it
    assert pool.reclaim_leaked() == ["leaky"]
    assert pool.n_free == 1


# --------------------------------------------------------- ladder layers
def test_l0_step_retry_mttr_observed():
    tele = Telemetry()
    retryable = {0: FaultInjector(rates={FaultType.CONNECTION: 0.5},
                                  seed=3)}
    gw, pools = _gateway(n_nodes=1, size=2, faults=retryable,
                         telemetry=tele)
    writer = TrajectoryWriter(capacity=16, retain=False)
    engine = RolloutEngine(gw, writer, telemetry=tele,
                           config=RolloutConfig(max_inflight=2))
    report = engine.run(get_default_registry().sample(4, seed=0))
    assert report.completed == 4
    l0 = tele.summary(MTTR_PREFIX + "l0")
    assert l0["n"] > 0 and l0["mean"] > 0    # retries charged as L0 repairs
    writer.close()
    gw.stop()


def test_l1_release_heal_through_ladder():
    tele = Telemetry()
    gw, pools = _gateway(n_nodes=1, size=2, telemetry=tele)
    node, r = gw.acquire("t1")
    r.manager.configure({"task_id": "t1", "horizon": 5})
    r.manager.replica.crash()
    dur = gw.release(node, r, task_id="t1")
    assert r.manager.replica.alive           # healed in place on release
    assert dur > 0
    assert tele.summary(MTTR_PREFIX + "l1")["n"] == 1
    gw.stop()


def test_l2_reclaimed_runner_is_rebooted_from_cow_base():
    tele = Telemetry()
    gw, pools = _gateway(n_nodes=1, size=1, telemetry=tele)
    pool = pools[0]
    pool.task_timeout_vs = 30.0
    loop = EventLoop()
    gw.attach_loop(loop, health_checks=False)
    clones_before = pool.base_image.store.reflink_clones

    def leaker():
        r = yield from pool.acquire_ev("wedged")
        r.manager.configure({"task_id": "wedged", "horizon": 5})
        yield Sleep(100.0)               # leaks far past the deadline

    def patient():
        r = yield from pool.acquire_ev("patient", timeout=500.0)
        assert r is not None
        # the reclaimed runner only served after its L2 reboot elapsed
        assert loop.now > 30.0
        pool.release(r, task_id="patient")

    loop.spawn(leaker())
    loop.spawn(patient())
    loop.run()
    gw.detach_loop()
    assert tele.summary(MTTR_PREFIX + "l2")["n"] >= 1
    # the reboot re-cloned the overlay from the shared CoW base
    assert pool.base_image.store.reflink_clones > clones_before
    assert all(r.manager.replica.alive for r in pool._all.values())
    gw.stop()


def test_l3_canary_detects_and_recreates_silent_runner():
    tele = Telemetry()
    gw, pools = _gateway(n_nodes=1, size=3, telemetry=tele)
    pool = pools[0]
    loop = EventLoop()
    gw.attach_loop(loop, health_checks=False)
    victim = next(iter(pool._all.values()))
    victim.mark_silent_broken(0.0)
    assert not probe_runner(victim).healthy
    report = pool.recovery.canary_sweep()
    assert report["detected"] == 1 and report["recreated"] == 1
    assert victim.runner_id in pool.recovery.quarantined_at
    assert victim.runner_id not in pool._all        # out of service forever
    assert tele.counter("runners_quarantined") == 1
    assert tele.summary(MTTR_PREFIX + "l3")["n"] == 1
    # replacement serves only after its boot latency elapses on the loop
    assert pool.size == 2
    loop.run()
    assert pool.size == 3
    assert all(not r.silent_broken for r in pool._all.values())
    gw.detach_loop()
    gw.stop()


def test_l4_exhausted_host_is_evicted():
    tele = Telemetry()
    host = SimHost(HostSpec(cores=96, ram_gb=768.0))
    base = _base()
    pools = [RunnerPool("sick", base, size=4, host=host, seed=0),
             RunnerPool("ok", base, size=4, seed=1)]
    gw = Gateway(pools, telemetry=tele)
    loop = EventLoop()
    gw.attach_loop(loop, health_checks=False)
    # exhaust the sick node's kernel limits and silently break its fleet
    for k in host.limits:
        host.limits[k] = 0
    for r in pools[0]._all.values():
        r.mark_silent_broken(0.0)
    report = pools[0].recovery.canary_sweep()
    assert report["evicted"] and pools[0].evicted
    assert tele.counter("nodes_evicted") == 1
    # bare gateway (no cluster): eviction stops routing to the node
    assert "sick" not in gw.healthy_nodes()
    assert "ok" in gw.healthy_nodes()
    # every broken runner the sweep saw is quarantined, none serve again
    assert all(rid in pools[0].recovery.quarantined_at
               for rid in [r.runner_id for r in pools[0].quarantined])
    # no VM leaks: quarantine frees the allocation even for born-broken
    # replacement runners that were never registered in the pool, and the
    # pool's quarantine list agrees with the ladder's timestamps
    assert host.vm_count == 0 and host.ram_used_gb == 4.0
    assert len(pools[0].quarantined) == len(pools[0].recovery.quarantined_at)
    gw.detach_loop()
    gw.stop()


def test_l4_cluster_evicts_and_replaces_capacity():
    from repro.cluster import Cluster, default_specs

    cluster = Cluster(default_specs(8, runners_per_node=4), 8,
                      runners_per_node=4, seed=0, faults=False)
    loop = EventLoop()
    cluster.attach_loop(loop)
    sick = cluster.hosts[0]
    assert sick.pool is not None
    node_id = sick.pool.node_id
    granted = cluster.evict_host(node_id)
    assert granted == 4                      # capacity replaced elsewhere
    assert sick.evicted and sick.pool is None and sick.placed == 0
    assert node_id not in cluster.gateway.pools
    assert cluster.telemetry.counter("cluster_nodes_evicted") == 1

    def clock_driver():          # boot timers are daemons: carry the
        yield Sleep(20.0)        # clock past the provisioning delay

    loop.spawn(clock_driver())
    loop.run()                               # replacement boot timers fire
    assert cluster.n_replicas == 8
    assert sick.headroom() == 0              # never schedulable again
    cluster.close()


def test_evicted_host_pending_grow_never_boots():
    """A boot-delayed grow reserved on a host that is evicted before the
    boot timer fires must be cancelled — not rebuild a pool on the
    exhausted node and re-add it to routing."""
    from repro.cluster import Cluster, default_specs

    cluster = Cluster(default_specs(8, runners_per_node=4), 8,
                      runners_per_node=4, seed=0, faults=False)
    loop = EventLoop()
    cluster.attach_loop(loop)
    sick = cluster.hosts[0]
    node_id = sick.pool.node_id
    granted = cluster.request_grow(4, delay_vs=10.0)   # lands on host0
    assert granted == 4 and cluster._pending_grows
    cluster.evict_host(node_id)
    # the pending grow for the evicted host is gone
    assert all(p[1] is not sick for p in cluster._pending_grows)

    def clock_driver():
        yield Sleep(40.0)

    loop.spawn(clock_driver())
    loop.run()
    assert sick.pool is None and sick.evicted
    # routing never sees a pool on the evicted host; capacity (the
    # original 8 + the pre-eviction grant of 4, minus nothing) lives
    # entirely on the surviving hosts
    assert node_id not in cluster.gateway.pools
    assert all(h is not sick or h.pool is None for h in cluster.hosts)
    assert cluster.n_replicas == 12
    cluster.close()


# ----------------------------------------------------- end-to-end contract
def test_silent_runner_detected_within_one_interval_and_never_serves_again():
    """The acceptance contract: a runner silently broken mid-run is
    canary-detected within one probe interval of first becoming
    observable (its next release), quarantined, and no corrupted
    trajectory is written after the quarantine instant."""
    tele = Telemetry()
    gw, pools = _gateway(n_nodes=2, size=4, telemetry=tele,
                         canary_interval_s=15.0)
    writer = TrajectoryWriter(capacity=64, retain=False)
    engine = RolloutEngine(gw, writer, telemetry=tele,
                           config=RolloutConfig(max_inflight=8,
                                                acquire_timeout_vs=600.0))
    tasks = get_default_registry().sample(48, seed=5)
    loop = EventLoop()
    broken = {}

    def inject():
        victim = next(iter(pools[0]._all.values()))
        victim.mark_silent_broken(loop.now)
        broken["id"] = victim.runner_id
        broken["at"] = loop.now

    loop.call_later(25.0, inject, daemon=True)
    report = engine.run_event_driven(tasks, loop=loop)
    assert report.completed == 48
    ladder = pools[0].recovery
    rid = broken["id"]
    # detected and quarantined...
    assert rid in ladder.detected_at and rid in ladder.quarantined_at
    # ...within one probe interval of the lease it was corrupting ending
    # (~ one episode), plus the interval itself as the sweep bound
    latency = ladder.detected_at[rid] - broken["at"]
    assert latency <= 15.0 + 60.0
    # corrupted trajectories exist (the in-flight episode at detection is
    # the honest cost) but none was written after the quarantine instant
    q_vt = ladder.quarantined_at[rid]
    for wrid, vt in report.corrupted_writes:
        assert wrid == rid
        assert vt <= q_vt + 1e-9
    # the quarantined runner is gone and the surviving fleet is clean —
    # nothing left in service can corrupt another trajectory
    assert rid not in pools[0]._all
    assert all(not r.manager.replica.silent_broken
               for p in pools for r in p._all.values())
    writer.close()
    gw.stop()


def test_canary_probe_known_answer_matches_healthy_replica():
    pool = RunnerPool("n0", _base(), size=1)
    r = next(iter(pool._all.values()))
    rep = r.manager.replica
    ok, cost = rep.canary_probe()
    assert ok and cost == rep.latency.canary_s
    import numpy as np
    want = expected_observation(rep.replica_id, rep.obs_nonce,
                                rep.step_count)
    assert np.array_equal(rep._observation(), want)
    rep.silent_broken = True
    assert not rep.canary_probe()[0]
    pool.close()
