"""The CI bench-regression gate (scripts/check_bench.py)."""
import copy
import importlib.util
import json
import os

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(_ROOT, "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cb = _load_check_bench()


def _baseline(name):
    path = os.path.join(_ROOT, "artifacts", "bench", name)
    with open(path) as f:
        return json.load(f)


def test_rel_dev_and_band():
    assert cb.rel_dev(100.0, 100.0) == 0.0
    assert cb.rel_dev(100.0, 85.0) == pytest.approx(-0.15)
    assert cb.rel_dev(0.0, 0.0) == 0.0
    assert cb.rel_dev(0.0, 1.0) == float("inf")
    assert cb.compare_value("m", 100.0, 95.0, 0.10) == []
    assert "REGRESSION" in cb.compare_value("m", 100.0, 85.0, 0.10)[0]
    assert "STALE" in cb.compare_value("m", 100.0, 115.0, 0.10)[0]


def test_committed_throughput_baseline_self_passes():
    base = _baseline("BENCH_throughput.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_throughput_minus_15_percent_fails():
    base = _baseline("BENCH_throughput.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["engine"]:
        row["traj_per_min"] *= 0.85
    problems = cb.check(base, perturbed, 0.10)
    assert problems, "a -15% regression must be caught at ±10% tolerance"
    assert all("REGRESSION" in p for p in problems)
    assert len(problems) == len(base["engine"])


def test_throughput_missing_row_fails():
    base = _baseline("BENCH_throughput.json")
    perturbed = copy.deepcopy(base)
    perturbed["engine"] = perturbed["engine"][1:]
    problems = cb.check(base, perturbed, 0.10)
    assert any("MISSING" in p for p in problems)


def test_committed_e2e_baseline_self_passes():
    base = _baseline("BENCH_e2e.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_e2e_minus_15_percent_fails():
    base = _baseline("BENCH_e2e.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["rollout_traj_per_min"] *= 0.85
    problems = cb.check(base, perturbed, 0.10)
    assert len(problems) == 1
    assert "REGRESSION" in problems[0]


def test_e2e_boolean_gate_must_hold():
    base = _baseline("BENCH_e2e.json")
    assert base["gate"]["loss_decreased"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["loss_decreased"] = False
    problems = cb.check(base, perturbed, 0.10)
    assert any("loss_decreased" in p for p in problems)


def test_stale_baseline_detected_on_improvement():
    base = _baseline("BENCH_e2e.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["rollout_traj_per_min"] *= 1.25
    problems = cb.check(base, perturbed, 0.10)
    assert any("STALE BASELINE" in p for p in problems)


def test_committed_elastic_baseline_self_passes():
    base = _baseline("BENCH_elastic.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_elastic_cluster_row_regression_fails():
    base = _baseline("BENCH_elastic.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["clusters"]:
        if row["name"] == "autoscaled":
            row["traj_per_min"] *= 0.85
    problems = cb.check(base, perturbed, 0.10)
    assert len(problems) == 1
    assert "REGRESSION" in problems[0]
    assert "autoscaled" in problems[0]


def test_elastic_replica_day_rise_is_a_regression():
    """replica-days is a cost: rising 30% is a REGRESSION (the autoscaler
    got lazier), not a stale baseline — labels are direction-aware."""
    base = _baseline("BENCH_elastic.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["clusters"]:
        if row["name"] == "autoscaled":
            row["replica_days"] *= 1.30
    problems = cb.check(base, perturbed, 0.10)
    assert any("REGRESSION" in p and "replica_days" in p for p in problems)
    # and an improvement (cost falls) flags the baseline as stale
    improved = copy.deepcopy(base)
    for row in improved["clusters"]:
        if row["name"] == "autoscaled":
            row["replica_days"] *= 0.70
    problems = cb.check(base, improved, 0.10)
    assert any("STALE BASELINE" in p and "replica_days" in p
               for p in problems)


def test_elastic_gate_boolean_and_missing_row():
    base = _baseline("BENCH_elastic.json")
    assert base["gate"]["autoscaled_meets_p95_bound"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["autoscaled_meets_p95_bound"] = False
    perturbed["clusters"] = [r for r in perturbed["clusters"]
                             if r["name"] != "overcommit"]
    problems = cb.check(base, perturbed, 0.10)
    assert any("autoscaled_meets_p95_bound" in p for p in problems)
    assert any("MISSING cluster[overcommit]" in p for p in problems)


def test_committed_recovery_baseline_self_passes():
    base = _baseline("BENCH_recovery.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_recovery_mttr_rise_is_a_regression():
    """MTTR and detection latency are costs — direction-aware labels:
    a 30% rise is a REGRESSION (slower repairs), a drop flags a stale
    baseline."""
    base = _baseline("BENCH_recovery.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["mttr_l3_mean_vs"] *= 1.30
    perturbed["gate"]["detection_p95_vs"] *= 1.30
    problems = cb.check(base, perturbed, 0.10)
    assert any("REGRESSION" in p and "mttr_l3_mean_vs" in p
               for p in problems)
    assert any("REGRESSION" in p and "detection_p95_vs" in p
               for p in problems)
    improved = copy.deepcopy(base)
    improved["gate"]["full_recovery_vs"] *= 0.70
    problems = cb.check(base, improved, 0.10)
    assert any("STALE BASELINE" in p and "full_recovery_vs" in p
               for p in problems)


def test_recovery_boolean_detection_gate_must_hold():
    base = _baseline("BENCH_recovery.json")
    assert base["gate"]["all_silent_detected"] is True
    assert base["gate"]["no_corrupt_after_quarantine"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["all_silent_detected"] = False
    perturbed["recovery_curve"] = []
    problems = cb.check(base, perturbed, 0.10)
    assert any("all_silent_detected" in p for p in problems)
    assert any("MISSING recovery_curve" in p for p in problems)


def test_committed_kernel_baseline_self_passes():
    base = _baseline("BENCH_kernel.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_kernel_parity_boolean_gate_must_hold():
    base = _baseline("BENCH_kernel.json")
    assert base["gate"]["lane_parity_bit_identical"] is True
    assert base["gate"]["engine_parity_bit_identical"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["lane_parity_bit_identical"] = False
    problems = cb.check(base, perturbed, 0.10)
    assert any("lane_parity_bit_identical" in p for p in problems)


def test_kernel_events_per_s_gets_the_wide_host_band():
    """Raw events/sec is a wall-clock rate: even a 60% dip (host speed +
    load) passes its very wide sanity band, a 90% collapse is still a
    REGRESSION — and the labels stay direction-aware (a 2x rise flags a
    stale baseline). The speedup ratio cancels host speed, so it keeps
    the tighter 50% band: a 30% dip passes, a 60% dip fails."""
    base = _baseline("BENCH_kernel.json")
    noisy = copy.deepcopy(base)
    for row in noisy["kernel"]:
        row["batched_events_per_s"] *= 0.40
        row["speedup"] *= 0.70
    assert cb.check(base, noisy, 0.10) == []
    collapsed = copy.deepcopy(base)
    for row in collapsed["kernel"]:
        row["batched_events_per_s"] *= 0.10
    problems = cb.check(base, collapsed, 0.10)
    assert problems and all(
        "REGRESSION" in p and "batched_events_per_s" in p for p in problems)
    improved = copy.deepcopy(base)
    for row in improved["kernel"]:
        row["batched_events_per_s"] *= 2.00
    problems = cb.check(base, improved, 0.10)
    assert any("STALE BASELINE" in p and "batched_events_per_s" in p
               for p in problems)
    slow_ratio = copy.deepcopy(base)
    for row in slow_ratio["kernel"]:
        row["speedup"] *= 0.40
    problems = cb.check(base, slow_ratio, 0.10)
    assert problems and all(
        "REGRESSION" in p and "speedup" in p for p in problems)


def test_kernel_deterministic_counts_keep_the_tight_band():
    base = _baseline("BENCH_kernel.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["kernel"]:
        row["events"] = int(row["events"] * 0.85)
    problems = cb.check(base, perturbed, 0.10)
    assert problems and all("events" in p for p in problems)


def test_kernel_wall_budget_is_a_hard_gate():
    base = _baseline("BENCH_kernel.json")
    over = copy.deepcopy(base)
    over["sweep_wall_seconds"] = base["wall_budget_s"] * 1.5
    problems = cb.check(base, over, 0.10)
    assert any("wall budget" in p for p in problems)
    missing = copy.deepcopy(base)
    del missing["sweep_wall_seconds"]
    problems = cb.check(base, missing, 0.10)
    assert any("MISSING sweep_wall_seconds" in p for p in problems)


def test_kernel_missing_lane_row_fails():
    base = _baseline("BENCH_kernel.json")
    perturbed = copy.deepcopy(base)
    perturbed["kernel"] = perturbed["kernel"][1:]
    problems = cb.check(base, perturbed, 0.10)
    assert any("MISSING kernel[" in p for p in problems)


def test_committed_dataplane_baseline_self_passes():
    base = _baseline("BENCH_dataplane.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_dataplane_parity_boolean_gates_must_hold():
    base = _baseline("BENCH_dataplane.json")
    assert base["gate"]["ingest_parity_bit_identical"] is True
    assert base["gate"]["ingest_speedup_ge_5x"] is True
    assert base["gate"]["learner_ge_2x_e2e"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["ingest_parity_bit_identical"] = False
    perturbed["gate"]["learner_ge_2x_e2e"] = False
    problems = cb.check(base, perturbed, 0.10)
    assert any("ingest_parity_bit_identical" in p for p in problems)
    assert any("learner_ge_2x_e2e" in p for p in problems)


def test_dataplane_rates_get_the_wide_host_band():
    """samples/sec and steps/min are wall-clock rates: a 60% dip (host
    speed) passes the wide band, a 90% collapse fails; the speedup ratio
    cancels host speed and keeps the tighter 50% band."""
    base = _baseline("BENCH_dataplane.json")
    noisy = copy.deepcopy(base)
    noisy["ingest"]["samples_per_s_batched"] *= 0.40
    noisy["learner"]["steps_per_min"] *= 0.40
    noisy["gate"]["learner_steps_per_min"] *= 0.40
    noisy["ingest"]["speedup"] *= 0.70
    noisy["gate"]["ingest_speedup"] *= 0.70
    assert cb.check(base, noisy, 0.10) == []
    collapsed = copy.deepcopy(base)
    collapsed["ingest"]["samples_per_s_batched"] *= 0.10
    problems = cb.check(base, collapsed, 0.10)
    assert problems and all("samples_per_s_batched" in p for p in problems)
    slow_ratio = copy.deepcopy(base)
    slow_ratio["ingest"]["speedup"] *= 0.40
    slow_ratio["gate"]["ingest_speedup"] *= 0.40
    problems = cb.check(base, slow_ratio, 0.10)
    assert problems and all(
        "REGRESSION" in p and "speedup" in p for p in problems)


def test_dataplane_deterministic_counts_keep_the_tight_band():
    base = _baseline("BENCH_dataplane.json")
    perturbed = copy.deepcopy(base)
    perturbed["ingest"]["parity_samples"] = int(
        base["ingest"]["parity_samples"] * 0.5)
    perturbed["gate"]["samples"] = int(base["gate"]["samples"] * 0.5)
    problems = cb.check(base, perturbed, 0.10)
    assert any("ingest.parity_samples" in p for p in problems)
    assert any("gate.samples" in p for p in problems)


def test_dataplane_wall_budget_and_missing_block():
    base = _baseline("BENCH_dataplane.json")
    over = copy.deepcopy(base)
    over["bench_wall_seconds"] = base["wall_budget_s"] * 1.5
    problems = cb.check(base, over, 0.10)
    assert any("wall budget" in p for p in problems)
    missing = copy.deepcopy(base)
    del missing["learner"]
    problems = cb.check(base, missing, 0.10)
    assert any("MISSING learner" in p for p in problems)


def test_committed_multitenant_baseline_self_passes():
    base = _baseline("BENCH_multitenant.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_multitenant_wait_p99_rise_is_a_regression():
    base = _baseline("BENCH_multitenant.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["scenarios"]:
        row["wait_p99_max_vs"] = row["wait_p99_max_vs"] * 1.5 + 10.0
    perturbed["gate"]["burst_quiet_wait_p99_vs"] = (
        base["gate"]["burst_quiet_wait_p99_vs"] * 1.5 + 10.0)
    problems = cb.check(base, perturbed, 0.10)
    assert problems
    # wait p99 is a cost: the rise must read REGRESSION, not STALE
    assert all("REGRESSION" in p for p in problems if "wait_p99" in p)
    assert any("burst_quiet_wait_p99_vs" in p for p in problems)


def test_multitenant_jain_drop_is_a_regression():
    base = _baseline("BENCH_multitenant.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["burst_jain_index"] = (
        base["gate"]["burst_jain_index"] * 0.7)
    problems = cb.check(base, perturbed, 0.10)
    assert any("REGRESSION" in p and "jain" in p for p in problems)


def test_multitenant_leakage_and_boolean_gate():
    base = _baseline("BENCH_multitenant.json")
    leaked = copy.deepcopy(base)
    leaked["scenarios"][0]["cross_tenant_leaks"] = 3
    leaked["gate"]["zero_cross_tenant_leakage"] = False
    problems = cb.check(base, leaked, 0.10)
    assert any("cross_tenant_leaks" in p for p in problems)
    assert any("zero_cross_tenant_leakage" in p for p in problems)


def test_multitenant_wall_budget_and_missing_scenario():
    base = _baseline("BENCH_multitenant.json")
    over = copy.deepcopy(base)
    over["sweep_wall_seconds"] = base["wall_budget_s"] * 1.5
    problems = cb.check(base, over, 0.10)
    assert any("wall budget" in p for p in problems)
    missing = copy.deepcopy(base)
    missing["scenarios"] = missing["scenarios"][1:]
    problems = cb.check(base, missing, 0.10)
    assert any("MISSING scenario" in p for p in problems)


def test_committed_federation_baseline_self_passes():
    base = _baseline("BENCH_federation.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_federation_wan_bytes_rise_is_a_regression():
    """WAN bytes are a cost — DiLoCo sync bytes rising 30% is a
    REGRESSION (the compression got lazier), a drop flags a stale
    baseline; same direction for the per-region metered totals."""
    base = _baseline("BENCH_federation.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["wan_bytes_diloco"] *= 1.30
    for row in perturbed["regions"]:
        row["wan_bytes_out"] *= 1.30
    problems = cb.check(base, perturbed, 0.10)
    assert any("REGRESSION" in p and "wan_bytes_diloco" in p
               for p in problems)
    assert any("REGRESSION" in p and "wan_bytes_out" in p for p in problems)
    improved = copy.deepcopy(base)
    improved["gate"]["wan_bytes_diloco"] *= 0.70
    problems = cb.check(base, improved, 0.10)
    assert any("STALE BASELINE" in p and "wan_bytes_diloco" in p
               for p in problems)


def test_federation_outage_throughput_drop_is_a_regression():
    base = _baseline("BENCH_federation.json")
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["outage_traj_per_min"] *= 0.80
    perturbed["gate"]["outage_throughput_frac"] *= 0.80
    problems = cb.check(base, perturbed, 0.10)
    assert any("REGRESSION" in p and "outage_traj_per_min" in p
               for p in problems)
    assert any("REGRESSION" in p and "outage_throughput_frac" in p
               for p in problems)


def test_federation_usd_gets_the_wide_band():
    """USD/traj folds in the price sheet: a 30% shift passes the wide
    band (honest sheet tweaks must not flap the gate), a 60% jump is
    still a REGRESSION — and the rise direction is the cost direction."""
    base = _baseline("BENCH_federation.json")
    noisy = copy.deepcopy(base)
    noisy["gate"]["spot_usd_per_traj"] *= 1.30
    for row in noisy["regions"]:
        row["usd_per_day"] *= 1.30
    assert cb.check(base, noisy, 0.10) == []
    jumped = copy.deepcopy(base)
    jumped["gate"]["spot_usd_per_traj"] *= 1.60
    problems = cb.check(base, jumped, 0.10)
    assert any("REGRESSION" in p and "spot_usd_per_traj" in p
               for p in problems)


def test_federation_boolean_gates_must_hold():
    base = _baseline("BENCH_federation.json")
    assert base["gate"]["outage_survived"] is True
    assert base["gate"]["bytes_accounting_exact"] is True
    assert base["gate"]["spot_cheaper"] is True
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["outage_survived"] = False
    perturbed["gate"]["bytes_accounting_exact"] = False
    problems = cb.check(base, perturbed, 0.10)
    assert any("outage_survived" in p for p in problems)
    assert any("bytes_accounting_exact" in p for p in problems)


def test_federation_wall_budget_and_missing_region():
    base = _baseline("BENCH_federation.json")
    over = copy.deepcopy(base)
    over["wall_seconds"] = base["wall_budget_s"] * 1.5
    problems = cb.check(base, over, 0.10)
    assert any("wall budget" in p for p in problems)
    missing = copy.deepcopy(base)
    missing["regions"] = missing["regions"][1:]
    problems = cb.check(base, missing, 0.10)
    assert any("MISSING region[" in p for p in problems)


def test_committed_mixedfleet_baseline_self_passes():
    base = _baseline("BENCH_mixedfleet.json")
    assert cb.check(base, copy.deepcopy(base), 0.10) == []


def test_mixedfleet_backend_row_regression_fails():
    base = _baseline("BENCH_mixedfleet.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["backends"]:
        if row["name"] == "swe":
            row["traj_per_min"] *= 0.85
    problems = cb.check(base, perturbed, 0.10)
    assert len(problems) == 1
    assert "REGRESSION" in problems[0] and "swe" in problems[0]


def test_mixedfleet_canary_and_routing_gates_are_strict():
    """The mixed-fleet booleans are the tentpole claims: every backend's
    canary detects its silent breaks, nothing corrupt lands after
    quarantine, and routing never crosses backends. Flipping any of them
    must fail regardless of tolerance; a single routing violation (0 ->
    1) is out of band at any tolerance because the baseline is zero."""
    base = _baseline("BENCH_mixedfleet.json")
    assert base["gate"]["all_silent_detected"] is True
    assert base["gate"]["no_corrupt_after_quarantine"] is True
    assert base["gate"]["routing_violations"] == 0
    perturbed = copy.deepcopy(base)
    perturbed["gate"]["all_silent_detected"] = False
    perturbed["gate"]["no_corrupt_after_quarantine"] = False
    perturbed["gate"]["routing_violations"] = 1
    problems = cb.check(base, perturbed, 0.50)
    assert any("all_silent_detected" in p for p in problems)
    assert any("no_corrupt_after_quarantine" in p for p in problems)
    assert any("routing_violations" in p for p in problems)


def test_mixedfleet_detection_latency_rise_is_a_regression():
    base = _baseline("BENCH_mixedfleet.json")
    perturbed = copy.deepcopy(base)
    for row in perturbed["backends"]:
        row["detection_p95_vs"] = row["detection_p95_vs"] * 1.5 + 10.0
    problems = cb.check(base, perturbed, 0.10)
    assert problems
    assert all("REGRESSION" in p for p in problems
               if "detection_p95_vs" in p)


def test_mixedfleet_learner_rate_gets_the_wide_band():
    """learner steps/min is wall-clock (host speed): a 40% dip passes
    the wide band, a 90% collapse fails; the deterministic update count
    keeps the tight band."""
    base = _baseline("BENCH_mixedfleet.json")
    noisy = copy.deepcopy(base)
    noisy["learner"]["steps_per_min"] *= 0.60
    assert cb.check(base, noisy, 0.10) == []
    collapsed = copy.deepcopy(base)
    collapsed["learner"]["steps_per_min"] *= 0.10
    problems = cb.check(base, collapsed, 0.10)
    assert any("learner.steps_per_min" in p for p in problems)
    fewer = copy.deepcopy(base)
    fewer["learner"]["updates"] = int(base["learner"]["updates"] * 0.5)
    problems = cb.check(base, fewer, 0.10)
    assert any("learner.updates" in p for p in problems)


def test_mixedfleet_wall_budget_and_missing_backend():
    base = _baseline("BENCH_mixedfleet.json")
    over = copy.deepcopy(base)
    over["wall_seconds"] = base["wall_budget_s"] * 1.5
    problems = cb.check(base, over, 0.10)
    assert any("wall budget" in p for p in problems)
    missing = copy.deepcopy(base)
    missing["backends"] = [r for r in missing["backends"]
                           if r["name"] != "mobile"]
    problems = cb.check(base, missing, 0.10)
    assert any("MISSING backend[mobile]" in p for p in problems)


def test_malformed_payloads_are_rejected():
    assert cb.check({}, {}, 0.10) == [
        "MALFORMED baseline: neither engine rows nor a gate block"
    ]
    assert "MALFORMED" in cb.check({"gate": {}}, {"gate": {}}, 0.10)[0]
    assert any("MALFORMED" in p
               for p in cb.check({"engine": []}, {"engine": []}, 0.10))
