"""Data pipeline (packing invariants), MoE routing properties, serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.configs import get_reduced
from repro.data import (ByteTokenizer, encode_trajectory, pack_batches,
                        synthetic_trajectories, ReplayBuffer)
from repro.models import build_model
from repro.models.moe import route, capacity
from repro.serve import ServeEngine, ServeConfig


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "click(120, 80) then type('héllo')"
    assert tok.decode(tok.encode(s)) == s


def test_encode_trajectory_mask_covers_thoughts_and_actions():
    tok = ByteTokenizer()
    traj = synthetic_trajectories(1, seed=0, steps_range=(3, 4))[0]
    ids, mask = encode_trajectory(traj, tok, vocab_size=264)
    assert len(ids) == len(mask)
    assert 0.2 < mask.mean() < 0.9          # both masked & unmasked content
    # instruction prefix is never a training target
    assert mask[:len(tok.encode(traj.instruction)) + 1].sum() == 0


def test_pack_batches_shapes_and_shift():
    tok = ByteTokenizer()
    trajs = synthetic_trajectories(8, seed=1, steps_range=(3, 5))
    enc = [encode_trajectory(t, tok, 264) for t in trajs]
    batches = list(pack_batches(enc, batch=2, seq_len=64, seed=0))
    assert batches, "must yield at least one packed batch"
    for b in batches:
        assert b["tokens"].shape == (2, 64)
        assert b["targets"].shape == (2, 64)
        assert b["mask"].shape == (2, 64)
    # next-token alignment: targets are tokens shifted by one in the stream
    stream = list(batches[0]["tokens"][0]) + [0]
    assert list(batches[0]["targets"][0][:-1]) == stream[1:64]


def test_replay_buffer_capacity_and_sampling():
    rb = ReplayBuffer(capacity=8, seed=0)
    rb.extend(range(20))
    assert len(rb) == 8
    assert rb.total_added == 20
    s = rb.sample(16)
    assert len(s) == 16 and all(12 <= x < 20 for x in s)


# ------------------------------------------------------------- MoE routing
@given(seed=st.integers(0, 100), E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_property_moe_capacity_never_exceeded(seed, E, k):
    g = 32
    C = capacity(g, k, E, 1.25)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, g, E))
    probs, gate_vals, de, dc = route(logits, E, k, C)
    # tokens per (expert, slot) <= 1 and per-expert load <= C
    disp = jnp.einsum("gtke,gtkc->gtec", de.astype(jnp.float32), dc)
    per_slot = disp.sum(axis=1)             # (1, E, C)
    assert float(per_slot.max()) <= 1.0 + 1e-6
    load = disp.sum(axis=(1, 3))            # (1, E)
    assert float(load.max()) <= C + 1e-6
    if k > 1:
        # top-k gates renormalize to a convex combination
        assert float(jnp.abs(gate_vals.sum(-1) - 1.0).max()) < 1e-5
    else:
        # top-1 keeps the raw router prob as the gate (Switch convention)
        assert 0.0 < float(gate_vals.min()) and float(gate_vals.max()) <= 1.0


def test_moe_dropped_tokens_contribute_zero():
    cfg = get_reduced("deepseek-moe-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits, aux = model.forward(params, tokens)   # must stay finite
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


# ----------------------------------------------------------------- serving
def test_serve_greedy_is_deterministic():
    cfg = get_reduced("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params)
    prompts = np.random.default_rng(0).integers(8, cfg.vocab_size, (2, 12))
    o1 = eng.generate(prompts, cfg=ServeConfig(max_new_tokens=6))
    o2 = eng.generate(prompts, cfg=ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(o1["sequences"], o2["sequences"])
    assert o1["sequences"].shape == (2, 18)


def test_serve_eos_early_stop():
    cfg = get_reduced("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params)
    prompts = np.random.default_rng(1).integers(8, cfg.vocab_size, (1, 8))
    greedy_first = eng.generate(prompts,
                                cfg=ServeConfig(max_new_tokens=1))
    eos = int(greedy_first["sequences"][0, -1])
    out = eng.generate(prompts, cfg=ServeConfig(max_new_tokens=10),
                       eos_id=eos)
    assert out["decode_steps"] <= 10
    assert (out["sequences"][:, 8:] == eos).any()
