"""Optimizers, microbatch accumulation invariants, SFT convergence, PPO."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.distributed.sharding import AxisRules
from repro.models import build_model
from repro.train.optimizer import (Optimizer, OptimizerConfig, schedule,
                                   clip_by_global_norm, global_norm)
from repro.train.train_step import TrainConfig, make_grad_fn
from repro.train.ppo import PPOTrainer, PPOConfig, compute_gae


def test_adamw_matches_reference_update():
    cfg = OptimizerConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, grad_clip=0.0,
                          warmup_steps=0, decay_steps=10**9, min_lr_frac=1.0)
    opt = Optimizer(cfg)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st_ = opt.init(p)
    p1, st1, _ = opt.update(g, st_, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    upd = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p["w"]) - 0.1 * upd, rtol=1e-5)


def test_grad_clip():
    t = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(t, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5, rel=1e-3)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_frac, rel=1e-2)


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizers_reduce_quadratic(name):
    opt = Optimizer(OptimizerConfig(name=name, lr=0.05, warmup_steps=0,
                                    decay_steps=10**9, min_lr_frac=1.0,
                                    grad_clip=0.0))
    p = {"w": jnp.array(np.random.default_rng(0).normal(size=(8, 4)),
                        jnp.float32)}
    s = opt.init(p)
    loss = lambda pp: jnp.sum(jnp.square(pp["w"]))
    l0 = float(loss(p))
    for _ in range(30):
        g = jax.grad(loss)(p)
        p, s, _ = opt.update(g, s, p)
    assert float(loss(p)) < 0.3 * l0


def test_adafactor_state_is_factored():
    opt = Optimizer(OptimizerConfig(name="adafactor"))
    p = {"w": jnp.zeros((64, 32))}
    s = opt.init(p)
    assert s["vr"]["w"].shape == (64,)
    assert s["vc"]["w"].shape == (32,)


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must be numerically equivalent (f32 accum)."""
    cfg = dataclasses.replace(get_reduced("qwen3-1.7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, S = 8, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    rules = AxisRules()
    g1 = make_grad_fn(model, rules, TrainConfig(microbatches=1, remat=None))
    g4 = make_grad_fn(model, rules, TrainConfig(microbatches=4, remat=None))
    l1, grads1 = g1(params, batch)
    l4, grads4 = g4(params, batch)
    assert abs(float(l1) - float(l4)) < 1e-4
    for a, b in zip(jax.tree.leaves(grads1), jax.tree.leaves(grads4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


# ------------------------------------------------------------------- PPO
def test_gae_matches_manual():
    r = np.array([0.0, 0.0, 1.0], np.float32)
    v = np.array([0.5, 0.5, 0.5], np.float32)
    adv, ret = compute_gae(r, v, gamma=1.0, lam=1.0)
    # with gamma=lam=1: adv[t] = sum(r[t:]) - v[t]
    np.testing.assert_allclose(adv, [0.5, 0.5, 0.5], rtol=1e-5)
    np.testing.assert_allclose(ret, [1.0, 1.0, 1.0], rtol=1e-5)


def test_ppo_update_runs_and_is_finite():
    cfg = get_reduced("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tr = PPOTrainer(model, params, cfg=PPOConfig(lr=1e-4))
    S = 16
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(4):
        samples.append({
            "tokens": rng.integers(0, cfg.vocab_size, S),
            "actions": rng.integers(0, cfg.vocab_size, S),
            "action_mask": (rng.random(S) < 0.5).astype(np.float32),
            "old_logp": -np.abs(rng.normal(size=S)).astype(np.float32),
            "rewards": rng.random(S).astype(np.float32),
            "values": rng.random(S).astype(np.float32),
        })
    batch = tr.make_batch(samples, S)
    metrics = tr.update(batch)
    assert np.isfinite(metrics["loss"])
    assert np.isfinite(metrics["entropy"])
