"""Config registry: advertised sizes, shape applicability, reduced configs."""
import pytest

from repro.configs import (get_config, get_reduced, list_arch_ids, SHAPES,
                           shape_applicable)

# advertised parameter counts (tolerance: 5%)
ADVERTISED = {
    "grok-1-314b": 314e9,
    "deepseek-moe-16b": 16.4e9,
    "nemotron-4-15b": 15e9,
    "h2o-danube-1.8b": 1.8e9,
    "qwen3-1.7b": 1.7e9,
    "starcoder2-15b": 15.5e9,   # hf reports 15.5B
    "llava-next-mistral-7b": 7.2e9,
    "mamba2-2.7b": 2.7e9,
    "jamba-1.5-large-398b": 398e9,
}


def test_all_archs_registered():
    assert len(list_arch_ids()) == 10


@pytest.mark.parametrize("arch", list(ADVERTISED))
def test_param_counts_match_advertised(arch):
    n = get_config(arch).param_count()
    assert abs(n - ADVERTISED[arch]) / ADVERTISED[arch] < 0.06, n


def test_moe_active_counts():
    grok = get_config("grok-1-314b")
    assert grok.active_param_count() < 0.3 * grok.param_count()
    ds = get_config("deepseek-moe-16b")
    assert 2e9 < ds.active_param_count() < 4e9


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in list_arch_ids()
            if shape_applicable(get_config(a), long)[0]]
    assert sorted(runs) == sorted(
        ["mamba2-2.7b", "jamba-1.5-large-398b", "h2o-danube-1.8b"])


def test_total_cells():
    """40 assigned cells: 33 runnable + 7 documented long-context skips."""
    n_run = n_skip = 0
    for a in list_arch_ids():
        for s in SHAPES.values():
            ok, why = shape_applicable(get_config(a), s)
            n_run += ok
            n_skip += not ok
            if not ok:
                assert "sub-quadratic" in why
    assert n_run + n_skip == 40 and n_skip == 7


@pytest.mark.parametrize("arch", list_arch_ids())
def test_reduced_configs_small(arch):
    r = get_reduced(arch)
    assert r.param_count() < 5e6
    assert r.family == get_config(arch).family
