"""Live cluster control plane: placement budgets, host accounting guards,
elastic pool lifecycle (grow/shrink, add/remove mid-run), live CPU
contention, least-loaded routing, the autoscaler, and replica-day
accounting."""
import pytest

from repro.cluster import (AutoscalerConfig, Cluster, Host, MachineSpec,
                           Placer, PlacementError, default_specs)
from repro.core.cow_store import CowStore, DiskImage
from repro.core.event_loop import EventLoop, Sleep
from repro.core.faults import FaultInjector
from repro.core.gateway import Gateway
from repro.core.runner_pool import RunnerPool, SimHost
from repro.core.seeding import stable_seed
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter


def _base(store=None):
    store = store or CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", 8 << 20)


def _pool(node_id, size=4, seed=0, base=None):
    return RunnerPool(node_id, base or _base(), size=size,
                      faults=FaultInjector(enabled=False), seed=seed)


# ------------------------------------------------------------- placement
def test_placer_binpacks_onto_hosts_in_order():
    store = CowStore(block_size=1 << 20)
    hosts = [Host(f"h{i}", MachineSpec(88, 768, "E5-2699"), store)
             for i in range(3)]
    plan = Placer(hosts).place(70, pool_size=32)
    assert [(p.host.host_id, p.n) for p in plan] == \
        [("h0", 32), ("h1", 32), ("h2", 6)]
    assert sum(h.placed for h in hosts) == 70


def test_placer_tops_up_beyond_pool_granularity_when_hosts_scarce():
    store = CowStore(block_size=1 << 20)
    host = Host("h0", MachineSpec(88, 768, "E5-2699"), store)  # cap 113
    plan = Placer([host]).place(64, pool_size=32)
    assert [(p.host.host_id, p.n) for p in plan] == [("h0", 64)]
    assert host.placed == 64


def test_placer_refuses_on_ram_exhaustion_and_rolls_back():
    store = CowStore(block_size=1 << 20)
    # 32 GB machine: 32*0.9 - 4 - 8 = 16.8 GB usable -> 2 replicas at 6 GB
    hosts = [Host("h0", MachineSpec(8, 32, "small-vm"), store)]
    assert hosts[0].replica_capacity() == 2
    with pytest.raises(PlacementError):
        Placer(hosts).place(3)
    assert hosts[0].placed == 0          # partial reservation rolled back
    assert len(Placer(hosts).place(2)) == 1


def test_placer_refuses_on_cow_disk_exhaustion():
    store = CowStore(block_size=1 << 20)
    # 1 GiB disk budget / 64 MiB worst-case CoW footprint -> 16 replicas
    spec = MachineSpec(88, 768, "E5-2699", disk_gb=1)
    hosts = [Host("h0", spec, store)]
    assert hosts[0].replica_capacity() == 16
    with pytest.raises(PlacementError):
        Placer(hosts).place(17)
    assert Placer(hosts).place(16)[0].n == 16


# --------------------------------------------------- host accounting guard
def test_simhost_free_vm_overfree_is_clamped():
    h = SimHost()
    baseline = h.ram_used_gb
    h.free_vm(6.0)                       # free with nothing allocated
    assert h.ram_used_gb == baseline     # no drift below the OS baseline
    assert all(v >= 0 for v in h.used.values())

    h.allocate_vm(6.0)
    assert h.ram_used_gb == baseline + 6.0
    h.free_vm(6.0)
    h.free_vm(6.0)                       # double free of the same VM
    assert h.ram_used_gb == baseline
    assert h.vm_count == 0
    assert all(v == 0 for v in h.used.values())


def test_simhost_free_vm_clamps_oversized_release():
    h = SimHost()
    baseline = h.ram_used_gb
    h.allocate_vm(6.0)
    h.allocate_vm(6.0)
    h.free_vm(100.0)                     # buggy caller frees too much RAM
    assert h.ram_used_gb >= baseline     # clamped to what was allocated
    h.free_vm(100.0)
    assert h.ram_used_gb == baseline


# ------------------------------------------------------- pool grow/shrink
def test_pool_grow_adds_fresh_runners():
    pool = _pool("n0", size=2)
    assert pool.grow(3) == 3
    assert pool.size == 5 and pool.n_free == 5
    assert len({r.runner_id for r in pool._all.values()}) == 5
    pool.close()


def test_pool_shrink_never_reclaims_leased_runner():
    pool = _pool("n0", size=4)
    vms_before = pool.host.vm_count
    leased = pool.acquire("t1", timeout=0.1)
    assert leased is not None
    retired = pool.shrink(10)            # ask for far more than is free
    assert retired == 3                  # only the free runners went
    assert pool.size == 1
    assert leased.runner_id in pool._all
    assert leased.busy                   # the lease is untouched
    assert pool.host.vm_count == vms_before - 3
    # the leased runner still works and returns to the (smaller) pool
    pool.release(leased, task_id="t1")
    assert pool.n_free == 1
    pool.close()


def test_pool_shrink_then_grow_issues_unique_ids():
    pool = _pool("n0", size=3)
    pool.shrink(2)
    pool.grow(2)
    assert len(pool._all) == 3
    assert len({r.runner_id for r in pool._all.values()}) == 3
    pool.close()


# ------------------------------------------- dynamic pools on a live loop
def test_add_pool_mid_run_serves_parked_acquires():
    base = _base()
    gw = Gateway([_pool("n0", size=2, base=base)])
    writer = TrajectoryWriter(retain=False)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(
        max_inflight=64, acquire_timeout_vs=2000.0))
    loop = EventLoop()
    extra = _pool("n1", size=8, seed=1, base=base)
    # 16 episodes over 2 runners saturate the fleet; the new node arrives
    # mid-run while many acquires are parked on the release condition
    loop.call_later(30.0, lambda: gw.add_pool(extra), daemon=True)
    tasks = get_default_registry().sample(16, seed=0)
    report = engine.run_event_driven(tasks, loop=loop)
    writer.close()
    assert report.completed == 16
    served = {n for r in report.results for n in r.nodes}
    assert served == {"n0", "n1"}        # the live-attached pool served
    with pytest.raises(ValueError):
        gw.add_pool(extra)               # duplicate node ids refused


def test_remove_pool_mid_run_retires_leased_runners():
    base = _base()
    gw = Gateway([_pool("n0", size=4, base=base),
                  _pool("n1", size=4, seed=1, base=base)])
    writer = TrajectoryWriter(retain=False)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(
        max_inflight=64, acquire_timeout_vs=2000.0))
    loop = EventLoop()
    removed = {}
    def pull():
        pool = gw.remove_pool("n0")      # mid-run: leases are in flight
        removed["busy"] = pool.n_busy
    loop.call_later(20.0, pull, daemon=True)
    tasks = get_default_registry().sample(24, seed=0)
    report = engine.run_event_driven(tasks, loop=loop)
    writer.close()
    assert report.completed == 24        # nothing lost in the removal
    assert removed["busy"] > 0           # the pool really was leased out
    assert list(gw.pools) == ["n1"]
    assert not gw._retired               # every lease found its way home


# ------------------------------------------------------------ contention
def test_overcommitted_host_inflates_latency_live():
    reg = get_default_registry()

    def traj_per_min(cores):
        cl = Cluster([MachineSpec(cores, 768, "E5-2699")], 32, seed=0)
        writer = TrajectoryWriter(retain=False, capacity=512)
        engine = RolloutEngine(cl, writer, registry=reg,
                               config=RolloutConfig(max_inflight=32))
        report = engine.run_event_driven(reg.sample(48, seed=7),
                                         loop=EventLoop())
        writer.close()
        cl.close()
        assert report.completed == 48
        return report.trajectories_per_min(32)

    provisioned = traj_per_min(88)       # 32 replicas need ~17 cores
    starved = traj_per_min(8)            # ~2.1x overcommitted
    assert starved < 0.65 * provisioned, (
        f"CPU overcommit should visibly degrade throughput: "
        f"{starved:.1f} vs {provisioned:.1f} traj/min")


def test_contention_factor_mean_field():
    store = CowStore(block_size=1 << 20)
    host = Host("h0", MachineSpec(8, 768, "E5-2699"), store)
    cl = Cluster([MachineSpec(8, 768, "E5-2699")], 32, seed=0)
    h = cl.hosts[0]
    assert h.contention_factor() == 1.0  # idle fleet: idle demand < 8 cores
    for r in list(h.pool._all.values())[:16]:
        r.busy = True
        h.pool._free.remove(r)
    # 32 idle * 0.1 + 16 stepping * 2.0 * 0.2 + 0.5 = 10.1 cores on 8
    assert h.contention_factor() == pytest.approx(10.1 / 8)
    cl.close()
    assert host.contention_factor() == 1.0   # pool-less host is neutral


# -------------------------------------------------------------- routing
def test_least_loaded_routing_routes_around_busy_node():
    base = _base()
    gw = Gateway([_pool("n0", size=4, base=base),
                  _pool("n1", size=4, seed=1, base=base)],
                 routing="least_loaded")
    task = next(t for t in (f"t{i}" for i in range(100))
                if gw._affinity_order(t)[0] == "n0")
    # idle fleet: load ties, the hash ring breaks the tie -> affinity node
    node, r = gw.acquire(task)
    assert node == "n0"
    # keep n0 half-busy: routing now prefers the idle n1 despite affinity
    gw.pools["n0"].acquire_nowait("occupier")
    node2, r2 = gw.acquire(task)
    assert node2 == "n1"
    gw.stop()


def test_affinity_routing_unchanged_by_default():
    gw = Gateway([_pool("n0"), _pool("n1", seed=1)])
    assert gw.routing == "affinity"
    for t in ("a", "b", "c"):
        assert gw._route_order(t) == gw._affinity_order(t)


# ------------------------------------------------------------ autoscaler
def test_autoscaler_grows_on_burst_and_drains_after():
    reg = get_default_registry()
    cl = Cluster(default_specs(64), 8, seed=0,
                 autoscaler=AutoscalerConfig(min_replicas=8,
                                             max_replicas=64,
                                             grow_step=16))
    writer = TrajectoryWriter(retain=False, capacity=1024)
    engine = RolloutEngine(cl, writer, registry=reg,
                           config=RolloutConfig(max_inflight=512,
                                                acquire_timeout_vs=2000.0))
    # hard burst at t=0, then 400 quiet virtual seconds for the drain
    tasks = reg.sample(96, seed=3)
    arrivals = [float(i) * 0.25 for i in range(95)] + [500.0]
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals)
    writer.close()
    auto = cl.autoscaler
    assert report.completed == 96
    assert auto.scale_ups > 0, "the burst must force growth"
    assert auto.scale_downs > 0, "the quiet tail must drain the fleet"
    assert cl.peak_placed > 8            # actually grew
    assert cl.placed_replicas < cl.peak_placed   # actually drained
    assert cl.placed_replicas >= 8       # never below the floor
    # elasticity saved replica-days vs static-at-peak provisioning
    static_days = cl.peak_placed * report.virtual_makespan / 86400.0
    assert cl.replica_days() < 0.9 * static_days
    cl.close()


def test_autoscaler_blocked_by_exhausted_budgets():
    cl = Cluster([MachineSpec(88, 768, "E5-2699", disk_gb=1)], 16, seed=0,
                 autoscaler=AutoscalerConfig(min_replicas=8,
                                             max_replicas=64))
    # host capacity is 16 by disk budget; any growth must be refused
    assert cl.request_grow(8) == 0
    cl.close()


# --------------------------------------------------- cluster bookkeeping
def test_replica_day_integral_tracks_capacity_changes():
    cl = Cluster(default_specs(64), 16, seed=0)
    loop = EventLoop()
    cl.attach_loop(loop)
    loop.call_later(100.0, lambda: cl.request_grow(16))

    def idle():
        yield Sleep(200.0)

    loop.spawn(idle())
    loop.run()
    cl.detach_loop()
    # 16 replicas for 100 vs, then 32 for the remaining 100 vs
    assert cl.replica_seconds() == pytest.approx(16 * 100 + 32 * 100)
    assert cl.peak_placed == 32
    cl.close()


def test_cluster_prices_from_table1_model():
    cl = Cluster(default_specs(113, runners_per_node=113), 113,
                 runners_per_node=113, seed=0)
    # one E5-2699 at full packing: the paper's 0.2-0.3 USD/replica-day
    assert 0.2 <= cl.usd_per_replica_day() <= 0.3
    health = cl.health()
    assert health["replicas_live"] == 113
    assert health["hosts"][0]["contention"] == 1.0
    cl.close()


def test_build_fleet_returns_live_cluster():
    from repro.pipeline import build_fleet

    cluster = build_fleet(8, seed=0)
    assert isinstance(cluster, Cluster)
    assert cluster.n_replicas == 8
    assert cluster.gateway.routing == "least_loaded"
    # node naming/seeding matches the old static build_fleet exactly
    assert [p.node_id for p in cluster.pools] == ["node0"]
    reg = get_default_registry()
    writer = TrajectoryWriter(retain=False)
    engine = RolloutEngine(cluster, writer, registry=reg)
    report = engine.run_event_driven(reg.sample(8, seed=0),
                                     loop=EventLoop())
    writer.close()
    assert report.completed == 8
    cluster.close()


def test_cluster_run_deterministic_per_seed():
    reg = get_default_registry()

    def run():
        cl = Cluster(default_specs(32), 16, seed=0,
                     autoscaler=AutoscalerConfig(min_replicas=8,
                                                 max_replicas=32,
                                                 grow_step=8))
        writer = TrajectoryWriter(retain=False, capacity=512)
        engine = RolloutEngine(cl, writer, registry=reg,
                               config=RolloutConfig(
                                   max_inflight=256,
                                   acquire_timeout_vs=2000.0))
        tasks = reg.sample(48, seed=stable_seed(0, "det"))
        arrivals = [float(i) * 0.5 for i in range(48)]
        report = engine.run_event_driven(tasks, loop=EventLoop(),
                                         arrivals=arrivals)
        writer.close()
        out = (report.completed, report.virtual_makespan,
               report.virtual_seconds, cl.replica_seconds(),
               cl.autoscaler.scale_ups, cl.autoscaler.scale_downs)
        cl.close()
        return out

    assert run() == run()