"""Checkpointing (dedup, eviction, elastic restore) + fault-tolerant loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.collectives import (make_error_feedback_compressor,
                                           quantize_int8, dequantize_int8)
from repro.distributed.diloco import (DiLoCoConfig, init_outer_state,
                                      outer_sync, cross_pod_bytes_per_cycle)
from repro.distributed.fault_tolerance import (FaultToleranceConfig,
                                               ResilientTrainLoop,
                                               straggler_stats)
from repro.distributed.sharding import AxisRules
from repro.models import build_model
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_step


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (64, 32), jnp.float32),
            "b": {"c": jax.random.normal(k, (16,), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip_exact():
    ckpt = CheckpointManager(keep=3)
    t = _tree()
    ckpt.save(1, t)
    r = ckpt.restore(1, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_dedup_unchanged_leaves():
    ckpt = CheckpointManager(keep=5)
    t = _tree()
    ckpt.save(1, t)
    s2 = ckpt.save(2, t)                       # identical -> zero new bytes
    assert s2["new_physical_bytes"] == 0
    t2 = dict(t)
    t2["a"] = t["a"] + 1.0                     # one leaf changes
    s3 = ckpt.save(3, t2)
    assert 0 < s3["new_physical_bytes"] <= 64 * 32 * 4 + 4096


def test_checkpoint_eviction_keeps_latest():
    ckpt = CheckpointManager(keep=2)
    t = _tree()
    for step in (1, 2, 3, 4):
        ckpt.save(step, jax.tree.map(lambda x: x + step, t))
    assert ckpt.latest_step() == 4
    r = ckpt.restore(4, t)
    np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(t["a"]) + 4)
    with pytest.raises(KeyError):
        ckpt.restore(1, t)


def test_resilient_loop_survives_preemptions():
    cfg = get_reduced("qwen3-1.7b")
    model = build_model(cfg)
    opt = Optimizer(OptimizerConfig(lr=1e-3, warmup_steps=1))
    step_fn = jax.jit(make_train_step(model, opt, AxisRules(),
                                      TrainConfig(remat=None)))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(1)
    batches = [{
        "tokens": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
        "mask": jnp.ones((2, 16), jnp.float32),
    } for k in jax.random.split(key, 12)]

    kills = {4, 9}
    loop = ResilientTrainLoop(
        step_fn, CheckpointManager(keep=2),
        FaultToleranceConfig(checkpoint_every=3),
        preempt_hook=lambda s: s in kills and not kills.discard(s))
    p, o, info = loop.run(params, opt_state, batches)
    assert info["failures"] == 2
    assert info["final_step"] == 12
    assert loop.lost_steps > 0                 # re-executed work was counted


# ---------------------------------------------------------- compression
def test_int8_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (5000,)) * 3.0
    q, s, pad = quantize_int8(x)
    y = dequantize_int8(q, s, pad, x.shape, jnp.float32)
    err = np.abs(np.asarray(y - x))
    bound = np.asarray(s).max() * 0.5 + 1e-6
    assert err.max() <= bound


def test_error_feedback_preserves_sum():
    """EF invariant: transmitted + residual == accumulated true gradient."""
    init, compress = make_error_feedback_compressor()
    rng = jax.random.split(jax.random.PRNGKey(1), 10)
    g_total = jnp.zeros((512,))
    sent_total = jnp.zeros((512,))
    ef = init({"g": g_total})
    for k in rng:
        g = jax.random.normal(k, (512,))
        g_total = g_total + g
        sent, ef = compress({"g": g}, ef)
        sent_total = sent_total + sent["g"]
    np.testing.assert_allclose(np.asarray(sent_total + ef["g"]),
                               np.asarray(g_total), rtol=1e-4, atol=1e-4)


def test_diloco_outer_sync_moves_toward_inner_params():
    params = {"w": jnp.ones((32,)) * 2.0}
    outer = init_outer_state({"w": jnp.ones((32,))})  # anchor at 1.0
    cfg = DiLoCoConfig(outer_lr=1.0, outer_momentum=0.0, compress_int8=False)
    new_params, outer2 = outer_sync(params, outer, cfg)
    # delta = anchor - params = -1; anchor' = anchor - lr*delta = 2.0
    np.testing.assert_allclose(np.asarray(new_params["w"]), 2.0, rtol=1e-5)


def test_diloco_collective_savings_accounting():
    acc = cross_pod_bytes_per_cycle(int(1e9), DiLoCoConfig(inner_steps=50))
    assert acc["reduction_x"] == pytest.approx(200.0)  # 50 steps * 4x bytes


def test_straggler_reclaim_bounds_batch_latency():
    stats = straggler_stats([1.0, 1.2, 30.0], deadline=5.0)
    assert stats["stragglers"] == 1
    assert stats["batch_latency_with_reclaim"] == 5.0
    assert stats["batch_latency_without"] == 30.0
