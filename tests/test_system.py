"""End-to-end behaviour of the whole system: the paper's pipeline (datagen
through the data server -> SFT -> RL) at smoke scale, plus the dry-run path
on reduced configs (spawned as a subprocess so the 512-device XLA flag never
leaks into this process)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (CowStore, DiskImage, DataServer, FaultInjector,
                        Gateway, RunnerPool)
from repro.core.tasks import TaskSuite
from repro.configs import get_reduced
from repro.data import (ByteTokenizer, Trajectory, TrajectoryStep,
                        encode_trajectory, pack_batches)
from repro.models import build_model
from repro.train.sft import SFTTrainer
from repro.serve import ServeEngine, ServeConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def collect_trajectories(n_tasks=6, seed=0):
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    pools = [RunnerPool(f"n{i}", base, size=4,
                        faults=FaultInjector(seed=seed + i), seed=i)
             for i in range(2)]
    ds = DataServer(Gateway(pools), max_workers=8)
    tasks = [t.to_dict() for t in TaskSuite(seed=seed).sample(n_tasks)]
    ds.reset(tasks)
    trajs = {s: [] for s in ds.live_slots()}
    actions = ["click(10,20)", "type('x')", "scroll(-1)"]
    for it in range(30):
        live = ds.live_slots()
        if not live:
            break
        res = ds.step({s: actions[it % 3] for s in live})
        for s, (obs, rew, done, info) in res.items():
            trajs[s].append(TrajectoryStep(obs, f"thought {it}",
                                           actions[it % 3]))
    scores = ds.evaluate()
    out = [Trajectory(f"t{s}", f"task {s}", steps, scores.get(s, 0.0))
           for s, steps in trajs.items() if steps]
    ds.close()
    return out


def test_end_to_end_datagen_sft_serve():
    """The paper's §4.2 pipeline at smoke scale."""
    trajs = collect_trajectories()
    assert len(trajs) >= 4
    cfg = get_reduced("qwen3-1.7b")
    tok = ByteTokenizer()
    enc = [encode_trajectory(t, tok, cfg.vocab_size) for t in trajs]
    batches = list(pack_batches(enc, batch=2, seq_len=48))
    assert batches
    model = build_model(cfg)
    trainer = SFTTrainer(model, seed=0)
    res = trainer.fit(batches[:25], verbose=False)
    assert res.steps > 5
    assert res.final_loss < res.losses[0]          # it learns
    eng = ServeEngine(model, trainer.params)
    out = eng.generate(np.asarray(batches[0]["tokens"][:1, :16]),
                       cfg=ServeConfig(max_new_tokens=4))
    assert out["sequences"].shape == (1, 20)


@pytest.mark.slow
@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure: jax.sharding.AxisType API drift under "
           "the forced multi-device mesh (see CI notes); kept running so the "
           "report shows when the drift is fixed")
def test_dryrun_reduced_subprocess():
    """The dry-run path itself (512 fake devices) on a reduced config."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-1.7b",
         "--shape", "decode_32k", "--mesh", "single", "--reduced",
         "--no-save"],
        capture_output=True, text=True, timeout=540, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "dry-run complete" in proc.stdout
