"""Hardware-aware orchestration (Fig. 3, Table 1) and the Fig. 6 fleet sims."""
import pytest

from repro.core.orchestrator import (table1, fig3_sweep,
                                     overload_fraction, ReplicaDemand)
from repro.core.simulation import (sweep_throughput,
                                   run_recovery, recovery_stats)


def test_table1_reproduces_paper_costs():
    rows = {r["cpu"]: r for r in table1()}
    assert rows["8275CL"]["replicas"] == 36
    assert rows["8275CL"]["usd_per_replica_day"] == pytest.approx(2.10, abs=0.02)
    assert rows["8259CL"]["usd_per_replica_day"] == pytest.approx(0.78, abs=0.02)
    assert rows["E5-2699"]["usd_per_replica_day"] == pytest.approx(0.23, abs=0.02)
    assert rows["E5-2699"]["replicas"] == 128


def test_fig3_cpu_to_ram_crossover():
    rows = fig3_sweep(128, seeds=3)
    by_k = {r["K"]: r for r in rows}
    assert by_k[1]["overload_frac_mean"] > 0.9       # small K: CPU-bound
    assert by_k[64]["overload_frac_mean"] < 0.05     # large K: bursts multiplex
    assert by_k[1]["bottleneck"] == "cpu"
    assert by_k[64]["bottleneck"] == "ram"
    # cost collapses roughly 10x (paper: ~300 -> ~30 USD/day)
    assert by_k[1]["usd_per_day"] > 250
    assert by_k[64]["usd_per_day"] < 40


def test_overload_monotone_in_cores():
    d = ReplicaDemand()
    lo = overload_fraction(8, 8.0, d)
    hi = overload_fraction(8, 64.0, d)
    assert lo > hi


def test_overload_fraction_deterministic_across_processes():
    """The Monte Carlo is blake2b-seeded from its parameters (not the
    process-randomized global RNG), so Fig. 3 / Table 1 artifacts are
    bit-identical in every process: the pinned values below must hold
    in any interpreter, on any platform."""
    d = ReplicaDemand()
    assert overload_fraction(8, 16.0, d) == overload_fraction(8, 16.0, d)
    assert overload_fraction(8, 16.0, d) == 0.29625
    assert overload_fraction(4, 8.0, d) == 0.55125
    # distinct parameters draw distinct streams
    assert overload_fraction(8, 16.0, d, trials=201) != 0.29625


def test_fig6_throughput_scaling():
    rows = sweep_throughput(designs=("centralized", "decentralized"),
                            sizes=(64, 1024), seeds=3)
    get = lambda d, n: next(r for r in rows
                            if r["design"] == d and r["replicas"] == n)
    dec64, dec1024 = get("decentralized", 64), get("decentralized", 1024)
    cen1024 = get("centralized", 1024)
    # near-linear decentralized scaling (>=85% of ideal 16x)
    assert dec1024["steps_per_s_mean"] / dec64["steps_per_s_mean"] > 13.5
    # centralized saturates at 1024 replicas
    assert cen1024["steps_per_s_mean"] < 0.5 * dec1024["steps_per_s_mean"]
    # decentralized latency stays near the 2.5 s step time
    assert dec1024["latency_mean_s"] < 3.0


def test_fig6_recovery_from_full_crash():
    r = run_recovery(256, seed=0)
    assert r["timeline"][0][1] == 0.0
    assert r["timeline"][-1][1] == 1.0
    assert r["full_recovery_s"] < 300
    stats = recovery_stats(256, seeds=3)
    assert stats["full_recovery_std_s"] >= 0.0
