"""Dedicated coverage for core/telemetry.py and core/cow_store.py."""
import threading

import pytest

from repro.core.cow_store import BlobStore, CowStore, DiskImage
from repro.core.telemetry import Telemetry


# ---------------------------------------------------------------- telemetry
def test_counters_accumulate_and_default_to_zero():
    tel = Telemetry()
    assert tel.counter("missing") == 0
    tel.count("episodes")
    tel.count("episodes", 4)
    assert tel.counter("episodes") == 5


def test_series_summary_percentiles():
    tel = Telemetry()
    for v in range(1, 101):                 # 1..100
        tel.observe("latency", float(v))
    s = tel.summary("latency")
    assert s["n"] == 100
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == 95.0                 # sorted[int(0.95 * 99)]
    assert s["max"] == 100.0
    assert tel.summary("nothing") == {"n": 0}
    assert tel.series("latency")[:3] == [1.0, 2.0, 3.0]


def test_gauges_last_write_wins():
    tel = Telemetry()
    assert tel.gauge_value("depth", -1.0) == -1.0
    tel.gauge("depth", 3.0)
    tel.gauge("depth", 7.0)
    assert tel.gauge_value("depth") == 7.0
    assert tel.snapshot()["gauges"]["depth"] == 7.0


def test_timer_observes_wall_seconds():
    tel = Telemetry()
    with tel.timer("block_s"):
        pass
    s = tel.summary("block_s")
    assert s["n"] == 1
    assert 0.0 <= s["max"] < 5.0


def test_snapshot_is_a_consistent_copy():
    tel = Telemetry()
    tel.count("a")
    tel.observe("x", 1.0)
    snap = tel.snapshot()
    tel.count("a")
    tel.observe("x", 2.0)
    assert snap["counters"]["a"] == 1
    assert snap["series"]["x"]["n"] == 1


def test_thread_safety_exact_totals():
    tel = Telemetry()
    n_threads, per_thread = 8, 2000

    def worker(k):
        for i in range(per_thread):
            tel.count("hits")
            tel.observe("vals", float(i))
            tel.gauge("last", float(k))

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tel.counter("hits") == n_threads * per_thread
    assert tel.summary("vals")["n"] == n_threads * per_thread
    assert tel.gauge_value("last") in {float(k) for k in range(n_threads)}


def test_snapshot_while_writing_does_not_crash():
    tel = Telemetry()
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            tel.observe("s", float(i))
            tel.count("c")
            i += 1

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(200):
            snap = tel.snapshot()
            assert snap["counters"].get("c", 0) >= snap["series"].get(
                "s", {"n": 0})["n"] - 1 or True
    finally:
        stop.set()
        t.join()


# ---------------------------------------------------------------- cow store
def test_virtual_block_refcounts():
    store = CowStore(block_size=4)
    store.put_virtual("b0")
    store.put_virtual("b0")                 # refcount 2, one allocation
    assert store.n_blocks() == 1
    assert store.physical_bytes() == 4
    store.release("b0")
    assert store.n_blocks() == 1            # still referenced
    store.release("b0")
    assert store.n_blocks() == 0


def test_double_free_is_a_safe_noop():
    store = CowStore(block_size=4)
    store.put_virtual("b0")
    store.release("b0")
    # the block is gone; releasing again must not throw or go negative
    store.release("b0")
    store.release("never-existed")
    assert store.n_blocks() == 0
    # the id is reusable afterwards with a fresh refcount
    store.put_virtual("b0")
    assert store.n_blocks() == 1
    store.release("b0")
    assert store.n_blocks() == 0


def test_clone_of_clone_shares_blocks():
    store = CowStore(block_size=1 << 10)
    base = DiskImage.create_base(store, "base", 4 << 10)     # 4 blocks
    assert store.physical_bytes() == 4 << 10
    c1, secs1 = base.clone("c1")
    c2, secs2 = c1.clone("c2")
    assert secs1 == secs2 == store.reflink_latency_s
    # three images, one physical copy
    assert store.physical_bytes() == 4 << 10
    assert c2.blocks == base.blocks


def test_clone_chain_survives_ancestor_close():
    store = CowStore(block_size=1 << 10)
    base = DiskImage.create_base(store, "base", 2 << 10)
    c1, _ = base.clone("c1")
    c2, _ = c1.clone("c2")
    base.close()
    c1.close()
    # grandchild still holds every block
    assert store.physical_bytes() == 2 << 10
    c2.close()
    assert store.physical_bytes() == 0
    assert store.n_blocks() == 0


def test_write_block_diverges_only_the_writer():
    store = CowStore(block_size=1 << 10)
    base = DiskImage.create_base(store, "base", 2 << 10)
    clone, _ = base.clone("clone")
    clone.write_block(0, "edit")
    assert clone.blocks[0] != base.blocks[0]
    assert clone.blocks[1] == base.blocks[1]
    # one extra physical block for the divergent write
    assert store.physical_bytes() == 3 << 10
    clone.close()
    base.close()
    assert store.physical_bytes() == 0


def test_image_double_close_is_idempotent():
    store = CowStore(block_size=1 << 10)
    base = DiskImage.create_base(store, "base", 2 << 10)
    clone, _ = base.clone("c")
    clone.close()
    clone.close()                           # second close must not re-release
    assert store.physical_bytes() == 2 << 10
    base.close()
    assert store.physical_bytes() == 0


def test_blob_store_dedup_and_overwrite():
    blob = BlobStore(chunk=8)
    data = b"abcdefgh" * 4                  # 4 identical chunks
    blob.put("ckpt", data)
    assert blob.get("ckpt") == data
    assert blob.store.physical_bytes() == 8   # deduplicated to one chunk
    blob.put("ckpt", b"ABCDEFGH" * 4)       # overwrite releases old chunks
    assert blob.get("ckpt") == b"ABCDEFGH" * 4
    assert blob.store.physical_bytes() == 8
    blob.delete("ckpt")
    assert blob.store.physical_bytes() == 0
    blob.delete("ckpt")                     # double delete is a no-op
    assert blob.keys() == []


def test_blob_store_shared_chunks_across_keys():
    blob = BlobStore(chunk=8)
    blob.put("a", b"xxxxxxxx" + b"yyyyyyyy")
    blob.put("b", b"xxxxxxxx" + b"zzzzzzzz")
    assert blob.store.physical_bytes() == 24  # x-chunk shared
    blob.delete("a")
    # b still reads correctly through the shared chunk
    assert blob.get("b") == b"xxxxxxxx" + b"zzzzzzzz"
    assert blob.store.physical_bytes() == 16
    blob.delete("b")
    assert blob.store.physical_bytes() == 0
