"""Online actor/learner pipeline: rewards, versions, staleness, e2e loop."""
import threading
import time

import numpy as np
import pytest

from repro.core.telemetry import Telemetry
from repro.data.pipeline import Trajectory, TrajectoryStep
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer
from repro.pipeline import (IngestConfig, LearnerConfig, LearnerLoop,
                            OnlinePipeline, PipelineConfig,
                            PolicyVersionStore, TrajectoryIngestor,
                            build_fleet, encode_for_rl)
from repro.rollout.scenarios import RewardSpec, get_default_registry


# ------------------------------------------------------------- reward shaping
def test_reward_spec_success_and_efficiency_bonus():
    spec = RewardSpec(success_threshold=0.5, success_bonus=1.0,
                      efficiency_bonus=0.5, step_penalty=0.01)
    assert spec.success(0.5) and spec.success(0.9)
    assert not spec.success(0.49)
    # finishing in half the horizon earns half the efficiency bonus
    full = spec.terminal_reward(0.8, n_steps=10, horizon=20)
    slow = spec.terminal_reward(0.8, n_steps=20, horizon=20)
    assert full == pytest.approx(1.0 + 0.5 * 0.5)
    assert slow == pytest.approx(1.0)
    # failures get partial credit only
    assert spec.terminal_reward(0.4, 10, 20) == pytest.approx(0.25 * 0.4)


def test_reward_spec_step_rewards_dense():
    spec = RewardSpec(step_penalty=0.02)
    r = spec.step_rewards(0.9, n_steps=5, horizon=10)
    assert r.shape == (5,)
    assert np.allclose(r[:-1], -0.02)
    assert r[-1] == pytest.approx(spec.terminal_reward(0.9, 5, 10) - 0.02)
    assert spec.episode_return(0.9, 5, 10) == pytest.approx(float(r.sum()))


def test_registry_has_per_family_reward_shaping():
    reg = get_default_registry()
    specs = {s.family: s.reward for s in reg}
    assert len({id(s) for s in specs.values()}) > 1, \
        "families should not all share one RewardSpec"
    # terminal steps are cheap, browser steps are expensive
    assert specs["terminal"].step_penalty < specs["browser"].step_penalty
    task = reg.tasks_for("terminal_os", 1)[0].to_dict()
    assert reg.reward_for(task) is specs["terminal"]
    assert reg.is_success(task, 0.99)
    assert not reg.is_success(task, 0.0)
    shaped = reg.shape_rewards(task, 0.8, n_steps=4)
    assert shaped.shape == (4,)


# ------------------------------------------------------------- version store
def test_policy_version_store_publish_and_staleness():
    store = PolicyVersionStore({"w": 0})
    assert store.version == 0
    v1 = store.publish({"w": 1})
    v2 = store.publish({"w": 2})
    assert (v1, v2) == (1, 2)
    version, params = store.current()
    assert version == 2 and params == {"w": 2}
    assert store.staleness(0) == 2
    assert store.staleness(2) == 0
    assert store.staleness(5) == 0          # future versions clamp to 0
    assert store.publishes == 2


def test_policy_version_store_concurrent_publishes():
    store = PolicyVersionStore(None)

    def publisher(k):
        for i in range(50):
            store.publish((k, i))

    threads = [threading.Thread(target=publisher, args=(k,))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.version == 200
    assert store.publishes == 200


# ------------------------------------------------------------------- encoding
def _trajectory(n_steps=3, score=0.9, task=None):
    rng = np.random.default_rng(0)
    steps = [TrajectoryStep(rng.integers(0, 255, (8, 8, 3), np.uint8),
                            f"thought {i}", f"click({i}, {i})")
             for i in range(n_steps)]
    return Trajectory("terminal_os-0", "configure the system", steps,
                      score, task=task)


def test_encode_for_rl_step_ends_are_action_tokens():
    tok = ByteTokenizer()
    traj = _trajectory(n_steps=4)
    ids, mask, step_ends = encode_for_rl(traj, tok, 264, obs_tokens=4)
    assert len(ids) == len(mask)
    assert len(step_ends) == 4
    for e in step_ends:
        assert mask[e] == 1.0, "step end must be a trainable action token"
    assert step_ends == sorted(step_ends)
    assert step_ends[-1] == len(ids) - 2    # only EOS after the last action


def test_ingestor_shapes_rewards_and_stamps_version():
    reg = get_default_registry()
    task = reg.tasks_for("terminal_os", 1)[0].to_dict()
    traj = _trajectory(n_steps=3, score=0.95, task=task)
    replay = ReplayBuffer(capacity=16)
    store = PolicyVersionStore(None)
    store.publish(None)                      # version 1
    tel = Telemetry()
    ingest = TrajectoryIngestor(replay, store, registry=reg,
                                cfg=IngestConfig(seq_len=256),
                                telemetry=tel)
    ingest(traj)
    assert len(replay) == 1
    s = replay.sample(1)[0]
    assert s["version"] == 1
    assert s["success"] is True
    assert s["family"] == "terminal"
    assert s["tokens"].shape == s["rewards"].shape
    # nothing truncated -> total credited reward equals the episode return
    assert float(s["rewards"].sum()) == pytest.approx(s["episode_return"])
    spec = reg.reward_for(task)
    expect = spec.episode_return(0.95, 3, int(task["horizon"]))
    assert s["episode_return"] == pytest.approx(expect)
    assert tel.counter("ingested") == 1
    assert tel.counter("ingest_success") == 1


def test_ingestor_truncation_preserves_terminal_reward():
    reg = get_default_registry()
    task = reg.tasks_for("terminal_os", 1)[0].to_dict()
    traj = _trajectory(n_steps=6, score=0.95, task=task)
    replay = ReplayBuffer(capacity=4)
    ingest = TrajectoryIngestor(replay, PolicyVersionStore(None),
                                registry=reg, cfg=IngestConfig(seq_len=64))
    ingest(traj)
    s = replay.sample(1)[0]
    assert len(s["tokens"]) == 64
    # truncated steps pile their rewards onto the final kept position
    assert float(s["rewards"].sum()) == pytest.approx(s["episode_return"])


# ---------------------------------------------------------- learner staleness
class _FakePPOTrainer:
    """Records batches; stands in for PPOTrainer in staleness unit tests."""

    def __init__(self):
        self.params = {"step": 0}
        self.batches = []

    def make_batch(self, samples, seq_len):
        return {"advantages": np.ones((len(samples), seq_len), np.float32)}

    def update(self, batch):
        self.batches.append(batch)
        self.params = {"step": self.params["step"] + 1}
        return {"loss": 1.0 / (len(self.batches) + 1)}


def _sample(version, n=8):
    return {"version": version, "ingest_wall": time.monotonic(),
            "success": True,
            "tokens_full": np.arange(20, dtype=np.int32),
            "loss_mask_full": np.ones(20, np.float32)}


def test_learner_reweights_stale_advantages():
    replay = ReplayBuffer(capacity=32)
    store = PolicyVersionStore(None)
    for _ in range(8):
        replay.add(_sample(version=0))
    for _ in range(3):
        store.publish(None)                  # current version: 3
    tel = Telemetry()
    loop = LearnerLoop(_FakePPOTrainer(), replay, store,
                       cfg=LearnerConfig(algo="ppo", batch_size=4,
                                         staleness_bound=1,
                                         staleness_policy="reweight",
                                         staleness_decay=0.5),
                       telemetry=tel)
    metrics = loop.step()
    assert metrics is not None
    batch = loop.trainer.batches[-1]
    # staleness 3, bound 1 -> excess 2 -> weight 0.5**2
    assert np.allclose(batch["advantages"], 0.25)
    assert tel.counter("stale_reweighted") >= 4
    assert metrics["version"] == 4           # update published a new version


def test_learner_drops_stale_samples_and_starves():
    replay = ReplayBuffer(capacity=32)
    store = PolicyVersionStore(None)
    for _ in range(8):
        replay.add(_sample(version=0))
    for _ in range(5):
        store.publish(None)
    tel = Telemetry()
    loop = LearnerLoop(_FakePPOTrainer(), replay, store,
                       cfg=LearnerConfig(algo="ppo", batch_size=4,
                                         staleness_bound=2,
                                         staleness_policy="drop"),
                       telemetry=tel)
    assert loop.step() is None               # everything beyond the bound
    assert len(replay) == 0                  # evicted, not left to rot
    assert tel.counter("stale_dropped") == 8
    assert tel.counter("learner_starved") == 1
    assert replay.total_pruned == 8


def test_learner_fresh_samples_pass_unweighted():
    replay = ReplayBuffer(capacity=32)
    store = PolicyVersionStore(None)
    for _ in range(8):
        replay.add(_sample(version=0))
    loop = LearnerLoop(_FakePPOTrainer(), replay, store,
                       cfg=LearnerConfig(algo="ppo", batch_size=4,
                                         staleness_bound=4))
    metrics = loop.step()
    assert metrics is not None
    assert np.allclose(loop.trainer.batches[-1]["advantages"], 1.0)


# ------------------------------------------------------------ virtual pacing
def test_engine_virtual_deadline_paces_launches():
    from repro.core.event_loop import EventLoop
    from repro.rollout.engine import RolloutConfig, RolloutEngine
    from repro.rollout.writer import TrajectoryWriter

    reg = get_default_registry()
    cluster = build_fleet(4, seed=0)
    writer = TrajectoryWriter(retain=False)
    engine = RolloutEngine(cluster, writer, registry=reg,
                           config=RolloutConfig(
                               max_inflight=4, virtual_deadline_s=60.0))
    tasks = reg.sample(64, seed=0)
    report = engine.run_event_driven(tasks, loop=EventLoop())
    writer.close()
    cluster.close()
    settled = report.completed + report.failed
    assert 0 < settled < 64, (
        f"deadline should stop launches mid-workload, settled {settled}")


# ----------------------------------------------------------------- end to end
@pytest.mark.slow
def test_online_pipeline_interleaved_ppo_end_to_end():
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train.ppo import PPOConfig, PPOTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trainer = PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4))
    cluster = build_fleet(8, seed=0)
    pipe = OnlinePipeline(
        cluster, 8, trainer,
        pipe_cfg=PipelineConfig(rounds=2, tasks_per_round=8,
                                updates_per_round=2, max_inflight=8),
        learner_cfg=LearnerConfig(algo="ppo", batch_size=4, seq_len=96,
                                  staleness_bound=2),
        ingest_cfg=IngestConfig(seq_len=96))
    try:
        report = pipe.run_interleaved()
    finally:
        pipe.close()
        cluster.close()
    assert report.rollout_completed > 0
    assert report.updates == 4
    assert report.versions_published == 4
    assert len(report.losses) == 4
    assert all(np.isfinite(report.losses))
    assert report.rollout_to_learner_s["n"] > 0
    assert report.rollout_traj_per_min > 0
    # round 1's experience is consumed after round 0's updates -> staleness
    assert report.staleness["n"] > 0
