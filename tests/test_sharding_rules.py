"""The logical-axis rule engine: divisibility fallback, duplicate-axis drop,
and hypothesis invariants (these run unbound — no mesh required)."""
from hypothesis import given, strategies as st

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import pspec_for


class FakeMesh:
    """Duck-typed mesh: only axis_names / devices.shape are consulted."""

    class _Dev:
        def __init__(self, shape):
            self.shape = shape

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = self._Dev(tuple(sizes.values()))


MESH = FakeMesh({"data": 16, "model": 16})
MAP = {"batch": ("data",), "embed": ("data",), "mlp": ("model",),
       "wide": ("data", "model")}


def test_basic_assignment():
    assert pspec_for((64, 32), ("embed", "mlp"), MAP, MESH) == \
        P("data", "model")


def test_divisibility_fallback_replicates():
    assert pspec_for((10, 32), ("embed", "mlp"), MAP, MESH) == \
        P(None, "model")


def test_duplicate_axis_first_dim_wins():
    assert pspec_for((32, 32), ("embed", "embed"), MAP, MESH) == P("data")


def test_multi_axis_mapping_degrades():
    # 256 divisible by 16*16 -> both axes; 32 only by 16 -> first axis only
    assert pspec_for((256,), ("wide",), MAP, MESH) == P(("data", "model"))
    assert pspec_for((32,), ("wide",), MAP, MESH) == P("data")


def test_unknown_logical_name_replicates():
    assert pspec_for((32,), ("nope",), MAP, MESH) == P()


@given(dims=st.lists(st.sampled_from([1, 3, 16, 32, 48, 256]), min_size=1,
                     max_size=4),
       names=st.lists(st.sampled_from(["batch", "embed", "mlp", "wide",
                                       None]), min_size=4, max_size=4))
def test_property_no_axis_reuse_and_divisibility(dims, names):
    spec = pspec_for(dims, names[:len(dims)], MAP, MESH)
    used = []
    sizes = {"data": 16, "model": 16}
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a not in used, "mesh axis assigned twice"
            used.append(a)
            prod *= sizes[a]
        assert dim % prod == 0, "non-divisible sharding emitted"
