"""Per-architecture smoke tests (assignment requirement): a reduced config of
the same family runs one forward + one train step on CPU, asserting output
shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_arch_ids
from repro.models import build_model
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_step
from repro.distributed.sharding import AxisRules


def _batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    frames = None
    if cfg.frontend != "none":
        frames = jax.random.normal(key, (B, 8, cfg.frontend_dim),
                                   jnp.bfloat16)
        if cfg.family != "encdec":
            tokens = tokens[:, :S - 8]
    return {
        "tokens": tokens, "frames": frames,
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", list_arch_ids())
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits, aux = model.forward(params, b["tokens"], b["frames"])
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_arch_ids())
def test_one_train_step_no_nans(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    opt = Optimizer(OptimizerConfig(lr=1e-3, warmup_steps=1))
    step = jax.jit(make_train_step(model, opt, AxisRules(),
                                   TrainConfig(remat=None)))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    b = _batch(cfg)
    params2, opt_state2, metrics = step(params, opt_state, b)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(c, np.float32))
        for a, c in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "jamba-1.5-large-398b",
                                  "mamba2-2.7b", "deepseek-moe-16b"])
def test_remat_matches_no_remat(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    l1 = model.loss(params, b, remat=None)
    l2 = model.loss(params, b, remat="full")
    assert abs(float(l1) - float(l2)) < 1e-4
