"""EnvBackend protocol conformance + SimOS-extraction bit-exactness.

Every registered backend must honor the same contract the control plane
assumes: the SimOS lifecycle ordering, the known-answer canary (salted
per backend, so a cross-wired probe cannot pass by accident), resource
accounting the placer can bin-pack, and per-family reward defaults that
raise on unknown families. The extraction itself is gated twice: a
replica built by ``SimOSBackend`` must be *bit-identical* to a directly
constructed ``SimOSReplica`` (same durations, same observation bytes,
same fault stream), and a full engine run over explicitly-backended
pools must replay bit-for-bit against the pre-protocol default path on
both event kernels."""
import numpy as np
import pytest

from repro.cluster import Cluster, default_specs
from repro.cluster.host import DEFAULT_FOOTPRINT, ReplicaFootprint
from repro.core import (CowStore, DiskImage, EventLoop, FaultInjector,
                        Gateway, RunnerPool)
from repro.core.faults import ReplicaError
from repro.core.replica import SimOSReplica, expected_observation
from repro.core.runner_pool import HOST_OS_BASELINE_GB
from repro.envs import (EnvBackend, RewardSpec, SimOSBackend,
                        UnknownBackendError, UnknownFamilyError,
                        backend_names, expected_backend_observation,
                        get_backend, register_backend)
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)
from repro.rollout.scenarios import mixed_registry

BUILTIN_BACKENDS = ("simos", "swe", "browser", "mobile")
# conformance parametrizes over the live registry: a newly registered
# backend is picked up by the protocol suite automatically
ALL_BACKENDS = tuple(backend_names())
KERNELS = ("scalar", "batched")


def _base(size=8 << 20):
    store = CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", size)


def _task():
    return get_default_registry().sample(1, seed=3)[0].to_dict()


# ----------------------------------------------------------------- registry
def test_registry_serves_all_four_backends():
    assert set(BUILTIN_BACKENDS) <= set(backend_names())
    for name in BUILTIN_BACKENDS:
        b = get_backend(name)
        assert b.name == name
        assert b is get_backend(name), "registry must return one instance"
        assert b.description
    with pytest.raises(UnknownBackendError, match="no EnvBackend"):
        get_backend("vr-headset")


def test_duplicate_registration_of_a_distinct_instance_raises():
    # idempotent for the same instance...
    b = get_backend("simos")
    assert register_backend(b) is b
    # ...but a second, distinct object under a taken name is a wiring bug
    with pytest.raises(ValueError, match="already registered"):
        register_backend(SimOSBackend())


# ---------------------------------------------------- lifecycle conformance
@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_lifecycle_ordering_is_enforced(name):
    backend = get_backend(name)
    rep = backend.make_replica(
        f"{name}/r0", _base(), faults=FaultInjector(enabled=False), seed=1)
    # operating on a cold (never-booted) replica is a crash, not a no-op
    with pytest.raises(ReplicaError):
        rep.step("click")
    with pytest.raises(ReplicaError):
        rep.configure(_task())
    rep.boot()
    with pytest.raises(AssertionError, match="configure before reset"):
        rep.reset()
    rep.configure(_task())
    obs, dur = rep.reset()
    assert obs.dtype == np.uint8 and dur > 0.0
    for action in ("open", "type", "submit"):
        obs, reward, done, info, dur = rep.step(action)
        assert obs.dtype == np.uint8 and dur > 0.0
    score, _ = rep.evaluate()
    assert 0.0 <= score <= 1.0
    rep.close()
    with pytest.raises(ReplicaError):
        rep.step("after close")


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_canary_known_answer_contract(name):
    backend = get_backend(name)
    rep = backend.make_replica(
        f"{name}/r0", _base(), faults=FaultInjector(enabled=False), seed=2)
    rep.boot()
    rep.configure(_task())
    obs, _ = rep.reset()
    # the healthy observation IS the backend's known answer, bit for bit
    want = backend.expected_canary(rep.replica_id, rep.obs_nonce,
                                   rep.step_count)
    assert obs.tobytes() == want.tobytes()
    healthy, lat = rep.canary_probe()
    assert healthy and lat > 0.0
    # silent corruption (the §3.4 kernel-limit failure mode) must trip
    # the same probe on every backend — no backend-specific detector
    rep.silent_broken = True
    healthy, _ = rep.canary_probe()
    assert not healthy


def test_backend_salted_canaries_are_pairwise_distinct():
    """A probe wired to the wrong backend's reference must fail loudly:
    the four backends' known answers for the *same* replica coordinates
    are all different."""
    answers = {
        name: get_backend(name).expected_canary("r7", 3, 5).tobytes()
        for name in BUILTIN_BACKENDS
    }
    assert len(set(answers.values())) == len(BUILTIN_BACKENDS)
    # the simos reference is the unsalted pre-protocol function...
    assert answers["simos"] == expected_observation("r7", 3, 5).tobytes()
    # ...and the salted helper is what the others use
    assert answers["swe"] == expected_backend_observation(
        "swe", "r7", 3, 5).tobytes()


# ------------------------------------------------------ resource accounting
@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_replica_resources_and_footprint_agree(name):
    backend = get_backend(name)
    rep = backend.make_replica(
        f"{name}/r0", _base(), faults=FaultInjector(enabled=False), seed=0)
    assert rep.resources.ram_limit_gb == backend.ram_limit_gb()
    fp = ReplicaFootprint.for_backend(backend)
    assert fp.ram_limit_gb == backend.ram_limit_gb()
    assert fp.cow_bytes == backend.est_cow_bytes
    if name == "simos":
        # the extracted oracle's footprint IS the fleet default — value
        # equality is what keeps legacy placement math bit-identical
        assert fp == DEFAULT_FOOTPRINT


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_pool_charges_the_backend_ram_envelope(name):
    backend = get_backend(name)
    pool = RunnerPool(f"{name}-n0", _base(32 << 20), size=4,
                      faults=FaultInjector(enabled=False), backend=backend)
    assert pool.backend_name == name
    assert pool.health()["backend"] == name
    assert pool.host.ram_used_gb == pytest.approx(
        HOST_OS_BASELINE_GB + 4 * backend.ram_limit_gb())
    for runner in pool._all.values():
        assert runner.manager.replica.resources.ram_limit_gb == \
            backend.ram_limit_gb()


def test_backend_latency_bands_reach_the_replica():
    for name in ("swe", "browser", "mobile"):
        backend = get_backend(name)
        rep = backend.make_replica(f"{name}/r0", _base(), seed=0)
        assert rep.latency is backend.latency() or \
            rep.latency == backend.latency()
        # an explicit fleet-wide calibration override wins over the bands
        simos_lat = SimOSReplica("x", _base()).latency
        rep2 = backend.make_replica(f"{name}/r1", _base(), seed=0,
                                    latency=simos_lat)
        assert rep2.latency is simos_lat


# ------------------------------------------------------------------ rewards
def test_reward_defaults_live_on_the_backend():
    for name in ALL_BACKENDS:
        backend = get_backend(name)
        assert backend.families(), f"{name} declares no reward families"
        for family in backend.families():
            assert isinstance(backend.reward_spec(family), RewardSpec)
        with pytest.raises(UnknownFamilyError, match="no reward defaults"):
            backend.reward_spec("definitely-not-a-family")
        assert 0.0 < backend.reward_scale <= 1.0


def test_default_registry_rewards_come_from_the_simos_backend():
    simos = get_backend("simos")
    registry = get_default_registry()
    assert set(registry.families()) == set(simos.families())
    for scenario in registry:
        assert scenario.backend == "simos"
        assert scenario.reward == simos.reward_spec(scenario.family)


def test_mixed_registry_binds_every_backend():
    registry = mixed_registry()
    assert set(registry.backends()) == set(BUILTIN_BACKENDS)
    for scenario in registry:
        backend = get_backend(scenario.backend)
        assert scenario.reward == backend.reward_spec(scenario.family)


# ----------------------------------------------- extraction: bit-exactness
def _scripted_run(rep):
    """Drive one replica through a fixed script; record every observable."""
    trace = []
    trace.append(("boot", rep.boot()))
    task = _task()
    trace.append(("configure", rep.configure(task)))
    obs, dur = rep.reset()
    trace.append(("reset", dur, obs.tobytes()))
    for i in range(6):
        try:
            obs, reward, done, info, dur = rep.step(f"action-{i}")
            trace.append(("step", i, reward, done, dur, obs.tobytes()))
        except ReplicaError as e:
            trace.append(("fault", i, e.fault.value))
            trace.append(("reboot", rep.boot()))
            trace.append(("reconfigure", rep.configure(task)))
    score, dur = rep.evaluate()
    trace.append(("evaluate", score, dur))
    healthy, lat = rep.canary_probe()
    trace.append(("canary", healthy, lat))
    trace.append(("close", rep.close()))
    return trace


def test_simos_backend_replica_is_bit_identical_to_direct_construction():
    """The extracted factory path must change *nothing*: same latency
    draws, same fault stream, same observation bytes as constructing
    SimOSReplica by hand — faults enabled, so the RNG streams are pinned
    too."""
    for seed in (0, 7, 1234):
        direct = _scripted_run(SimOSReplica(
            "r0", _base(), faults=FaultInjector(seed=seed), seed=seed))
        via_backend = _scripted_run(SimOSBackend().make_replica(
            "r0", _base(), faults=FaultInjector(seed=seed), seed=seed))
        assert direct == via_backend


def _engine_report(kernel, *, explicit_backend):
    """A small live-engine run; the full observable surface, exactly."""
    base = _base(64 << 20)
    backend = SimOSBackend() if explicit_backend else None
    pools = [RunnerPool(f"n{i}", base, size=4,
                        faults=FaultInjector(seed=i), seed=i,
                        backend=backend)
             for i in range(2)]
    gw = Gateway(pools)
    writer = TrajectoryWriter(capacity=32, retain=False)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(max_inflight=8))
    tasks = get_default_registry().sample(16, seed=11)
    rep = engine.run_event_driven(tasks, loop=EventLoop(kernel=kernel))
    writer.drain(timeout=10.0)
    out = {
        "completed": rep.completed,
        "failed": rep.failed,
        "total_steps": rep.total_steps,
        "virtual_seconds": rep.virtual_seconds,
        "virtual_makespan": rep.virtual_makespan,
        "results": [(r.task["task_id"], r.ok, r.steps, r.attempts,
                     tuple(r.nodes), r.score, r.virtual_seconds)
                    for r in rep.results],
        "writer": (writer.stats.written, writer.stats.consumed,
                   writer.stats.steps),
    }
    writer.close()
    gw.stop()
    return out


def test_extracted_stack_replays_bit_identically_on_both_kernels():
    """Engine-level extraction gate: pools built with an explicit
    ``SimOSBackend`` replay bit-for-bit against the default (pre-protocol
    signature) path — same event order, same virtual timestamps — on the
    scalar heap oracle AND the batched time-wheel kernel."""
    reports = {}
    for kernel in KERNELS:
        legacy = _engine_report(kernel, explicit_backend=False)
        extracted = _engine_report(kernel, explicit_backend=True)
        assert legacy == extracted, f"extraction drift on {kernel}"
        reports[kernel] = extracted
    assert reports["scalar"] == reports["batched"]


# --------------------------------------------------- mixed-fleet routing
def test_mixed_cluster_routes_by_backend():
    """Two backends behind one gateway: every episode lands only on
    pools of its own backend, and both backends complete work."""
    cluster = Cluster(default_specs(16, runners_per_node=8), 16,
                      runners_per_node=8, seed=0,
                      backends=[("swe", 8), ("browser", 8)])
    node_backend = {p.node_id: p.backend_name for p in cluster.pools}
    assert set(node_backend.values()) == {"swe", "browser"}
    registry = mixed_registry()
    writer = TrajectoryWriter(capacity=64, retain=False)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           config=RolloutConfig(max_inflight=16,
                                                acquire_timeout_vs=600.0))
    tasks = registry.sample(24, seed=5, backends=["swe", "browser"])
    report = engine.run_event_driven(tasks, loop=EventLoop())
    writer.drain(timeout=10.0)
    writer.close()
    cluster.close()
    completed_by = {"swe": 0, "browser": 0}
    for r in report.results:
        want = r.task["backend"]
        for node in r.nodes:
            assert node_backend[node] == want, (
                f"task {r.task['task_id']} ({want}) routed to "
                f"{node_backend[node]} pool {node}")
        if r.ok:
            completed_by[want] += 1
    assert completed_by["swe"] > 0 and completed_by["browser"] > 0
    assert report.completed == sum(completed_by.values())
