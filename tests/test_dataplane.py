"""Vectorized rollout→learner data plane: parity with the scalar oracle.

Locks down the PR's bit-exactness contracts:

- micro-batched ingest == per-sample ingest, replay row for replay row
  (full flushes, remainder flushes, deadline flushes);
- SoA arena backend == dict-list backend under one seed (same sampling
  stream, same FIFO eviction, same pruning);
- fused learner batches == dict-path learner batches, update for update;
- packed batch assembly is deterministic across processes.
"""
import hashlib
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.event_loop import EventLoop
from repro.core.telemetry import Telemetry
from repro.data.pipeline import Trajectory, TrajectoryStep
from repro.data.replay_buffer import ReplayBuffer
from repro.pipeline import (IngestConfig, LearnerConfig, LearnerLoop,
                            PolicyVersionStore, TrajectoryIngestor)

SEQ = 96
MB = 8  # test micro-batch


# --------------------------------------------------------------- helpers
def _trajectories(n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        n_steps = int(rng.integers(2, 5))
        steps = [TrajectoryStep(rng.integers(0, 255, (8, 8, 3), np.uint8),
                                f"thought {i}-{k} " + "x" * int(rng.integers(0, 9)),
                                f"click({i}, {k})")
                 for k in range(n_steps)]
        out.append(Trajectory(f"terminal_os-{i}", "configure the system",
                              steps, float(rng.uniform(0, 1))))
    return out


def _rows(n, seed=0, seq_len=SEQ, version=0):
    """Synthetic RL sample dicts with ragged lengths (no model needed)."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        L = int(rng.integers(4, seq_len + 1))
        rows.append({
            "tokens": rng.integers(0, 264, L).astype(np.int32),
            "actions": rng.integers(0, 264, L).astype(np.int32),
            "action_mask": (rng.random(L) < 0.7).astype(np.float32),
            "rewards": rng.normal(size=L).astype(np.float32),
            "old_logp": rng.normal(size=L).astype(np.float32),
            "values": rng.normal(size=L).astype(np.float32),
            "version": version,
            "ingest_wall": 1000.0 + i,
            "task_id": f"t-{seed}-{i}",
        })
    return rows


def _assert_rows_equal(a_rows, b_rows):
    assert len(a_rows) == len(b_rows)
    for i, (a, b) in enumerate(zip(a_rows, b_rows)):
        keys = {k for k in a if k != "ingest_wall"}
        assert keys == {k for k in b if k != "ingest_wall"}, (i, keys)
        for k in keys:
            va, vb = a[k], b[k]
            if isinstance(va, np.ndarray):
                assert np.array_equal(va, np.asarray(vb)), (i, k)
            else:
                assert va == vb, (i, k, va, vb)


@pytest.fixture(scope="module")
def tiny_trainer():
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train.ppo import PPOConfig, PPOTrainer

    def build(seed=0):
        cfg = get_reduced("qwen3-1.7b", vocab_size=264, d_model=32,
                          n_layers=1, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        return PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4), seed=seed)

    return build


def _make_ingest(trainer, micro_batch, **cfg_over):
    cfg_over.setdefault("flush_wall_s", float("inf"))
    replay = ReplayBuffer(capacity=256, seed=0,
                          backend="soa" if micro_batch > 1 else "list",
                          seq_len=SEQ if micro_batch > 1 else None)
    store = PolicyVersionStore(trainer.params)
    ing = TrajectoryIngestor(
        replay, store, trainer=trainer, telemetry=Telemetry(),
        cfg=IngestConfig(seq_len=SEQ, micro_batch=micro_batch, **cfg_over))
    return replay, ing


# ----------------------------------------------------- ingest plane parity
def test_batched_ingest_bit_identical_to_oracle(tiny_trainer):
    trainer = tiny_trainer()
    # below one batch (forced flush), exactly one batch, two + remainder
    for n in (MB - 3, MB, 2 * MB + 3):
        trajs = _trajectories(n, seed=n)
        replay_s, ing_s = _make_ingest(trainer, 1)
        replay_b, ing_b = _make_ingest(trainer, MB)
        for t in trajs:
            ing_s(t)
        for t in trajs:
            ing_b(t)
        ing_b.flush()
        assert ing_b.pending_rows == 0
        _assert_rows_equal(replay_s.snapshot(), replay_b.snapshot())


def test_wall_deadline_flushes_partial_batches(tiny_trainer):
    trainer = tiny_trainer()
    replay_s, ing_s = _make_ingest(trainer, 1)
    replay_b, ing_b = _make_ingest(trainer, MB, flush_wall_s=0.0)
    # a zero wall deadline makes every arrival overdue: each episode
    # flushes alone through the padded fused call — still bit-exact
    for t in _trajectories(3):
        ing_s(t)
        ing_b(t)
        assert ing_b.pending_rows == 0
    assert len(replay_b) == 3
    _assert_rows_equal(replay_s.snapshot(), replay_b.snapshot())


def test_maybe_flush_respects_deadline(tiny_trainer):
    trainer = tiny_trainer()
    _, ing = _make_ingest(trainer, MB)
    for t in _trajectories(3):
        ing(t)
    assert ing.pending_rows == 3
    assert len(ing.replay) == 0
    assert ing.maybe_flush() == 0            # not overdue, not forced
    ing.cfg.flush_wall_s = 0.0
    assert ing.maybe_flush() == 3            # now overdue
    assert ing.pending_rows == 0
    assert len(ing.replay) == 3


def test_virtual_time_tick_flushes_pending(tiny_trainer):
    trainer = tiny_trainer()
    _, ing = _make_ingest(trainer, MB, flush_virtual_s=5.0)
    loop = EventLoop()
    ing.arm_virtual_flush(loop)
    for t in _trajectories(3):
        ing(t)
    assert ing.pending_rows == 3
    # one non-daemon event keeps the loop alive past the first tick; the
    # tick itself is daemon and must not keep the loop running forever
    loop.call_later(6.0, lambda: None)
    loop.run()
    assert ing.pending_rows == 0
    assert len(ing.replay) == 3


def test_version_change_flushes_old_group_first(tiny_trainer):
    trainer = tiny_trainer()
    _, ing = _make_ingest(trainer, MB)
    trajs = _trajectories(3)
    ing(trajs[0])
    ing(trajs[1])
    ing.store.publish(trainer.params)        # behavior policy moved on
    ing(trajs[2])                            # arrival flushes the v0 group
    assert len(ing.replay) == 2
    assert ing.pending_rows == 1
    ing.flush()
    assert [s["version"] for s in ing.replay.snapshot()] == [0, 0, 1]


# -------------------------------------------------- arena backend parity
def _both(capacity=64, seed=7):
    return (ReplayBuffer(capacity, seed=seed, backend="list"),
            ReplayBuffer(capacity, seed=seed, backend="soa", seq_len=SEQ))


def test_soa_and_list_share_one_sampling_stream():
    lst, soa = _both()
    rows = _rows(20)
    lst.extend(rows)
    soa.extend(rows)
    assert len(lst) == len(soa) == 20
    np.testing.assert_array_equal(lst.versions(), soa.versions())
    _assert_rows_equal(lst.sample(10), soa.sample(10))
    _assert_rows_equal(lst.snapshot(), soa.snapshot())


def test_soa_and_list_evict_oldest_on_overflow():
    lst, soa = _both(capacity=8)
    for chunk in (0, 1, 2):
        rows = _rows(5, seed=chunk, version=chunk)
        lst.extend(rows)
        soa.extend(rows)
    assert len(lst) == len(soa) == 8
    assert lst.total_added == soa.total_added == 15
    _assert_rows_equal(lst.snapshot(), soa.snapshot())
    # newest 8 of the 15 survive, in FIFO order
    assert [s["version"] for s in soa.snapshot()] == [1, 1, 1, 2, 2, 2, 2, 2]


def test_soa_bulk_insert_wider_than_capacity_keeps_newest():
    lst, soa = _both(capacity=8)
    rows = _rows(12)
    lst.extend(rows)
    soa.extend(rows)
    _assert_rows_equal(lst.snapshot(), soa.snapshot())
    _assert_rows_equal(soa.snapshot(), rows[-8:])


def test_soa_and_list_prune_equivalently():
    lst, soa = _both()
    rows = _rows(16)
    lst.extend(rows)
    soa.extend(rows)
    # dict-level predicate
    pred = lambda it: len(it["tokens"]) % 2 == 0
    assert lst.prune(pred) == soa.prune(pred)
    _assert_rows_equal(lst.snapshot(), soa.snapshot())
    # vectorized mask over the version column
    drop = lambda vers: vers >= 0
    assert lst.prune_where(drop) == soa.prune_where(drop)
    assert len(lst) == len(soa) == 0
    assert lst.total_pruned == soa.total_pruned


def test_soa_and_list_sample_columns_agree():
    lst, soa = _both()
    rows = _rows(12)
    lst.extend(rows)
    soa.extend(rows)
    a = lst.sample_columns(6, seq_len=SEQ)
    b = soa.sample_columns(6)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    with pytest.raises(ValueError):
        lst.sample_columns(2)                # list backend needs seq_len
    assert ReplayBuffer(8, backend="soa", seq_len=SEQ).sample_columns(2) is None


def test_soa_rejects_malformed_samples():
    with pytest.raises(ValueError):
        ReplayBuffer(8, backend="soa")       # seq_len required
    soa = ReplayBuffer(8, backend="soa", seq_len=16)
    with pytest.raises(TypeError):
        soa.add("not a sample dict")
    with pytest.raises(ValueError):
        soa.add({"tokens": np.zeros(17, np.int32)})  # wider than the arena


def test_extend_columns_list_backend_copies_planes():
    lst = ReplayBuffer(8, backend="list")
    cols = {name: np.ones((2, SEQ), np.float32) for name in
            ("tokens", "actions", "action_mask", "rewards", "old_logp",
             "values")}
    cols["version"] = np.zeros(2, np.int64)
    cols["ingest_wall"] = np.zeros(2, np.float64)
    lst.extend_columns(cols, [4, 4], [{}, {}])
    cols["rewards"][:] = -99.0               # ingest reuses its buffers
    assert float(lst.snapshot()[0]["rewards"].sum()) == 4.0


def test_extend_is_atomic_under_contention():
    for backend in ("list", "soa"):
        buf = ReplayBuffer(256, seed=0, backend=backend, seq_len=SEQ)
        errors = []

        def writer(k):
            try:
                for i in range(25):
                    buf.extend(_rows(4, seed=k * 100 + i))
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def sampler():
            try:
                for _ in range(50):
                    buf.sample(8)
                    buf.versions()
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
        threads.append(threading.Thread(target=sampler))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert buf.total_added == 4 * 25 * 4
        assert len(buf) == 256               # filled to capacity


# ------------------------------------------------------ learner plane parity
def test_weights_vec_matches_scalar_weight():
    for policy in ("reweight", "drop"):
        loop = LearnerLoop.__new__(LearnerLoop)
        loop.cfg = LearnerConfig(staleness_bound=4, staleness_policy=policy,
                                 staleness_decay=0.8, min_weight=0.05)
        versions = np.arange(0, 30, dtype=np.int64)
        vec = loop._weights_vec(29, versions)
        for i, v in enumerate(versions):
            scalar = loop._weight(29, int(v))
            if scalar is None:
                assert np.isnan(vec[i]), (policy, v)
            else:
                assert vec[i] == scalar, (policy, v)


def test_compute_gae_batch_bit_identical_to_scalar():
    from repro.train.ppo import compute_gae, compute_gae_batch

    rng = np.random.default_rng(0)
    lengths = [1, 3, 17, 40, 64]
    S = 64
    rewards = np.zeros((len(lengths), S), np.float32)
    values = np.zeros((len(lengths), S), np.float32)
    for i, L in enumerate(lengths):
        rewards[i, :L] = rng.normal(size=L).astype(np.float32)
        values[i, :L] = rng.normal(size=L).astype(np.float32)
    adv_b, ret_b = compute_gae_batch(rewards, values, 0.99, 0.95)
    for i, L in enumerate(lengths):
        adv_s, ret_s = compute_gae(rewards[i, :L], values[i, :L], 0.99, 0.95)
        assert np.array_equal(adv_b[i, :L], adv_s), L
        assert np.array_equal(ret_b[i, :L], ret_s), L
        assert not adv_b[i, L:].any() and not ret_b[i, L:].any(), L


def _shim_ppo():
    from repro.train.ppo import PPOConfig, PPOTrainer

    shim = PPOTrainer.__new__(PPOTrainer)
    shim.cfg = PPOConfig()
    return shim


def test_make_batch_columns_matches_make_batch():
    shim = _shim_ppo()
    soa = ReplayBuffer(64, seed=3, backend="soa", seq_len=SEQ)
    rows = _rows(12, seed=5)
    soa.extend(rows)
    cols = soa.sample_columns(10)
    fused = shim.make_batch_columns(cols, np.arange(10), seq_len=SEQ)
    # reconstruct the per-sample dicts the dict path would have pulled
    dicts = []
    for i in range(10):
        L = int(cols["length"][i])
        dicts.append({k: cols[k][i, :L] for k in
                      ("tokens", "actions", "action_mask", "rewards",
                       "old_logp", "values")})
    oracle = shim.make_batch(dicts, seq_len=SEQ)
    assert set(fused) == set(oracle)
    for k in oracle:
        assert np.array_equal(fused[k], oracle[k]), k


def test_fused_learner_bit_matches_dict_learner(tiny_trainer):
    # two identical trainers; the same episode stream through each plane;
    # then every update must consume an identical batch and produce an
    # identical loss
    trainer_f = tiny_trainer()
    trainer_d = tiny_trainer()
    trajs = _trajectories(12, seed=9)
    replay_d, ing_d = _make_ingest(trainer_d, 1)
    replay_f, ing_f = _make_ingest(trainer_f, MB)
    for t in trajs:
        ing_d(t)
        ing_f(t)
    ing_f.flush()
    _assert_rows_equal(replay_d.snapshot(), replay_f.snapshot())

    seen = {}

    def recording(trainer, tag):
        inner = trainer.update

        def update(batch):
            seen.setdefault(tag, []).append(
                {k: np.asarray(v).copy() for k, v in batch.items()})
            return inner(batch)

        trainer.update = update

    recording(trainer_f, "fused")
    recording(trainer_d, "dicts")
    cfg = dict(algo="ppo", batch_size=4, seq_len=SEQ, staleness_bound=8)
    loop_f = LearnerLoop(trainer_f, replay_f, ing_f.store,
                         cfg=LearnerConfig(fused=True, **cfg))
    loop_d = LearnerLoop(trainer_d, replay_d, ing_d.store,
                         cfg=LearnerConfig(fused=False, **cfg))
    for step in range(3):
        mf = loop_f.step()
        md = loop_d.step()
        assert mf is not None and md is not None
        assert mf["loss"] == md["loss"], step
        bf, bd = seen["fused"][step], seen["dicts"][step]
        assert set(bf) == set(bd)
        for k in bf:
            assert np.array_equal(bf[k], bd[k]), (step, k)


class _FakeFusedTrainer:
    """make_batch_columns/make_batch + update recorder (no jax)."""

    def __init__(self, seq_len=SEQ):
        self.params = {"step": 0}
        self.seq_len = seq_len
        self.batches = []

    def _ones(self, n):
        return {"advantages": np.ones((n, self.seq_len), np.float32),
                "action_mask": np.ones((n, self.seq_len), np.float32)}

    def make_batch(self, samples, seq_len):
        return self._ones(len(samples))

    def make_batch_columns(self, cols, sel, seq_len):
        return self._ones(len(sel))

    def update(self, batch):
        self.batches.append(batch)
        self.params = {"step": self.params["step"] + 1}
        return {"loss": 0.5}


@pytest.mark.parametrize("fused", [True, False])
def test_padded_slots_are_zeroed_and_counted_separately(fused):
    # a short batch needs unusable rows to survive into the sampler — that
    # only happens when experience lands *after* the step's eviction pass
    # (the concurrent-mode race); simulate it by disabling eviction
    backend = "soa" if fused else "list"
    replay = ReplayBuffer(32, seed=0, backend=backend, seq_len=SEQ)
    replay.extend(_rows(2, version=4))       # excess 1 -> w=0.5 (reweighted)
    replay.extend(_rows(6, seed=1, version=0))  # excess 5 -> w<min_weight
    store = PolicyVersionStore(None)
    for _ in range(6):
        store.publish(None)                  # current version: 6
    tel = Telemetry()
    loop = LearnerLoop(
        _FakeFusedTrainer(), replay, store, telemetry=tel,
        cfg=LearnerConfig(algo="ppo", batch_size=4, seq_len=SEQ, fused=fused,
                          oversample=2, staleness_bound=1,
                          staleness_decay=0.5, staleness_policy="reweight",
                          min_weight=0.05))
    loop._evict_stale = lambda version: 0
    # replicate the buffer's first draw to know which rows it pulls:
    # logical rows 0-1 are the usable (reweighted) ones
    draws = np.random.default_rng(0).integers(0, 8, size=8)
    n_kept = min(int((draws < 2).sum()), 4)
    assert 0 < n_kept < 4, "seed must yield a short batch for this test"
    n_padded = 4 - n_kept
    assert loop.step() is not None
    batch = loop.trainer.batches[-1]
    assert tel.counter("learner_batch_padded") == n_padded
    assert tel.counter("stale_reweighted") == n_kept, \
        "padded slots must not inflate staleness telemetry"
    assert np.all(batch["advantages"][:n_kept] == 0.5)   # ones x weight
    assert not batch["advantages"][n_kept:].any()
    assert not batch["action_mask"][n_kept:].any()


# ------------------------------------------------- cross-process determinism
_DET_SCRIPT = """
import hashlib
import numpy as np
from repro.train.ppo import PPOConfig, PPOTrainer
from repro.data.replay_buffer import ReplayBuffer

rng = np.random.default_rng(0)
buf = ReplayBuffer(64, seed=3, backend="soa", seq_len=96)
rows = []
for i in range(12):
    L = int(rng.integers(4, 97))
    rows.append({
        "tokens": rng.integers(0, 264, L).astype(np.int32),
        "actions": rng.integers(0, 264, L).astype(np.int32),
        "action_mask": (rng.random(L) < 0.7).astype(np.float32),
        "rewards": rng.normal(size=L).astype(np.float32),
        "old_logp": rng.normal(size=L).astype(np.float32),
        "values": rng.normal(size=L).astype(np.float32),
        "version": 0, "ingest_wall": float(i),
    })
buf.extend(rows)
cols = buf.sample_columns(8)
shim = PPOTrainer.__new__(PPOTrainer)
shim.cfg = PPOConfig()
batch = PPOTrainer.make_batch_columns(shim, cols, np.arange(8), seq_len=96)
h = hashlib.sha256()
for k in sorted(batch):
    h.update(k.encode())
    h.update(batch[k].tobytes())
print(h.hexdigest())
"""


def test_packed_batches_deterministic_across_processes():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    if env.get("PYTHONPATH"):
        env["PYTHONPATH"] = src + os.pathsep + env["PYTHONPATH"]
    else:
        env["PYTHONPATH"] = src
    digests = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", _DET_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64
