"""OSGym core infrastructure: CoW store, runner pool, state managers,
gateway, data server — unit + integration + hypothesis property tests."""
import pytest
from hypothesis import given, strategies as st

from repro.core import (CowStore, DiskImage, BlobStore, DataServer,
                        FaultInjector, FaultType, Gateway, RunnerPool,
                        SimOSReplica, ReplicaStateManager, TaskAborted,
                        RetryPolicy)
from repro.core.runner_pool import SimHost, HostSpec
from repro.core.tasks import TaskSuite, TABLE3_ROWS


# ------------------------------------------------------------------ CoW
def test_reflink_clone_is_instant_and_shares_blocks():
    store = CowStore()
    base = DiskImage.create_base(store, "ubuntu", 24 * 10**9)
    phys0 = store.physical_bytes()
    clones = [base.clone(f"vm{i}")[0] for i in range(16)]
    assert store.physical_bytes() == phys0          # zero new physical bytes
    _, t_reflink = base.clone()
    _, t_full = base.full_copy("naive")
    assert t_full / t_reflink > 30                  # paper: 37x faster
    for c in clones:
        c.close()


def test_cow_write_allocates_only_dirty_blocks():
    store = CowStore(block_size=1024)
    base = DiskImage.create_base(store, "img", 1024 * 100)
    vm, _ = base.clone("vm")
    phys0 = store.physical_bytes()
    vm.write_block(0, "x")
    vm.write_block(1, "y")
    assert store.physical_bytes() == phys0 + 2 * 1024
    assert vm.logical_bytes() == base.logical_bytes()


def test_cow_refcount_release():
    store = CowStore(block_size=64)
    base = DiskImage.create_base(store, "img", 64 * 10)
    vm, _ = base.clone("vm")
    vm.write_block(3, "dirty")
    vm.close()
    base.close()
    assert store.physical_bytes() == 0
    assert store.n_blocks() == 0


@given(st.lists(st.tuples(st.sampled_from(["clone", "write", "close"]),
                          st.integers(0, 9)), max_size=40))
def test_property_cow_invariants(ops):
    """Random op sequences: physical <= sum of logical; refcounts never leak."""
    store = CowStore(block_size=32)
    base = DiskImage.create_base(store, "b", 32 * 10)
    vms = []
    for op, arg in ops:
        if op == "clone":
            vms.append(base.clone(f"v{len(vms)}")[0])
        elif op == "write" and vms:
            vms[arg % len(vms)].write_block(arg % 10, f"w{arg}")
        elif op == "close" and vms:
            vms.pop(arg % len(vms)).close()
    live = [base] + vms
    logical = sum(v.logical_bytes() for v in live)
    assert store.physical_bytes() <= logical
    for v in live:
        v.close()
    assert store.physical_bytes() == 0


def test_blob_store_dedup_across_keys():
    bs = BlobStore(chunk=128)
    data = b"A" * 1000
    bs.put("k1", data)
    p1 = bs.store.physical_bytes()
    bs.put("k2", data)                  # identical content
    assert bs.store.physical_bytes() == p1
    assert bs.get("k2") == data
    bs.delete("k1")
    assert bs.get("k2") == data         # refcount protects shared chunks


# --------------------------------------------------------------- replicas
def _base(store=None):
    store = store or CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", 64 << 20)


def test_state_manager_lifecycle():
    rep = SimOSReplica("r0", _base(), seed=0)
    mgr = ReplicaStateManager(rep)
    mgr.configure({"task_id": "t", "horizon": 3})
    obs, _ = mgr.reset()
    assert obs.shape == (48, 64, 3)
    done = False
    while not done:
        obs, rew, done, info, dur = mgr.step({"a": 1})
    score, _ = mgr.evaluate()
    assert 0.0 <= score <= 1.0
    assert mgr.stats.steps == 3


def test_step_retry_then_abort():
    # 100% runtime faults: retries exhaust, task aborts, replica survives
    inj = FaultInjector(rates={FaultType.RUNTIME: 1.0}, seed=1)
    rep = SimOSReplica("r1", _base(), faults=inj, seed=1)
    mgr = ReplicaStateManager(rep, retry=RetryPolicy(max_retries=3))
    mgr.configure({"task_id": "t", "horizon": 5})
    mgr.reset()
    with pytest.raises(TaskAborted):
        mgr.step({})
    assert mgr.stats.retries == 3
    assert rep.alive                    # runtime faults don't kill the VM


def test_crash_triggers_autonomous_recovery():
    inj = FaultInjector(rates={FaultType.CRASH: 1.0}, seed=2)
    rep = SimOSReplica("r2", _base(), faults=inj, seed=2)
    mgr = ReplicaStateManager(rep)
    mgr.configure({"task_id": "t", "horizon": 5})
    mgr.reset()
    with pytest.raises(TaskAborted):
        mgr.step({})
    assert mgr.stats.recoveries == 1
    assert rep.alive                    # manager re-cloned + rebooted it


# ------------------------------------------------------------------ pool
def test_pool_prewarm_and_recycle():
    pool = RunnerPool("n0", _base(), size=4)
    assert pool.size == 4 and pool.n_free == 4
    r = pool.acquire("task-1")
    assert r is not None and pool.n_free == 3
    pool.release(r)
    assert pool.n_free == 4


def test_resource_guard_blocks_overcommit():
    host = SimHost(HostSpec(cores=8, ram_gb=40.0))   # fits ~4 replicas
    pool = RunnerPool("n1", _base(), size=16, host=host)
    assert pool.size < 16
    assert pool.blocked_creations >= 1
    h = pool.health()
    assert h["ram_used_gb"] <= 40.0


def test_untuned_kernel_limits_cause_silent_failures():
    host = SimHost(HostSpec(cores=96, ram_gb=768.0,
                            limits={"fs.aio-max-nr": 4096,
                                    "fs.inotify.max_user_instances": 128,
                                    "fs.file-max": 65536,
                                    "net.netfilter.nf_conntrack_max": 65536}))
    pool = RunnerPool("n2", _base(), size=8, host=host, tune_limits=False)
    broken = [r for r in pool._all.values() if r.silent_broken]
    assert broken, "exhausted aio-max-nr must silently break runners"
    tuned = SimHost(HostSpec(cores=96, ram_gb=768.0))
    tuned_pool = RunnerPool("n3", _base(), size=8, host=tuned,
                            tune_limits=True)
    assert not any(r.silent_broken for r in tuned_pool._all.values())


def test_leaked_task_reclamation():
    pool = RunnerPool("n4", _base(), size=2, task_timeout_vs=10.0)
    pool.acquire("leaky")
    assert pool.n_free == 1
    pool.advance_time(11.0)
    reclaimed = pool.reclaim_leaked()
    assert reclaimed == ["leaky"]
    assert pool.n_free == 2


# --------------------------------------------------------------- gateway
def test_gateway_affinity_and_failover():
    base = _base()
    pools = [RunnerPool(f"n{i}", base, size=2) for i in range(3)]
    gw = Gateway(pools)
    node1, r1 = gw.acquire("task-A")
    node2, r2 = gw.acquire("task-A")    # same affinity, pool has room
    assert node1 == node2
    gw.mark_unreachable(node1)
    node3, r3 = gw.acquire("task-A")
    assert node3 != node1               # failover
    assert gw.failovers >= 1
    for n, r in ((node1, r1), (node2, r2), (node3, r3)):
        gw.release(n, r)


def test_gateway_health_check_recovers_node():
    base = _base()
    pools = [RunnerPool("n0", base, size=2)]
    gw = Gateway(pools)
    gw.mark_unreachable("n0")
    assert gw.healthy_nodes() == []
    report = gw.check_now()             # pool is actually fine
    assert report["n0"]["healthy"]
    assert gw.healthy_nodes() == ["n0"]


# ------------------------------------------------------------ data server
def test_data_server_end_to_end_with_faults():
    base = _base()
    inj = FaultInjector(seed=3)         # default stochastic rates
    pools = [RunnerPool(f"n{i}", base, size=8, faults=inj, seed=i)
             for i in range(2)]
    gw = Gateway(pools)
    ds = DataServer(gw, max_workers=8)
    tasks = [t.to_dict() for t in TaskSuite(seed=0).sample(8)]
    obs = ds.reset(tasks)
    assert len(obs) == 8
    # enough rounds for a max-horizon (25-step) episode to crash late and
    # replay in full on a fresh runner after reassignment
    for _ in range(60):
        live = ds.live_slots()
        if not live:
            break
        res = ds.step({s: {"click": (1, 2)} for s in live})
        assert set(res) == set(live)
    assert not ds.live_slots(), "all episodes must finish despite faults"
    scores = ds.evaluate()
    assert all(0 <= v <= 1 for v in scores.values())
    assert ds.telemetry.counter("steps") >= 8 * 10
    ds.close()


def test_data_server_async_non_blocking():
    base = _base()
    pools = [RunnerPool("n0", base, size=4)]
    ds = DataServer(Gateway(pools), max_workers=4)
    ds.reset([t.to_dict() for t in TaskSuite(seed=1).sample(4)])
    futs = ds.step_async({s: {} for s in ds.live_slots()})
    # futures resolve; the caller was never blocked on submission
    for f in futs.values():
        obs, rew, done, info = f.result(timeout=10)
        assert obs is not None
    ds.close()


def test_table3_task_suite_domains():
    suite = TaskSuite(seed=0)
    tasks = suite.sample(200)
    domains = {t.domain for t in tasks}
    assert domains <= set(suite.domains())
    assert all(10 <= t.horizon <= 25 for t in tasks)
    assert len(TABLE3_ROWS) == 10
