"""Geo-distributed federation: WAN metering, region routing, spot
preemption, and DiLoCo learner sync.

The PR's contracts, pinned:

- ``WanLink`` byte accounting is exact (ledger == telemetry == what was
  sent) and delivery lands at the transfer's virtual arrival;
- episodes stay in-region when home is healthy (zero WAN bytes), spill
  to a peer on brownout, and ship their trajectories home over the
  metered WAN;
- a single-region federation is **bit-identical** to the bare Cluster
  stack on both event kernels (full report + completion series);
- the ``preempt`` fault class validates like every other rate, its
  streams are creation-order independent, preemptions recover at L2 and
  are counted by the engine;
- DiLoCo outer sync moves exactly ``cross_pod_bytes_per_cycle`` bytes
  per region per cycle over the WAN, keeps the regions' anchors
  bit-identical, and the regional learners' losses still decrease.
"""
import os
import subprocess
import sys
from dataclasses import asdict

import numpy as np
import pytest

from repro.core.event_loop import EventLoop
from repro.core.faults import (DEFAULT_RATES, FaultInjector, FaultType,
                               spot_rates)
from repro.core.telemetry import Telemetry
from repro.federation import (Federation, RegionSpec, WanLink, WanProfile,
                              WanTopology, trajectory_bytes)
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter


# ----------------------------------------------------------------- helpers
def _run_fleet(fleet, telemetry, n_tasks, *, seed=7, inflight=96,
               loop=None, assign=None, on_loop=None):
    reg = get_default_registry()
    tds = [t.to_dict() for t in reg.sample(n_tasks, seed=seed)]
    if assign is not None:
        assign(tds)
    writer = TrajectoryWriter(retain=False, capacity=256)
    eng = RolloutEngine(fleet, writer, registry=reg, telemetry=telemetry,
                        config=RolloutConfig(max_inflight=inflight,
                                             acquire_timeout_vs=3000.0))
    loop = loop or EventLoop()
    if on_loop is not None:
        on_loop(loop)
    report = eng.run_event_driven(tds, loop=loop)
    writer.close()
    return report, loop


# ------------------------------------------------------------- WAN plumbing
def test_wan_profile_cost_is_latency_plus_serialization():
    p = WanProfile("test", 0.05, 10.0)  # 10 Gbps
    assert p.cost(0) == 0.05
    # 1.25 GB at 10 Gbps = 1 s on the wire
    assert p.cost(1_250_000_000) == pytest.approx(1.05)


def test_seeded_topology_is_order_independent():
    a = WanTopology.seeded(["us", "eu", "ap"], seed=3)
    b = WanTopology.seeded(["ap", "eu", "us"], seed=3)
    for pair in (("us", "eu"), ("ap", "us"), ("eu", "ap")):
        assert a.profile(*pair) == b.profile(*pair)
        # symmetric: both directions share one class
        assert a.profile(*pair) == a.profile(*pair[::-1])


def test_wanlink_metering_is_exact():
    tele = Telemetry()
    link = WanLink("us", "eu", WanProfile("test", 0.01, 1.0),
                   telemetry=tele)
    cost = link.send(1000, "control")
    assert cost == pytest.approx(0.01 + 8000 / 1e9)
    link.send(2500, "traj")
    link.send(500, "traj")
    assert link.bytes_total == 4000
    assert link.transfers == 3
    assert link.by_kind == {"control": 1000, "traj": 3000}
    assert tele.counter("wan_bytes") == 4000
    assert tele.counter("wan_bytes:us->eu") == 4000
    assert tele.counter("wan_bytes_kind:traj") == 3000
    assert tele.counter("wan_transfers") == 3
    # the counters(prefix) helper sees the per-link breakdown
    assert tele.counters("wan_bytes:") == {"us->eu": 4000}


@pytest.mark.parametrize("kernel", ["batched", "scalar"])
def test_wanlink_delivery_lands_at_virtual_arrival(kernel):
    loop = EventLoop(kernel=kernel)
    link = WanLink("us", "eu", WanProfile("test", 0.5, 1.0))
    link.attach_loop(loop)
    landed = []
    link.deliver(10_000, "traj", lambda: landed.append(loop.now))
    loop.run()
    assert landed == [pytest.approx(0.5 + 80_000 / 1e9)]
    assert link.bytes_total == 10_000


# ---------------------------------------------------------- region routing
def test_episodes_stay_in_region_when_healthy():
    # faults off: a crash mid-episode parks its runner in recovery, and a
    # home region at capacity for > spill_after_vs legitimately spills —
    # this test isolates the routing invariant, not fault absorption
    fed = Federation([RegionSpec("us", 32), RegionSpec("eu", 32)], seed=0,
                     faults=False)
    tele = fed.telemetry
    report, _ = _run_fleet(fed, tele, 64, assign=fed.assign)
    fed.close()
    assert report.completed == 64
    assert tele.counter("episodes_spilled") == 0
    assert tele.counter("wan_trajectories") == 0
    assert tele.counter("wan_bytes") == 0
    assert fed.wan.total_bytes() == 0


def test_brownout_spills_to_peer_and_ships_trajectories_home():
    fed = Federation([RegionSpec("us", 32), RegionSpec("eu", 32)], seed=0)
    tele = fed.telemetry

    def on_loop(loop):
        loop.call_later(20.0, lambda: fed.brownout("eu"), daemon=True)

    report, _ = _run_fleet(fed, tele, 64, assign=fed.assign,
                           on_loop=on_loop)
    spilled = tele.counter("episodes_spilled")
    fed.close()
    # eu-homed work after t0 must complete on us capacity
    assert spilled > 0
    assert tele.counter("episodes_spilled:eu->us") == spilled
    assert tele.counter("wan_trajectories") == spilled
    # every spilled trajectory paid wire bytes home (us -> eu), every
    # spill attempt paid a control round trip (eu -> us)
    assert fed.wan.link("us", "eu").by_kind.get("traj", 0) > 0
    assert fed.wan.link("eu", "us").by_kind.get("control", 0) > 0
    # the fleet absorbed a full regional outage
    assert report.completed >= 0.9 * 64


def test_restore_clears_the_dark_flag():
    fed = Federation([RegionSpec("us", 16), RegionSpec("eu", 16)], seed=0)
    fed.brownout("eu", kill_running=False)
    assert not fed.region("eu").reachable()
    fed.restore("eu")
    assert fed.region("eu").reachable()
    fed.close()


def test_home_region_is_stable_between_acquire_and_delivery():
    fed = Federation([RegionSpec("us", 16), RegionSpec("eu", 16)], seed=0)
    tds = [{"task_id": f"t-{i}"} for i in range(8)]
    fed.assign(tds)
    for t in tds:
        # id-only resolution (acquire path) == dict resolution (delivery)
        assert fed.home_region(t["task_id"]) is fed.home_region(t)
    # unassigned ids hash stably
    assert fed.home_region("never-assigned") is fed.home_region(
        {"task_id": "never-assigned"})
    fed.close()


# ------------------------------------------------- single-region parity
@pytest.mark.parametrize("kernel", ["batched", "scalar"])
def test_single_region_federation_is_bit_identical_to_cluster(kernel):
    from repro.cluster import Cluster, default_specs

    def run(make):
        fleet, tele = make()
        report, loop = _run_fleet(fleet, tele, 48, inflight=48,
                                  loop=EventLoop(kernel=kernel))
        series = tele.series("completion_vt")
        makespan = loop.now
        fleet.close()
        d = asdict(report)
        d.pop("wall_seconds")
        return d, series, makespan

    def plain():
        c = Cluster(default_specs(32), 32, seed=3)
        return c, c.telemetry

    def fed():
        f = Federation([RegionSpec("solo", 32, node_prefix="node",
                                   seed=3)], seed=99)
        return f, f.telemetry

    assert run(plain) == run(fed)


# ----------------------------------------------------- preempt fault class
def test_preempt_rate_validates_like_every_other_rate():
    with pytest.raises(ValueError, match="negative"):
        FaultInjector(rates=spot_rates(-0.01))
    with pytest.raises(ValueError, match="sum"):
        FaultInjector(rates=spot_rates(0.99))  # defaults + 0.99 > 1
    # a table summing to exactly 1.0 stays legal
    FaultInjector(rates={FaultType.PREEMPT: 1.0})
    inj = FaultInjector(rates={FaultType.PREEMPT: 1.0}, seed=1)
    assert inj.sample() is FaultType.PREEMPT


def test_spot_rates_extends_defaults_without_mutating_them():
    rates = spot_rates(0.02)
    assert rates[FaultType.PREEMPT] == 0.02
    assert FaultType.PREEMPT not in DEFAULT_RATES
    for f, r in DEFAULT_RATES.items():
        assert rates[f] == r


def test_preempt_streams_are_creation_order_independent():
    def child_stream(order):
        """Build children interleaved with parent draws per ``order``;
        returns the k-th child's first 50 samples."""
        parent = FaultInjector(rates=spot_rates(0.3), seed=5)
        children = []
        for op in order:
            if op == "sample":
                parent.sample()
            else:
                children.append(parent.scaled(1.0))
        return [[c.sample() for _ in range(50)] for c in children]

    a = child_stream(["child", "child"])
    b = child_stream(["sample", "child", "sample", "sample", "child"])
    assert a == b
    # and the preempt class actually fires in those streams
    assert any(FaultType.PREEMPT in s for s in a)


def test_spot_preemptions_abort_count_and_recover_at_l2():
    fed = Federation(
        [RegionSpec("solo", 16, runners_per_node=16, spot_frac=1.0,
                    preempt_rate=0.05)],
        seed=2)
    tele = fed.telemetry
    report, _ = _run_fleet(fed, tele, 48, inflight=16)
    fed.close()
    preempts = tele.counter("preemptions")
    assert preempts > 0
    # every preemption is also a reassignment (the episode failed over)
    assert tele.counter("task_reassignments") >= preempts
    # reclaim recovery is an L2 respawn, never an in-place L1 repair
    l2 = tele.summary("recovery_mttr_vs:l2")
    assert l2.get("n", 0) >= preempts
    assert report.completed >= 0.9 * 48


def test_spot_tier_prices_below_on_demand():
    on_demand = Federation([RegionSpec("od", 32)], seed=0)
    spot = Federation([RegionSpec("sp", 32, spot_frac=1.0,
                                  spot_discount=0.35)], seed=0)
    try:
        od = on_demand.price_per_day()
        sp = spot.price_per_day()
        assert sp == pytest.approx(0.35 * od)
        # regional multiplier stacks on top
        premium = Federation([RegionSpec("pr", 32,
                                         price_multiplier=1.5)], seed=0)
        assert premium.price_per_day() == pytest.approx(1.5 * od)
        premium.close()
    finally:
        on_demand.close()
        spot.close()


# -------------------------------------------------------- DiLoCo live loop
jax = pytest.importorskip("jax")


@pytest.fixture(scope="module")
def tiny_trainer():
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train.ppo import PPOConfig, PPOTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264, d_model=32,
                      n_layers=1, n_heads=2, n_kv_heads=2, head_dim=16,
                      d_ff=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4), seed=0)


def _trajs(n, seed=0):
    from repro.data.pipeline import Trajectory, TrajectoryStep
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        steps = [TrajectoryStep(rng.integers(0, 255, (8, 8, 3), np.uint8),
                                f"thought {i}-{k}", f"click({i},{k})")
                 for k in range(int(rng.integers(2, 5)))]
        out.append(Trajectory(f"terminal_os-{i}", "configure the system",
                              steps, float(rng.uniform(0, 1))))
    return out


def _regional_learners(trainer, names, *, seq_len=64, seed0=10):
    from repro.data.replay_buffer import ReplayBuffer
    from repro.federation import RegionLearner
    from repro.pipeline import (IngestConfig, LearnerConfig,
                                PolicyVersionStore, TrajectoryIngestor)
    learners = []
    for i, name in enumerate(names):
        replay = ReplayBuffer(capacity=256, seed=i, backend="soa",
                              seq_len=seq_len)
        store = PolicyVersionStore(trainer.params)
        ing = TrajectoryIngestor(
            replay, store, trainer=trainer,
            cfg=IngestConfig(seq_len=seq_len, micro_batch=8))
        for t in _trajs(12, seed=seed0 + i):
            ing(t)
        ing.flush()
        learners.append(RegionLearner(
            name, trainer, replay, store,
            cfg=LearnerConfig(batch_size=4, seq_len=seq_len)))
    return learners


def test_compress_roundtrip_bounded_error_and_cross_process():
    from repro.distributed.collectives import compress_roundtrip
    x = jax.random.normal(jax.random.PRNGKey(7), (257,), jnp_dtype())
    y = compress_roundtrip(x)
    # int8 symmetric quantization: error bounded by one step (absmax/127)
    step = float(jnp_abs_max(x)) / 127.0
    assert float(jnp_abs_max(x - y)) <= step + 1e-7
    # deterministic across processes: the same roundtrip hashes the same
    code = (
        "import hashlib, jax, numpy as np;"
        "from repro.distributed.collectives import compress_roundtrip;"
        "x = jax.random.normal(jax.random.PRNGKey(7), (257,));"
        "y = np.asarray(compress_roundtrip(x));"
        "print(hashlib.blake2b(y.tobytes(), digest_size=16).hexdigest())"
    )
    env = dict(os.environ, PYTHONPATH="src")
    outs = {subprocess.run([sys.executable, "-c", code], env=env,
                           cwd=os.path.join(os.path.dirname(__file__), ".."),
                           capture_output=True, text=True,
                           check=True).stdout.strip()
            for _ in range(2)}
    assert len(outs) == 1
    import hashlib
    local = hashlib.blake2b(np.asarray(compress_roundtrip(
        jax.random.normal(jax.random.PRNGKey(7), (257,)))).tobytes(),
        digest_size=16).hexdigest()
    assert outs == {local}


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


def jnp_abs_max(x):
    import jax.numpy as jnp
    return jnp.max(jnp.abs(x))


def test_diloco_wan_bytes_agree_with_accounting(tiny_trainer):
    from repro.distributed.diloco import (DiLoCoConfig,
                                          cross_pod_bytes_per_cycle)
    from repro.federation import FederatedLearners
    tele = Telemetry()
    wan = WanTopology.seeded(["us", "eu"], seed=0, telemetry=tele)
    learners = _regional_learners(tiny_trainer, ["us", "eu"])
    cfg = DiLoCoConfig(inner_steps=2)
    fl = FederatedLearners(learners, cfg=cfg, wan=wan, telemetry=tele)
    acc = cross_pod_bytes_per_cycle(fl.n_params, cfg)
    cycles = 2
    for _ in range(cycles):
        for _ in range(cfg.inner_steps):
            for lr in learners:
                assert lr.step() is not None
        assert fl.maybe_sync() is not None
    # exact-bytes agreement: per region per cycle == the accounting's
    # diloco_bytes_per_H_steps, metered on the wire
    assert (tele.counter("wan_bytes_kind:diloco")
            == acc["diloco_bytes_per_H_steps"] * len(learners) * cycles)
    # streaming baseline meters baseline/H per region per inner step
    fl.stream_sync()
    assert (tele.counter("wan_bytes_kind:stream")
            == acc["baseline_bytes_per_H_steps"] // cfg.inner_steps
            * len(learners))
    assert acc["reduction_x"] == pytest.approx(
        fl.stream_bytes_per_region() * cfg.inner_steps
        / fl.diloco_bytes_per_region())


def test_two_region_outer_sync_converges_with_identical_anchors(
        tiny_trainer):
    from repro.distributed.diloco import DiLoCoConfig
    from repro.federation import FederatedLearners
    learners = _regional_learners(tiny_trainer, ["us", "eu"], seed0=40)
    fl = FederatedLearners(learners, cfg=DiLoCoConfig(inner_steps=3),
                           wan=None)
    assert fl.anchors_equal()
    for _ in range(3):
        for _ in range(3):
            for lr in learners:
                assert lr.step() is not None
        fl.outer_sync()
        # the sync invariant: anchors bit-identical across regions, and
        # post-sync params identical too
        assert fl.anchors_equal()
        ref = jax.tree.leaves(learners[0].params)
        for other in learners[1:]:
            for a, b in zip(ref, jax.tree.leaves(other.params)):
                assert bool(jax.numpy.array_equal(a, b))
    for lr in learners:
        trend = lr.loss_trend()
        assert trend["decreased"], (lr.name, trend)


def test_trajectory_bytes_scales_with_steps():
    class T:
        steps = [None] * 5
    assert trajectory_bytes(T()) == 4096 + 5 * 9216
