"""Multi-tenant serving plane (repro.tenancy): admission control, weighted
DRR fairness, burst isolation, per-tenant telemetry, and determinism."""
import json
import random
import subprocess
import sys

import pytest

from repro.cluster import Cluster, default_specs
from repro.cluster.autoscaler import slo_burn
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.core.telemetry import Telemetry, p99
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter
from repro.tenancy import (ADMITTED, REJECTED, THROTTLED, FairShareScheduler,
                           Tenant, jain_index)


def _task(i, tenant=None):
    d = {"task_id": f"t{i:04d}", "task_type": "web", "domain": "web",
         "description": "x", "horizon": 10, "scenario": ""}
    if tenant is not None:
        d["tenant"] = tenant
    return d


# ---------------------------------------------------------------- admission
def test_unknown_tenant_rejected():
    sched = FairShareScheduler([Tenant("a")])
    d = sched.submit(_task(0, "ghost"), now=0.0)
    assert d.status == REJECTED and "unknown" in d.reason
    assert not d.admitted
    d = sched.submit(_task(1), now=0.0)   # no tenant, no default
    assert d.status == REJECTED


def test_default_tenant_routes_untagged_tasks():
    sched = FairShareScheduler([Tenant("a")], default_tenant="a")
    d = sched.submit(_task(0), now=0.0)
    assert d.status == ADMITTED and d.tenant_id == "a"
    assert sched.queue_depth("a") == 1


def test_queue_quota_throttles_not_grows():
    sched = FairShareScheduler([Tenant("a", max_queued=3, burst_tokens=100.0)])
    verdicts = [sched.submit(_task(i, "a"), now=0.0) for i in range(5)]
    assert [v.status for v in verdicts] == [ADMITTED] * 3 + [THROTTLED] * 2
    assert all("queue full" in v.reason for v in verdicts[3:])
    assert sched.queue_depth("a") == 3  # explicit verdicts, no silent growth


def test_burst_budget_throttles_then_refills():
    t = Tenant("a", burst_tokens=2.0, refill_per_vs=1.0, max_queued=100)
    sched = FairShareScheduler([t])
    assert sched.submit(_task(0, "a"), now=0.0).status == ADMITTED
    assert sched.submit(_task(1, "a"), now=0.0).status == ADMITTED
    blocked = sched.submit(_task(2, "a"), now=0.0)
    assert blocked.status == THROTTLED and "burst budget" in blocked.reason
    # one token refills after one virtual second at refill_per_vs=1.0
    assert sched.submit(_task(3, "a"), now=1.0).status == ADMITTED
    assert sched.tokens("a") == pytest.approx(0.0)


def test_bucket_caps_at_burst_tokens():
    t = Tenant("a", burst_tokens=4.0, refill_per_vs=10.0)
    sched = FairShareScheduler([t])
    sched.submit(_task(0, "a"), now=0.0)
    sched.submit(_task(1, "a"), now=1000.0)  # long idle must not overfill
    assert sched.tokens("a") <= t.burst_tokens


def test_tenant_validation():
    with pytest.raises(ValueError):
        Tenant("a", weight=0.0)
    with pytest.raises(ValueError):
        Tenant("a", max_inflight=0)
    with pytest.raises(ValueError):
        FairShareScheduler([Tenant("a"), Tenant("a")])
    with pytest.raises(ValueError):
        FairShareScheduler([Tenant("a")], default_tenant="b")


# ----------------------------------------------------------------- dispatch
def _drain(sched, now=0.0, budget=10**9):
    """Dispatch everything currently servable, observing DRR order."""
    return sched.dispatch(now, budget)


def test_drr_weight_proportionality_under_saturation():
    tenants = [Tenant("a", weight=1.0, max_inflight=10**6, max_queued=10**6,
                      burst_tokens=10**6),
               Tenant("b", weight=2.0, max_inflight=10**6, max_queued=10**6,
                      burst_tokens=10**6),
               Tenant("c", weight=4.0, max_inflight=10**6, max_queued=10**6,
                      burst_tokens=10**6)]
    sched = FairShareScheduler(tenants)
    for i in range(300):
        sched.submit(_task(i, "abc"[i % 3]), now=0.0)
    # saturated: dispatch far fewer slots than the backlog holds
    got = sched.dispatch(0.0, 70)
    by = {t: sum(1 for j in got if j["tenant"] == t) for t in "abc"}
    assert by["b"] / by["a"] == pytest.approx(2.0, rel=0.15)
    assert by["c"] / by["a"] == pytest.approx(4.0, rel=0.15)


def test_drr_sub_unit_weight_still_served():
    tenants = [Tenant("a", weight=0.25, max_inflight=100, burst_tokens=100.0),
               Tenant("b", weight=1.0, max_inflight=100, burst_tokens=100.0)]
    sched = FairShareScheduler(tenants)
    for i in range(40):
        sched.submit(_task(i, "ab"[i % 2]), now=0.0)
    got = sched.dispatch(0.0, 20)
    by = {t: sum(1 for j in got if j["tenant"] == t) for t in "ab"}
    assert by["a"] > 0, "a sub-unit weight must still make progress"
    assert by["b"] / by["a"] == pytest.approx(4.0, rel=0.35)


def test_inflight_quota_blocks_without_banking_credit():
    t = Tenant("a", max_inflight=2, burst_tokens=100.0)
    sched = FairShareScheduler([t, Tenant("b", burst_tokens=100.0)])
    for i in range(6):
        sched.submit(_task(i, "a"), now=0.0)
        sched.submit(_task(100 + i, "b"), now=0.0)
    got = sched.dispatch(0.0, 100)
    assert sum(1 for j in got if j["tenant"] == "a") == 2  # quota binds
    assert sched.n_inflight == 8
    # freeing one slot lets exactly one more "a" job through
    sched.task_done("a", ok=True)
    got = sched.dispatch(0.0, 100)
    assert [j["tenant"] for j in got] == ["a"]


def test_priority_tiers_are_strict():
    tenants = [Tenant("low", priority=2, burst_tokens=100.0),
               Tenant("high", priority=0, burst_tokens=100.0)]
    sched = FairShareScheduler(tenants)
    for i in range(4):
        sched.submit(_task(i, "low"), now=0.0)
        sched.submit(_task(10 + i, "high"), now=0.0)
    got = sched.dispatch(0.0, 6)
    assert [j["tenant"] for j in got] == ["high"] * 4 + ["low"] * 2


def test_dispatch_respects_budget_across_calls():
    sched = FairShareScheduler([Tenant("a", burst_tokens=100.0),
                                Tenant("b", burst_tokens=100.0)])
    for i in range(10):
        sched.submit(_task(i, "ab"[i % 2]), now=0.0)
    first = sched.dispatch(0.0, 3)
    second = sched.dispatch(0.0, 100)
    assert len(first) == 3 and len(second) == 7
    ids = [j["task_id"] for j in first + second]
    assert len(set(ids)) == 10  # nothing dispatched twice


def test_mark_stopped_drops_and_accounts():
    sched = FairShareScheduler([Tenant("a", burst_tokens=100.0)])
    for i in range(5):
        sched.submit(_task(i, "a"), now=0.0)
    sched.dispatch(0.0, 2)
    dropped = sched.mark_stopped(10.0)
    assert dropped == 3
    st = sched.stats()["a"]
    assert st.queued_at_stop == 3 and st.dispatched == 2
    assert sched.n_queued == 0


# ---------------------------------------------------------------- telemetry
def test_per_tenant_telemetry_exactness():
    tel = Telemetry()
    sched = FairShareScheduler(
        [Tenant("a", max_queued=2, burst_tokens=100.0)], telemetry=tel)
    for i in range(4):
        sched.submit(_task(i, "a"), now=0.0)
    sched.dispatch(0.0, 1)
    sched.task_done("a", ok=True, service_vs=7.5)
    sched.observe_wait("a", 3.0)
    assert tel.counter("tenant_admitted:a") == 2
    assert tel.counter("tenant_throttled:a") == 2
    assert tel.counter("tenant_dispatched:a") == 1
    assert tel.counter("tenant_completed:a") == 1
    assert tel.summary("tenant_wait_vs:a")["n"] == 1
    st = sched.stats()["a"]
    assert (st.submitted, st.admitted, st.throttled) == (4, 2, 2)
    assert st.service_vs == pytest.approx(7.5)
    assert sched.share_of_fleet() == {"a": 1.0}


def test_jain_index_units():
    assert jain_index([]) == 1.0
    assert jain_index([5.0, 5.0, 5.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    assert 0.5 < jain_index([1.0, 2.0]) < 1.0


# --------------------------------------------------------------- autoscaler
def test_slo_burn_single_tenant_special_case():
    # untagged window: burn > 1.0 iff the old global p95 > high test fired
    slow = [(None, w) for w in [1.0] * 10 + [50.0] * 10]
    assert p99([w for _t, w in slow]) == 50.0
    assert slo_burn(slow, 10.0) > 1.0
    assert slo_burn([(None, w) for w in [1.0, 2.0, 3.0]], 10.0) <= 1.0
    assert slo_burn([], 10.0) == 0.0


def test_slo_burn_catches_starved_minority_tenant():
    # 19 quick samples for "big", one slow tenant out of SLO: aggregate
    # p95 looks fine but the per-tenant burn must flag it
    tagged = [("big", 1.0)] * 19 + [("small", 40.0)]
    aggregate_p95 = sorted(w for _t, w in tagged)[int(0.95 * 19)]
    assert aggregate_p95 <= 10.0
    assert slo_burn(tagged, 10.0) > 1.0


def test_slo_burn_per_tenant_overrides():
    tagged = [("gold", 8.0), ("bronze", 8.0)]
    assert slo_burn(tagged, 10.0) <= 1.0
    assert slo_burn(tagged, 10.0, {"gold": 4.0}) == pytest.approx(2.0)


def test_scheduler_slo_map():
    sched = FairShareScheduler([Tenant("a", slo_wait_p95_vs=30.0),
                                Tenant("b")])
    assert sched.slo_map() == {"a": 30.0}


# --------------------------------------------------------- engine end-to-end
def _mt_run(seed=0, n_tasks=36, n_replicas=8, tenants=None, weights=None):
    reg = get_default_registry()
    cluster = Cluster(default_specs(n_replicas), n_replicas,
                      runners_per_node=4, seed=seed)
    writer = TrajectoryWriter(retain=False, capacity=2048)
    engine = RolloutEngine(cluster, writer, registry=reg,
                           telemetry=cluster.telemetry,
                           config=RolloutConfig(max_inflight=n_replicas,
                                                acquire_timeout_vs=3000.0))
    tenants = tenants or [Tenant("a", burst_tokens=100.0),
                          Tenant("b", burst_tokens=100.0),
                          Tenant("c", burst_tokens=100.0)]
    sched = FairShareScheduler(tenants, telemetry=cluster.telemetry)
    ids = [t.tenant_id for t in tenants]
    specs = reg.sample(n_tasks, seed=stable_seed(seed, "tenancy-e2e"))
    tasks = []
    for i, s in enumerate(specs):
        d = s.to_dict()
        d["tenant"] = ids[i % len(ids)]
        tasks.append(d)
    rng = random.Random(stable_seed(seed, "tenancy-arrivals"))
    arrivals, t = [], 0.0
    for _ in tasks:
        t += rng.expovariate(1.0)
        arrivals.append(t)
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals, scheduler=sched)
    writer.drain(timeout=10.0)
    writer.close()
    cluster.close()
    return report, sched, cluster, tasks


def test_engine_multitenant_run_completes_all():
    report, sched, cluster, tasks = _mt_run()
    assert report.completed == len(tasks)
    stats = sched.stats()
    assert sum(s.completed for s in stats.values()) == len(tasks)
    assert all(s.submitted == s.admitted for s in stats.values())
    # every tenant observed a submit->runner wait per dispatched job
    for tid, s in stats.items():
        assert len(s.wait_vs) == s.dispatched
        assert cluster.telemetry.summary(f"tenant_wait_vs:{tid}")["n"] \
            == s.dispatched


def test_engine_zero_cross_tenant_leakage():
    report, _sched, _cluster, tasks = _mt_run()
    submitted_by = {t["task_id"]: t["tenant"] for t in tasks}
    for r in report.results:
        assert r.task["tenant"] == submitted_by[r.task["task_id"]]


def test_engine_throttled_tasks_never_launch():
    # one tenant with a 3-token bucket and no refill: exactly 3 of its
    # jobs may run; throttled ones are verdicts, not failed episodes
    tenants = [Tenant("tight", burst_tokens=3.0, refill_per_vs=0.0),
               Tenant("open", burst_tokens=100.0)]
    report, sched, _cluster, tasks = _mt_run(n_tasks=20, tenants=tenants)
    st = sched.stats()["tight"]
    assert st.admitted == 3 and st.throttled == 7
    assert st.completed == 3
    assert report.failed == 0
    assert report.completed == 3 + sched.stats()["open"].completed


def test_engine_burst_isolation_quiet_p95():
    # quiet tenant alone on an idle fleet: measure its wait profile; then
    # add a noisy tenant spiking 6x the jobs — the quiet p95 must not
    # degrade beyond the SLO even though total load jumped
    reg = get_default_registry()

    def run(noisy_jobs):
        cluster = Cluster(default_specs(8), 8, runners_per_node=4, seed=0)
        writer = TrajectoryWriter(retain=False, capacity=2048)
        engine = RolloutEngine(cluster, writer, registry=reg,
                               telemetry=cluster.telemetry,
                               config=RolloutConfig(max_inflight=8,
                                                    acquire_timeout_vs=3000.0))
        tenants = [Tenant("quiet", burst_tokens=100.0),
                   Tenant("noisy", burst_tokens=8.0, refill_per_vs=0.02)]
        sched = FairShareScheduler(tenants, telemetry=cluster.telemetry)
        quiet_specs = reg.sample(12, seed=stable_seed(0, "iso-quiet"))
        rng = random.Random(stable_seed(0, "iso-arrivals"))
        events = []
        t = 0.0
        for s in quiet_specs:
            t += rng.expovariate(0.05)
            d = s.to_dict()
            d["tenant"] = "quiet"
            events.append((t, d))
        if noisy_jobs:
            noisy_specs = reg.sample(noisy_jobs,
                                     seed=stable_seed(0, "iso-noisy"))
            nt = 20.0
            nrng = random.Random(stable_seed(0, "iso-noisy-arr"))
            for s in noisy_specs:
                nt += nrng.expovariate(2.0)
                d = s.to_dict()
                d["tenant"] = "noisy"
                events.append((nt, d))
        events.sort(key=lambda e: e[0])
        arrivals = [e[0] for e in events]
        tasks = [e[1] for e in events]
        engine.run_event_driven(tasks, loop=EventLoop(), arrivals=arrivals,
                                scheduler=sched)
        waits = sched.stats()["quiet"].wait_vs
        writer.drain(timeout=10.0)
        writer.close()
        cluster.close()
        return sorted(waits)[int(0.95 * (len(waits) - 1))], sched

    alone_p95, _ = run(0)
    with_spike_p95, sched = run(72)
    assert sched.stats()["noisy"].throttled > 0  # the spike was clamped
    # the quiet tail may move by the spike's admitted share, but stays
    # bounded: within the bucket-sized allowance, not the 6x spike
    assert with_spike_p95 <= alone_p95 + 60.0


def test_engine_deadline_drops_are_accounted():
    reg = get_default_registry()
    cluster = Cluster(default_specs(4), 4, runners_per_node=4, seed=0)
    writer = TrajectoryWriter(retain=False, capacity=2048)
    engine = RolloutEngine(cluster, writer, registry=reg,
                           config=RolloutConfig(max_inflight=4,
                                                acquire_timeout_vs=3000.0,
                                                virtual_deadline_s=50.0))
    sched = FairShareScheduler([Tenant("a", burst_tokens=1000.0,
                                       max_queued=1000)])
    specs = reg.sample(60, seed=stable_seed(0, "deadline"))
    tasks = []
    for s in specs:
        d = s.to_dict()
        d["tenant"] = "a"
        tasks.append(d)
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=[0.0] * len(tasks),
                                     scheduler=sched)
    st = sched.stats()["a"]
    assert st.queued_at_stop > 0, "the deadline should strand a backlog"
    assert st.dispatched + st.queued_at_stop == st.admitted
    assert report.completed == st.completed


def test_cross_process_seed_determinism():
    """The full multi-tenant pipeline replays bit-identically in a fresh
    interpreter: same seeds -> same verdicts, waits, and completions."""
    prog = """
import json, random, sys
sys.path.insert(0, "src")
from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter
from repro.tenancy import FairShareScheduler, Tenant

reg = get_default_registry()
cluster = Cluster(default_specs(8), 8, runners_per_node=4, seed=0)
writer = TrajectoryWriter(retain=False, capacity=2048)
engine = RolloutEngine(cluster, writer, registry=reg,
                       config=RolloutConfig(max_inflight=8,
                                            acquire_timeout_vs=3000.0))
tenants = [Tenant("a", burst_tokens=5.0, refill_per_vs=0.1),
           Tenant("b", weight=2.0, burst_tokens=100.0)]
sched = FairShareScheduler(tenants)
specs = reg.sample(30, seed=stable_seed(0, "det"))
tasks = []
for i, s in enumerate(specs):
    d = s.to_dict(); d["tenant"] = "ab"[i % 2]; tasks.append(d)
rng = random.Random(stable_seed(0, "det-arr"))
arrivals, t = [], 0.0
for _ in tasks:
    t += rng.expovariate(1.5); arrivals.append(t)
report = engine.run_event_driven(tasks, loop=EventLoop(),
                                 arrivals=arrivals, scheduler=sched)
out = {
    "verdicts": [[d.tenant_id, d.status, d.vt] for d in sched.decisions],
    "waits": {tid: s.wait_vs for tid, s in sched.stats().items()},
    "completed": report.completed,
    "makespan": report.virtual_makespan,
}
writer.drain(timeout=10.0); writer.close(); cluster.close()
print(json.dumps(out, sort_keys=True))
"""
    runs = [subprocess.run([sys.executable, "-c", prog],
                           capture_output=True, text=True, timeout=120)
            for _ in range(2)]
    for r in runs:
        assert r.returncode == 0, r.stderr
    assert runs[0].stdout == runs[1].stdout
    assert json.loads(runs[0].stdout)["completed"] > 0
