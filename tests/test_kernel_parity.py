"""Batched-vs-scalar kernel parity: the batched time-wheel kernel must be
*bit-identical* to the scalar heap oracle on every non-vectorized workload
— same event order under the (time, seq) tie-break, same virtual times,
same task results, same counters — plus golden-value pins for the
stable_seed/lognorm/LatencyStream streams so kernel edits can't silently
shift all committed benchmark baselines, and a cross-process determinism
check for the bulk latency draws."""
import hashlib
import random
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BatchedEventLoop, CowStore, DiskImage, EventLoop,
                        FaultInjector, Gateway, RunnerPool, ScalarEventLoop,
                        Sleep)
from repro.core.replica import expected_observation
from repro.core.seeding import LatencyStream, lognorm_jitter, stable_seed
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)

KERNELS = ("scalar", "batched")


# ------------------------------------------------------------ factory flag
def test_factory_dispatch_and_env_flag(monkeypatch):
    assert isinstance(EventLoop(), BatchedEventLoop)
    assert isinstance(EventLoop(kernel="scalar"), ScalarEventLoop)
    assert isinstance(EventLoop(kernel="batched"), BatchedEventLoop)
    for loop in (EventLoop(), EventLoop(kernel="scalar")):
        assert isinstance(loop, EventLoop)
    monkeypatch.setenv("REPRO_KERNEL", "scalar")
    assert EventLoop().kernel == "scalar"
    monkeypatch.setenv("REPRO_KERNEL", "batched")
    assert EventLoop().kernel == "batched"
    with pytest.raises(ValueError, match="unknown event kernel"):
        EventLoop(kernel="quantum")


# --------------------------------------------------- random-schedule replay
def _make_spec(seed: int, n_tasks: int = 6, n_conds: int = 3):
    """A random event schedule: mixed sleeps, timers (some cancelled —
    immediately or racing a later cancel timer — some daemon), condition
    waits with/without timeouts, notifies, and task joins."""
    rng = random.Random(stable_seed("kernel-parity", seed))
    spec = []
    for _t in range(n_tasks):
        ops = []
        for _o in range(rng.randint(2, 7)):
            roll = rng.random()
            if roll < 0.30:
                ops.append(("sleep", round(rng.uniform(0.0, 3.0), 3)))
            elif roll < 0.50:
                ops.append(("timer", round(rng.uniform(0.0, 2.5), 3),
                            rng.choice(["keep", "cancel_now", "cancel_later"]),
                            rng.random() < 0.25))
            elif roll < 0.70:
                ops.append(("wait", rng.randrange(n_conds),
                            rng.choice([None, round(rng.uniform(0.05, 2.0),
                                                    3)])))
            elif roll < 0.90:
                ops.append(("notify", rng.randrange(n_conds),
                            rng.randint(1, 2)))
            else:
                ops.append(("join_prev",))
        spec.append(ops)
    return spec


def _replay(kernel: str, spec):
    """Run one schedule on one kernel; return every observable output."""
    loop = EventLoop(kernel=kernel)
    conds = [loop.condition() for _ in range(8)]
    trace = []
    tasks = []

    def program(name, ops):
        for j, op in enumerate(ops):
            if op[0] == "sleep":
                yield Sleep(op[1])
                trace.append((name, j, "slept", loop.now))
            elif op[0] == "timer":
                _, delay, mode, daemon = op
                t = loop.call_later(
                    delay,
                    lambda name=name, j=j: trace.append(
                        (name, j, "timer-fired", loop.now)),
                    daemon=daemon)
                if mode == "cancel_now":
                    t.cancel()
                elif mode == "cancel_later":
                    # racing cancel: lands before/at/after the fire
                    # deterministically by (time, seq)
                    loop.call_later(delay * 0.9, t.cancel, daemon=True)
            elif op[0] == "wait":
                ok = yield from conds[op[1]].wait(op[2])
                trace.append((name, j, "wait", ok, loop.now))
            elif op[0] == "notify":
                conds[op[1]].notify(op[2])
                trace.append((name, j, "notify", loop.now))
            elif op[0] == "join_prev":
                if tasks:
                    done = yield tasks[-1]
                    trace.append((name, j, "joined", done.name, loop.now))
        return (name, loop.now)

    for i, ops in enumerate(spec):
        tasks.append(loop.spawn(program(f"t{i}", ops), name=f"t{i}"))
    end = loop.run()
    return {
        "trace": trace,
        "end": end,
        "now": loop.now,
        "results": [(t.name, t.done,
                     t.value if (t.done and t.error is None) else None,
                     type(t.error).__name__ if t.error else None)
                    for t in tasks],
        "n_processed": loop.n_processed,
        "n_scheduled_left": loop.n_scheduled,
        "n_live_left": loop.n_live_tasks,
        "errors": [(n, type(e).__name__) for n, e in loop.errors],
    }


@pytest.mark.parametrize("seed", range(12))
def test_random_schedules_replay_bit_identically(seed):
    spec = _make_spec(seed)
    assert _replay("scalar", spec) == _replay("batched", spec)


@given(seed=st.integers(0, 10_000), n_tasks=st.integers(1, 10))
@settings(max_examples=40, deadline=None)
def test_property_random_schedules_replay_bit_identically(seed, n_tasks):
    spec = _make_spec(seed, n_tasks=n_tasks)
    assert _replay("scalar", spec) == _replay("batched", spec)


def test_run_until_clamps_identically():
    for until in (0.0, 0.7, 1.0, 2.49, 2.5, 99.0):
        outs = []
        for kernel in KERNELS:
            loop = EventLoop(kernel=kernel)
            fired = []
            for d in (0.5, 1.0, 1.5, 2.5):
                loop.call_later(d, fired.append, d)
            dropped = loop.call_later(0.6, fired.append, "no")
            dropped.cancel()
            end = loop.run(until=until)
            outs.append((end, loop.now, fired, loop.n_processed,
                         loop.n_scheduled))
        assert outs[0] == outs[1], f"until={until}"


def test_daemon_timers_do_not_keep_either_kernel_alive():
    outs = []
    for kernel in KERNELS:
        loop = EventLoop(kernel=kernel)
        beats = []

        def heartbeat():
            beats.append(loop.now)
            loop.call_later(10.0, heartbeat, daemon=True)

        loop.call_later(10.0, heartbeat, daemon=True)
        loop.call_later(25.0, beats.append, "work")
        end = loop.run()
        outs.append((end, beats))
    assert outs[0] == outs[1] == (25.0, [10.0, 20.0, "work"])


# --------------------------------------------------------- engine-level
def _engine_report(kernel: str, n_nodes=4, size=8, n_tasks=48):
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    pools = [RunnerPool(f"n{i}", base, size=size,
                        faults=FaultInjector(seed=i), seed=i)
             for i in range(n_nodes)]
    gw = Gateway(pools)
    writer = TrajectoryWriter(capacity=64, retain=False)
    engine = RolloutEngine(gw, writer,
                           config=RolloutConfig(max_inflight=n_nodes * size))
    tasks = get_default_registry().sample(n_tasks, seed=13)
    rep = engine.run_event_driven(tasks, loop=EventLoop(kernel=kernel))
    writer.drain(timeout=10.0)
    out = {
        "completed": rep.completed,
        "failed": rep.failed,
        "total_steps": rep.total_steps,
        "reassignments": rep.reassignments,
        "virtual_seconds": rep.virtual_seconds,      # exact, no rounding
        "virtual_makespan": rep.virtual_makespan,
        "backpressure_waits": rep.backpressure_waits,
        "results": [(r.task["task_id"], r.ok, r.steps, r.attempts,
                     tuple(r.nodes), r.score, r.virtual_seconds)
                    for r in rep.results],
        "failovers": gw.failovers,
        "writer": (writer.stats.written, writer.stats.consumed,
                   writer.stats.steps),
    }
    writer.close()
    gw.stop()
    return out


def test_full_engine_run_is_bit_identical_across_kernels():
    """The real rollout stack — gateway routing, failover, recovery
    ladder timers, canary sweeps, writer gate — replays bit-for-bit on
    the batched kernel: every virtual timestamp and latency draw equal,
    not approximately equal."""
    assert _engine_report("scalar") == _engine_report("batched")


# ------------------------------------------------------------- vec timers
def test_vec_timer_delivers_same_elements_on_both_kernels():
    """The array-scheduling primitive: batched delivery may group
    elements (one callback per bucket) but the delivered (time, index)
    pairs — and any per-lane arithmetic chained off them — must equal the
    scalar oracle's element-at-a-time replay bit-for-bit."""
    rng = np.random.default_rng(stable_seed("vec-parity"))
    n_lanes, n_hops = 64, 6
    hops = rng.lognormal(0.5, 0.4, size=(n_lanes, n_hops))
    outs = []
    for kernel in KERNELS:
        loop = EventLoop(kernel=kernel)
        done_at = np.zeros(n_lanes)
        hop_no = np.zeros(n_lanes, np.int64)
        delivered = []

        def on_fire(ats, idx):
            delivered.extend(zip(idx.tolist(), ats.tolist()))
            h = hop_no[idx]
            last = h == n_hops - 1
            done_at[idx[last]] = ats[last]
            cont = ~last
            if cont.any():
                nxt = idx[cont]
                # next hop chains the same float additions per lane
                vt.schedule(ats[cont] + hops[nxt, h[cont] + 1], nxt)
            hop_no[idx] = h + 1

        vt = loop.vec_timer(on_fire)
        vt.schedule(hops[:, 0].copy())
        loop.run()
        # per-lane delivery order is what the workload observes
        per_lane = {}
        for i, at in delivered:
            per_lane.setdefault(i, []).append(at)
        outs.append({"per_lane": per_lane,
                     "done_at": done_at.tobytes(),
                     "makespan": loop.now,
                     "n": loop.n_processed,
                     "booked": vt.n_booked,
                     "delivered": vt.n_delivered})
    assert outs[0] == outs[1]
    # and the virtual completion times are the exact per-lane hop sums
    np.testing.assert_array_equal(
        np.frombuffer(outs[0]["done_at"]), hops.cumsum(axis=1)[:, -1])


def test_vec_timer_batches_on_batched_kernel():
    """One bucket's worth of same-family events arrives as one callback
    on the batched kernel (the 'one heap interaction per batch' claim is
    observable), while the scalar oracle delivers singletons."""
    sizes = {}
    for kernel in KERNELS:
        loop = EventLoop(kernel=kernel)
        calls = []
        vt = loop.vec_timer(lambda ats, idx: calls.append(len(idx)))
        # 100 events spread over ~2 buckets (span 0.5)
        vt.schedule(np.linspace(5.0, 5.9, 100))
        loop.run()
        assert sum(calls) == 100
        sizes[kernel] = calls
    assert all(c == 1 for c in sizes["scalar"])
    assert len(sizes["batched"]) <= 4     # one per touched bucket
    assert max(sizes["batched"]) >= 50


# ----------------------------------------------- seeding / latency streams
def test_latency_stream_golden_values():
    """Exact pinned floats: any change to the LatencyStream derivation
    silently shifts every committed benchmark baseline — fail loudly
    instead. (Regenerate baselines AND these pins together, explaining
    the shift in CHANGES.md.)"""
    assert stable_seed(0, 1024, "decentralized") == 2432442263420793307
    assert stable_seed("pool", 7) == 8927699488785045167
    r = random.Random(stable_seed(42))
    assert [lognorm_jitter(r, 0.35) for _ in range(4)] == [
        1.0126809073328895, 1.6187959481484668,
        0.5458204195057804, 0.9490894145409831]
    s = LatencyStream(stable_seed(42, "r0", "lat"), 0.35)
    assert [s.jitter() for _ in range(4)] == [
        0.9526672134961464, 1.129339085777782,
        1.2041713483200398, 1.0870846908996488]
    s2 = LatencyStream(stable_seed(42, "r0", "lat"), 0.35)
    assert s2.jitter_block(4).tobytes().hex() == (
        "2bffbdf33f7cee3f6c2978dcc511f23f"
        "6709fd2c4944f33f1557b6eab264f13f")
    obs = expected_observation("r0", 1, 3)
    assert hashlib.blake2b(obs.tobytes(),
                           digest_size=8).hexdigest() == "3ed73ef4b1807447"


def test_latency_stream_block_equals_scalar_draws():
    """Bulk draws are the same stream: jitter_block(n) == n jitter()s,
    split anywhere."""
    a = LatencyStream(stable_seed(9, "x"), 0.35)
    b = LatencyStream(stable_seed(9, "x"), 0.35)
    singles = [a.jitter() for _ in range(150)]
    blocks = list(b.jitter_block(7)) + list(b.jitter_block(64)) + \
        list(b.jitter_block(79))
    assert singles == blocks


def test_latency_stream_mean_is_one():
    s = LatencyStream(stable_seed("mean-check"), 0.35)
    assert abs(float(np.mean(s.jitter_block(100_000))) - 1.0) < 0.01


def test_bulk_draws_are_cross_process_deterministic():
    """The numpy Philox stream must not depend on PYTHONHASHSEED, process
    boundaries, or consumption pattern — it feeds every committed
    baseline."""
    code = (
        "import sys; sys.path.insert(0, 'src'); import hashlib;"
        "from repro.core.seeding import LatencyStream, stable_seed;"
        "from repro.core.replica import expected_observation;"
        "s = LatencyStream(stable_seed(0, 'r7', 'lat'), 0.35);"
        "print(s.jitter_block(130).tobytes().hex());"
        "print(hashlib.blake2b(expected_observation('r7', 2, 5).tobytes(),"
        "      digest_size=8).hexdigest())")
    want_stream = LatencyStream(stable_seed(0, "r7", "lat"),
                                0.35).jitter_block(130).tobytes().hex()
    want_obs = hashlib.blake2b(expected_observation("r7", 2, 5).tobytes(),
                               digest_size=8).hexdigest()
    for hashseed in ("0", "12345"):
        out = subprocess.run(
            [sys.executable, "-c", code], cwd=".", capture_output=True,
            text=True, env={"PYTHONHASHSEED": hashseed, "PATH": "/usr/bin"})
        lines = out.stdout.split()
        assert lines == [want_stream, want_obs], out.stderr
