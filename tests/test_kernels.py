"""Pallas kernels vs the pure-jnp oracles: shape/dtype sweeps in interpret
mode, plus oracle-vs-naive cross checks."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.ssd_scan import ssd_scan


def naive_attention(q, k, v, causal, window, scale=None):
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    scale = scale or 1.0 / math.sqrt(hd)
    s = jnp.einsum("bihd,bjhd->bhij", q, kr).astype(jnp.float32) * scale
    i = jnp.arange(Sq)[:, None]
    j = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhij,bjhd->bihd", p.astype(v.dtype), vr)


@pytest.mark.parametrize("S,H,KVH,hd,window", [
    (128, 4, 4, 32, 0),      # MHA
    (256, 8, 2, 64, 0),      # GQA
    (256, 8, 2, 64, 64),     # GQA + sliding window
    (512, 4, 1, 80, 0),      # MQA, non-pow2 head dim
])
def test_flash_attention_vs_ref(S, H, KVH, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, KVH, hd), jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=True, window=window,
                              q_chunk=128)
    o_pal = flash_attention(q, k, v, causal=True, window=window,
                            block_q=128, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               rtol=1e-4, atol=1e-5)


def test_ref_attention_vs_naive():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 192, 8, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 192, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 192, 2, 32), jnp.float32)
    for window in (0, 48):
        o_naive = naive_attention(q, k, v, True, window)
        o_ref = ref.attention_ref(q, k, v, causal=True, window=window,
                                  q_chunk=64)
        np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_ref),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 256, 4, 64), dtype)
    v = jax.random.normal(ks[2], (1, 256, 4, 64), dtype)
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o_pal = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,KVH,G,block", [(512, 2, 4, 128), (1024, 1, 8, 256)])
def test_decode_attention_vs_ref(S, KVH, G, block):
    B, hd = 3, 64
    H = KVH * G
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
    cl = jnp.array([S // 2, S, 7][:B], jnp.int32)
    o_ref = ref.decode_attention_ref(q, kc, vc, cl)
    o_pal = decode_attention(q, kc, vc, cl, block_s=block, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               rtol=1e-4, atol=1e-5)


def _ssd_inputs(key, B, S, H, P, N, G=1):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    D = jnp.ones((H,))
    return x, dt, A, Bm, Cm, D


def ssd_sequential(x, dt, A, Bm, Cm, D):
    """O(S) reference recurrence (the ground truth both impls must match)."""
    B_, S_, H_, P_ = x.shape
    N_ = Bm.shape[-1]
    h = np.zeros((B_, H_, N_, P_), np.float32)
    ys = []
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn, Cn, Dn = map(np.asarray, (Bm, Cm, D))
    for t in range(S_):
        dA = np.exp(dtn[:, t] * An)
        h = (h * dA[:, :, None, None]
             + np.einsum("bn,bhp->bhnp", Bn[:, t, 0],
                         xn[:, t] * dtn[:, t][:, :, None]))
        ys.append(np.einsum("bn,bhnp->bhp", Cn[:, t, 0], h)
                  + Dn[None, :, None] * xn[:, t])
    return np.stack(ys, 1)


@pytest.mark.parametrize("S,H,P,N,chunk,bh", [
    (128, 4, 32, 16, 32, 2),
    (256, 8, 64, 32, 64, 4),
    (64, 2, 16, 8, 64, 2),     # single chunk
])
def test_ssd_kernel_vs_ref_vs_sequential(S, H, P, N, chunk, bh):
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(4), 2, S, H, P, N)
    y_ref, st_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=chunk,
                                return_state=True)
    y_pal, st_pal = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, block_h=bh,
                             return_state=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(st_pal),
                               rtol=1e-4, atol=1e-4)
    y_seq = ssd_sequential(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y_seq, np.asarray(y_ref), rtol=2e-3, atol=2e-3)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half with state carry == one full pass."""
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(5), 1, 128, 4, 16, 8)
    y_full = ref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=32)
    y1, st = ref.ssd_ref(x[:, :64], dt[:, :64], A, Bm[:, :64], Cm[:, :64], D,
                         chunk=32, return_state=True)
    y2 = ref.ssd_ref(x[:, 64:], dt[:, 64:], A, Bm[:, 64:], Cm[:, 64:], D,
                     chunk=32, initial_state=st)
    y_split = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_scan():
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(6), 2, 8, 4, 16, 8)
    y_ref, st = ref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=8, return_state=True)
    h = jnp.zeros_like(st)
    ys = []
    for t in range(8):
        y, h = ref.ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                  D, h)
        ys.append(y)
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dec),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(h),
                               rtol=1e-3, atol=1e-3)


@given(S=st.sampled_from([64, 128]), KVH=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), seed=st.integers(0, 50))
def test_property_flash_attention_random_shapes(S, KVH, g, seed):
    H = KVH * g
    hd = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, KVH, hd), jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=True, q_chunk=64)
    o_pal = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               rtol=1e-4, atol=1e-5)


def test_causal_conv1d():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 8))
    w = jax.random.normal(jax.random.PRNGKey(8), (4, 8))
    y = ref.causal_conv1d_ref(x, w)
    # manual check at position t: sum_k w[k] * x[t - 3 + k]
    t = 10
    manual = sum(np.asarray(w)[k] * np.asarray(x)[:, t - 3 + k]
                 for k in range(4))
    np.testing.assert_allclose(np.asarray(y)[:, t], manual, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("shape,dtype", [
    ((4, 96, 128), jnp.float32),
    ((2, 300, 64), jnp.bfloat16),     # rows not divisible by block
])
def test_rmsnorm_kernel_vs_ref(shape, dtype):
    from repro.kernels.rmsnorm import rmsnorm as rn
    x = jax.random.normal(jax.random.PRNGKey(9), shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(10), shape[-1:],
                              jnp.float32)
    y_ref = ref.rmsnorm_ref(x, scale)
    y_pal = rn(x, scale, block_rows=128, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pal, np.float32),
                               rtol=tol, atol=tol)
