"""Expert-parallel shard_map MoE == dense GShard MoE (multi-device)."""
import subprocess, sys, os
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.distributed.sharding import train_rules
from repro.models.moe import moe_spec, moe_apply
from repro.models.param import init_params

meshes = {1: jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2),
          2: jax.make_mesh((8, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)}
for arch, fs in [("deepseek-moe-16b", 1), ("grok-1-314b", 2)]:
    mesh = meshes[fs]
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, ep_fsplit=fs, capacity_factor=8.0), d_model=32, d_ff=64,
        n_heads=2, n_kv_heads=2, head_dim=16)
    params = init_params(jax.random.PRNGKey(0), moe_spec(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)
    y_dense, _ = moe_apply(params, cfg, x, rules=train_rules(mesh))
    rules_ep = train_rules(mesh).with_overrides(moe_impl=("ep",))
    y_ep, _ = jax.jit(lambda p, xx: moe_apply(p, cfg, xx, rules=rules_ep))(params, x)
    err = float(jnp.max(jnp.abs(y_dense - y_ep)))
    assert err < 1e-3, (arch, err)
print("EP-OK")
'''

@pytest.mark.slow
@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure: jax.sharding.AxisType API drift under "
           "the forced multi-device mesh (see CI notes); kept running so the "
           "report shows when the drift is fixed")
def test_ep_moe_matches_dense_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                          text=True, timeout=420, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "EP-OK" in proc.stdout
