"""Event-driven virtual-time core: kernel determinism, timer cancellation,
condition timeouts, virtual-time leak reclamation, and threaded-vs-event
parity of the full rollout stack."""
import time

import pytest

from repro.core import (CowStore, DiskImage, EventLoop, FaultInjector,
                        FaultType, Gateway, RunnerPool, Sleep)
from repro.core.event_loop import Condition
from repro.core.seeding import stable_seed
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           VirtualWriterGate, get_default_registry)


# ------------------------------------------------------------------ kernel
def test_virtual_clock_orders_events_and_joins_tasks():
    loop = EventLoop()
    trace = []

    def worker():
        trace.append(("worker-start", loop.now))
        yield Sleep(2.0)
        trace.append(("worker-end", loop.now))
        return "payload"

    def joiner(target):
        done = yield target
        trace.append(("joined", loop.now, done.result()))

    t = loop.spawn(worker())
    loop.spawn(joiner(t))
    loop.call_later(1.0, lambda: trace.append(("timer", loop.now)))
    end = loop.run()
    assert trace == [("worker-start", 0.0), ("timer", 1.0),
                     ("worker-end", 2.0), ("joined", 2.0, "payload")]
    assert end == 2.0 and t.result() == "payload"


def test_kernel_event_order_is_deterministic_across_runs():
    def run_once():
        loop = EventLoop()
        trace = []

        def task(name, delays):
            for d in delays:
                yield Sleep(d)
                trace.append((name, round(loop.now, 6)))

        # deliberate ties: tasks b and c land on the same instants
        loop.spawn(task("a", [0.5, 0.5, 1.0]))
        loop.spawn(task("b", [1.0, 1.0]))
        loop.spawn(task("c", [1.0, 1.0]))
        loop.run()
        return trace

    assert run_once() == run_once()


def test_timer_cancellation_and_daemon_timers():
    loop = EventLoop()
    fired = []
    loop.call_later(1.0, lambda: fired.append("kept"))
    dropped = loop.call_later(0.5, lambda: fired.append("dropped"))
    dropped.cancel()
    # recurring daemon work must not keep the loop alive once real work ends
    def heartbeat():
        fired.append("beat")
        loop.call_later(10.0, heartbeat, daemon=True)
    loop.call_later(10.0, heartbeat, daemon=True)
    loop.run()
    assert fired == ["kept"]
    assert not dropped.fired and dropped.cancelled
    assert loop.now == 1.0          # never advanced to the daemon tick


def test_condition_wait_timeout_and_notify():
    loop = EventLoop()
    cond = Condition(loop)
    got = []

    def waiter(name, timeout):
        ok = yield from cond.wait(timeout)
        got.append((name, ok, loop.now))

    loop.spawn(waiter("timed-out", 1.0))
    loop.spawn(waiter("notified", 10.0))
    loop.call_later(2.0, cond.notify)
    loop.run()
    assert ("timed-out", False, 1.0) in got
    assert ("notified", True, 2.0) in got


# --------------------------------------------------- pool/gateway citizens
def _base():
    store = CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", 64 << 20)


def test_reclaim_leaked_fires_from_virtual_time_advancement():
    loop = EventLoop()
    pool = RunnerPool("n0", _base(), size=1, task_timeout_vs=50.0)
    pool.attach_loop(loop)
    outcome = {}

    def leaker():
        r = yield from pool.acquire_ev("leaky")
        assert r is not None
        outcome["leaked_at"] = loop.now
        # never releases: the daemon reclaim timer must recover the runner

    def waiter():
        r = yield from pool.acquire_ev("patient")
        outcome["acquired_at"] = loop.now
        outcome["runner"] = r
        pool.release(r)

    loop.spawn(leaker())
    loop.spawn(waiter())
    loop.run()
    # reclamation fired when the virtual clock passed the leak deadline —
    # no polling sweep, no advance_time() call
    assert outcome["acquired_at"] == pytest.approx(50.0, abs=1e-6)
    assert pool.n_free == 1


def test_stale_release_after_reclaim_does_not_double_free():
    """A leaked runner that reclamation re-issued to task B must not be
    freed again when task A's zombie episode finally releases it."""
    loop = EventLoop()
    pool = RunnerPool("n0", _base(), size=1, task_timeout_vs=20.0)
    pool.attach_loop(loop)
    trace = []

    def zombie():
        r = yield from pool.acquire_ev("task-A")
        yield Sleep(30.0)               # leaks: deadline passes at vt=20
        # stale handle: reclamation freed it and B holds it now
        pool.release(r, task_id="task-A")
        trace.append(("zombie-release", pool.n_free, r.task_id))

    def successor():
        yield Sleep(5.0)
        # parks until reclamation frees the leaked runner at vt=20
        r = yield from pool.acquire_ev("task-B", timeout=None)
        trace.append(("B-acquired", loop.now, r.task_id))
        yield Sleep(15.0)               # still holding at vt=30 (A releases)
        pool.release(r, task_id="task-B")
        trace.append(("B-release", pool.n_free))

    loop.spawn(zombie())
    loop.spawn(successor())
    loop.run()
    assert ("B-acquired", pytest.approx(20.0), "task-B") in trace
    # the stale release was a no-op: B still held the runner (n_free 0)
    assert ("zombie-release", 0, "task-B") in trace
    assert ("B-release", 1) in trace
    assert pool.n_free == 1             # exactly one copy in the pool


def test_gateway_health_sweep_runs_on_virtual_clock():
    loop = EventLoop()
    pool = RunnerPool("n0", _base(), size=1)
    gw = Gateway([pool], health_interval_s=10.0)
    gw.attach_loop(loop)
    gw.mark_unreachable("n0")
    assert gw.healthy_nodes() == []

    def prober():
        # all nodes unhealthy: immediate None (matches the threaded path)
        got = yield from gw.acquire_ev("t", timeout=5.0)
        assert got is None
        yield Sleep(11.0)   # one virtual health sweep runs at t=10
        got = yield from gw.acquire_ev("t", timeout=5.0)
        assert got is not None
        node, r = got
        gw.release(node, r)
        return loop.now

    t = loop.spawn(prober())
    loop.run()
    assert gw.healthy_nodes() == ["n0"]
    assert t.result() == pytest.approx(11.0)
    assert gw.status["n0"].last_check == pytest.approx(10.0)


def test_pool_acquire_deadline_loop_survives_steals():
    """Threaded-path regression: a waiter whose wakeup is stolen by another
    thread must keep waiting until its own timeout, not return None at the
    first spurious wakeup."""
    import threading

    pool = RunnerPool("n0", _base(), size=1)
    held = pool.acquire("holder")
    results = {}

    def slow_waiter():
        results["slow"] = pool.acquire("slow", timeout=5.0)

    t = threading.Thread(target=slow_waiter)
    t.start()
    time.sleep(0.1)
    # release and instantly steal from this thread: the waiter's notify
    # races with the steal, and before the deadline-loop fix it returned
    # None here instead of waiting for the second release
    pool.release(held)
    stolen = pool.acquire("thief", timeout=1.0)
    assert stolen is not None
    time.sleep(0.1)
    pool.release(stolen)
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert results["slow"] is not None


def test_release_wakes_excluded_and_unexcluded_waiters():
    """Lost-wakeup regression: the frontmost waiter may refuse a freed
    runner (node exclusion), so a release must wake every waiter — a
    single notify would strand the one that could have taken it."""
    loop = EventLoop()
    base = _base()
    pools = [RunnerPool(f"n{i}", base, size=1, seed=i) for i in range(2)]
    gw = Gateway(pools)
    gw.attach_loop(loop)
    held = {}

    def holder():
        for node in ("n0", "n1"):
            got = yield from gw.acquire_ev(f"warm-{node}", timeout=None)
            held[got[0]] = got
        # free n0 after both waiters have parked
        yield Sleep(5.0)
        gw.release(*held["n0"])

    def excluded_waiter():
        # parks first (FIFO front) but refuses n0
        got = yield from gw.acquire_ev("picky", timeout=30.0,
                                       exclude={"n0"})
        return (got, loop.now)

    def plain_waiter():
        got = yield from gw.acquire_ev("easy", timeout=30.0)
        return (got, loop.now)

    loop.spawn(holder())
    a = loop.spawn(excluded_waiter())
    b = loop.spawn(plain_waiter())
    loop.run()
    got_b, when_b = b.result()
    assert got_b is not None and got_b[0] == "n0"
    assert when_b == pytest.approx(5.0)     # immediately on release
    got_a, _ = a.result()
    assert got_a is None                    # n1 never freed; times out


def test_attach_loop_rearms_health_sweep_on_new_loop():
    """Back-to-back event runs each bring a fresh loop: the health sweep
    must be re-armed on the new clock, not left on the dead old one."""
    def sleeper(dt):
        yield Sleep(dt)

    pool = RunnerPool("n0", _base(), size=1)
    gw = Gateway([pool], health_interval_s=10.0)
    loop1 = EventLoop()
    gw.attach_loop(loop1)
    loop1.spawn(sleeper(15.0))
    loop1.run()
    assert gw.status["n0"].last_check == pytest.approx(10.0)
    loop2 = EventLoop()
    gw.attach_loop(loop2)
    loop2.spawn(sleeper(25.0))
    loop2.run()
    # sweeps ran on loop2's clock (t=10 and t=20 of the new loop); without
    # the re-arm the stale loop1 timer leaves last_check stuck at 10.0
    assert gw.status["n0"].last_check == pytest.approx(20.0)


# ------------------------------------------------------- engine parity
def _stack(n_nodes=2, size=2, faults=True, **cfg_kw):
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    pools = [RunnerPool(f"n{i}", base, size=size,
                        faults=FaultInjector(seed=i) if faults else None,
                        seed=i) for i in range(n_nodes)]
    gw = Gateway(pools)
    writer = TrajectoryWriter(capacity=64)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(**cfg_kw))
    return engine, writer


def test_event_engine_matches_threaded_engine_serially():
    """max_inflight=1 serializes both paths, so reports must be identical
    episode-for-episode — faults, failover, and scores included."""
    tasks = get_default_registry().sample(8, seed=7)
    reports = []
    for mode in ("threaded", "event"):
        engine, writer = _stack(max_inflight=1)
        rep = (engine.run(tasks) if mode == "threaded"
               else engine.run_event_driven(tasks))
        writer.close()
        reports.append(rep)
    a, b = reports
    assert (a.completed, a.failed, a.total_steps) == \
           (b.completed, b.failed, b.total_steps)
    assert a.virtual_seconds == pytest.approx(b.virtual_seconds)
    for ra, rb in zip(a.results, b.results):
        assert (ra.ok, ra.steps, ra.attempts, ra.nodes) == \
               (rb.ok, rb.steps, rb.attempts, rb.nodes)
        assert ra.score == pytest.approx(rb.score)


def test_event_engine_semantic_parity_when_concurrent():
    """With faults off, outcomes (completions, per-task step counts) are
    schedule-independent: the concurrent event run must agree with the
    threaded run even though interleavings differ."""
    tasks = get_default_registry().sample(12, seed=3)
    outcomes = []
    for mode in ("threaded", "event"):
        engine, writer = _stack(faults=False, max_inflight=6)
        rep = (engine.run(tasks) if mode == "threaded"
               else engine.run_event_driven(tasks))
        writer.close()
        assert rep.peak_inflight <= 6
        outcomes.append(sorted((r.task["task_id"], r.ok, r.steps)
                               for r in rep.results))
    assert outcomes[0] == outcomes[1]


def test_threaded_mode_works_after_event_run_on_same_stack():
    """run_event_driven detaches the loop on exit, so a later threaded
    run — and pool-local virtual time / reclamation — behaves normally."""
    tasks = get_default_registry().sample(4, seed=9)
    engine, writer = _stack(faults=False, max_inflight=2)
    rep_ev = engine.run_event_driven(tasks)
    assert rep_ev.completed == 4
    rep_th = engine.run(tasks)
    assert rep_th.completed == 4
    # pool-local clock moves again: leaked-runner reclamation works
    pool = next(iter(engine.gateway.pools.values()))
    r = pool.acquire("leaky", timeout=1.0)
    assert r is not None
    pool.advance_time(pool.task_timeout_vs + 1.0)
    assert pool.reclaim_leaked() == ["leaky"]
    writer.close()


def test_event_engine_report_is_deterministic():
    tasks = get_default_registry().sample(10, seed=11)
    runs = []
    for _ in range(2):
        engine, writer = _stack(max_inflight=8)
        rep = engine.run_event_driven(tasks)
        writer.close()
        runs.append((rep.completed, rep.failed, rep.total_steps,
                     rep.reassignments, round(rep.virtual_seconds, 9),
                     round(rep.virtual_makespan, 9),
                     [(r.task["task_id"], r.ok, r.steps, r.nodes)
                      for r in rep.results]))
    assert runs[0] == runs[1]


def test_event_engine_failover_excludes_faulty_node():
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    crash_always = FaultInjector(rates={FaultType.CRASH: 1.0}, seed=0)
    pools = [RunnerPool("n0", base, size=4, faults=crash_always, seed=0),
             RunnerPool("n1", base, size=4, seed=1)]
    gw = Gateway(pools)
    writer = TrajectoryWriter(capacity=64)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(
        max_inflight=4, max_attempts=3))
    tasks = [t for t in get_default_registry().sample(50, seed=2)
             if gw._affinity_order(t.task_id)[0] == "n0"][:4]
    assert len(tasks) == 4
    rep = engine.run_event_driven(tasks)
    assert rep.completed == 4 and rep.failed == 0
    assert rep.reassignments >= 4
    for r in rep.results:
        assert r.nodes[0] == "n0" and r.nodes[-1] == "n1"
    assert all(r.manager.replica.alive for r in pools[0]._all.values())
    writer.close()


def test_event_engine_writer_backpressure_throttles_feeder():
    # capacity 2 with a glacial virtual consumer: the gate saturates after
    # the second completed episode and the feeder must stall on it
    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    gw = Gateway([RunnerPool("n0", base, size=4, seed=0)])
    writer = TrajectoryWriter(capacity=2)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(
        max_inflight=4, writer_consume_vs=500.0))
    tasks = get_default_registry().sample(8, seed=5)
    rep = engine.run_event_driven(tasks)
    assert rep.completed == 8
    assert rep.backpressure_waits > 0, \
        "feeder must throttle while the virtual writer backlog is saturated"
    # the run still drains: every completed trajectory reached the writer
    assert writer.drain(timeout=10.0)
    assert writer.stats.consumed == 8
    writer.close()


def test_event_engine_records_malformed_task_as_failed():
    """Parity with the threaded path: a bad task dict becomes a failed
    EpisodeResult, never a silently dropped episode."""
    engine, writer = _stack(faults=False, max_inflight=2)
    good = get_default_registry().sample(2, seed=0)
    bad = {"task_id": "legacy-x", "domain": "NoSuchApp",
           "description": "unknown domain", "horizon": 5}
    no_id = {"domain": "NoSuchApp", "description": "missing task_id"}
    rep = engine.run_event_driven(list(good) + [bad, no_id])
    assert rep.completed == 2 and rep.failed == 2
    assert sum("KeyError" in r.error for r in rep.results if not r.ok) == 2
    writer.close()


def test_event_engine_surfaces_kernel_task_crashes():
    """A crashed non-episode task (feeder/kernel level) must raise, not
    return a normal-looking report with episodes missing."""
    engine, writer = _stack(faults=False, max_inflight=2)
    loop = EventLoop()

    def saboteur():
        yield Sleep(1.0)
        raise ValueError("boom")

    loop.spawn(saboteur(), name="saboteur")
    with pytest.raises(RuntimeError, match="saboteur"):
        engine.run_event_driven(get_default_registry().sample(2, seed=0),
                                loop=loop)
    writer.close()


def test_virtual_writer_gate_drains_on_schedule():
    loop = EventLoop()
    writer = TrajectoryWriter(capacity=4)
    gate = VirtualWriterGate(loop, writer, consume_vs=2.0)
    from repro.data.pipeline import Trajectory
    for i in range(4):
        gate.write(Trajectory(f"t{i}", "d", []))
    assert gate.saturated() and gate.backlog() == 4
    loop.run(until=5.0)       # 2 virtual consumes at t=2 and t=4
    assert gate.backlog() == 2
    loop.run()
    assert gate.backlog() == 0 and not gate.saturated()
    assert writer.drain(timeout=5.0) and writer.stats.consumed == 4
    writer.close()


# ----------------------------------------------------- writer drain (CV)
def test_writer_drain_returns_promptly_after_last_consume():
    import threading

    from repro.data.pipeline import Trajectory

    writer = TrajectoryWriter(capacity=8)
    writer.pause()
    for i in range(3):
        writer.write(Trajectory(f"t{i}", "d", []))
    threading.Timer(0.3, writer.resume).start()
    t0 = time.monotonic()
    assert writer.drain(timeout=10.0)
    elapsed = time.monotonic() - t0
    # condition-variable wakeup: returns right after the final consume,
    # not after another poll interval (the old busy-poll burned 10 ms
    # ticks; allow generous CI scheduling slack)
    assert 0.2 <= elapsed < 2.0
    assert writer.stats.consumed == 3
    writer.close()


# ----------------------------------------------------------- determinism
def test_stable_seed_is_process_stable_and_distinct():
    import subprocess
    import sys

    assert stable_seed(0, 1024, "decentralized") != \
        stable_seed(0, 1024, "centralized")
    assert stable_seed("ab", "c") != stable_seed("a", "bc")
    code = ("import sys; sys.path.insert(0, 'src'); "
            "from repro.core.seeding import stable_seed; "
            "print(stable_seed(0, 1024, 'decentralized'))")
    outs = {subprocess.run([sys.executable, "-c", code], cwd=".",
                           capture_output=True, text=True).stdout.strip()
            for _ in range(2)}
    assert outs == {str(stable_seed(0, 1024, "decentralized"))}
