"""Rollout subsystem: concurrent episode completion, bounded in-flight
scheduling with writer backpressure, failover-on-fault retry, scenario
registry round-trip, and the gateway's non-blocking submit API."""
import threading
import time

import pytest

from repro.core import (CowStore, DiskImage, FaultInjector, FaultType,
                        Gateway, RunnerPool)
from repro.core.gateway import NoRunnerAvailable
from repro.core.tasks import TaskSuite
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer
from repro.rollout import (RolloutConfig, RolloutEngine, Scenario,
                           ScenarioProfile, ScenarioRegistry,
                           TrajectoryWriter, default_registry,
                           get_default_registry)


def _base(store=None):
    store = store or CowStore(block_size=1 << 20)
    return DiskImage.create_base(store, "ubuntu", 64 << 20)


def _gateway(n_nodes=2, size=4, faults=None, base=None):
    base = base or _base()
    pools = [RunnerPool(f"n{i}", base, size=size,
                        faults=faults[i] if faults else None, seed=i)
             for i in range(n_nodes)]
    return Gateway(pools), pools


# ------------------------------------------------------- concurrent episodes
def test_concurrent_episodes_complete_into_replay_buffer():
    gw, _ = _gateway(n_nodes=2, size=4)
    replay = ReplayBuffer()
    writer = TrajectoryWriter(replay=replay, tokenizer=ByteTokenizer(),
                              capacity=64)
    engine = RolloutEngine(gw, writer,
                           config=RolloutConfig(max_inflight=8))
    tasks = get_default_registry().sample(10, seed=0)
    report = engine.run(tasks)
    assert report.completed == 10 and report.failed == 0
    assert writer.drain(timeout=10.0)
    assert len(replay) == 10                   # streamed into the buffer
    assert writer.stats.encoded_tokens > 0     # SFT-encoded on the way
    for r in report.results:
        assert r.ok and 10 <= r.steps <= 25    # paper's horizon band
        assert r.virtual_seconds > 0
    # trajectories carry the scripted thought/action steps
    traj = writer.trajectories[0]
    assert traj.steps and traj.steps[0].thought and traj.steps[0].action
    writer.close()


# ------------------------------------------------ bounded in-flight + waits
def test_bounded_inflight_and_writer_backpressure():
    gw, _ = _gateway(n_nodes=1, size=4)
    writer = TrajectoryWriter(capacity=1)
    writer.pause()                     # consumer stalls -> queue saturates
    engine = RolloutEngine(
        gw, writer,
        config=RolloutConfig(max_inflight=2, backpressure_poll_s=0.005))
    tasks = get_default_registry().sample(6, seed=1)

    done = {}

    def run():
        done["report"] = engine.run(tasks)

    t = threading.Thread(target=run)
    t.start()
    deadline = time.monotonic() + 15.0
    while time.monotonic() < deadline:
        if engine.stats.backpressure_waits > 0:
            break
        time.sleep(0.01)
    assert engine.stats.backpressure_waits > 0, \
        "feeder must throttle while the writer backlog is saturated"
    writer.resume()
    t.join(timeout=30.0)
    assert not t.is_alive()
    report = done["report"]
    assert report.completed == 6
    assert report.peak_inflight <= 2           # bounded worker slots
    assert writer.drain(timeout=10.0)
    writer.close()


# ---------------------------------------------------- failover-on-fault retry
def test_failover_retries_on_faulty_node():
    base = _base()
    crash_always = FaultInjector(rates={FaultType.CRASH: 1.0}, seed=0)
    clean = FaultInjector(enabled=False)
    gw, pools = _gateway(n_nodes=2, size=4, base=base,
                         faults={0: crash_always, 1: clean})
    writer = TrajectoryWriter(capacity=64)
    engine = RolloutEngine(gw, writer,
                           config=RolloutConfig(max_inflight=4,
                                                max_attempts=3))
    # craft tasks whose affinity prefers the crashing node, guaranteeing at
    # least one abort -> failover to the clean node
    tasks = []
    suite_tasks = get_default_registry().sample(50, seed=2)
    for t in suite_tasks:
        if gw._affinity_order(t.task_id)[0] == "n0":
            tasks.append(t)
        if len(tasks) == 4:
            break
    assert len(tasks) == 4, "need tasks with affinity to the faulty node"

    report = engine.run(tasks)
    assert report.completed == 4 and report.failed == 0
    assert report.reassignments >= 4          # every episode aborted on n0
    for r in report.results:
        assert r.nodes[0] == "n0" and r.nodes[-1] == "n1"
    # the pool recovered the crashed runners autonomously on release
    assert all(r.manager.replica.alive for r in pools[0]._all.values())
    writer.close()


def test_episode_fails_gracefully_when_retries_exhausted():
    crash_always = FaultInjector(rates={FaultType.CRASH: 1.0}, seed=0)
    gw, _ = _gateway(n_nodes=1, size=2, faults={0: crash_always})
    writer = TrajectoryWriter(capacity=8)
    engine = RolloutEngine(gw, writer,
                           config=RolloutConfig(max_inflight=2,
                                                max_attempts=2))
    report = engine.run(get_default_registry().sample(3, seed=3))
    assert report.completed == 0 and report.failed == 3
    for r in report.results:
        assert not r.ok and r.attempts == 2 and r.error
    assert writer.stats.written == 0
    writer.close()


def test_unresolvable_task_fails_gracefully():
    gw, _ = _gateway(n_nodes=1, size=2)
    writer = TrajectoryWriter(capacity=8)
    engine = RolloutEngine(gw, writer, config=RolloutConfig(max_inflight=2))
    good = get_default_registry().sample(2, seed=0)
    bad = {"task_id": "legacy-x", "domain": "NoSuchApp",
           "description": "legacy dict with unknown domain", "horizon": 5}
    report = engine.run(list(good) + [bad])
    assert report.completed == 2 and report.failed == 1
    assert any("KeyError" in r.error for r in report.results if not r.ok)
    writer.close()


def test_gateway_submit_after_stop_raises():
    gw, _ = _gateway(n_nodes=1, size=2)
    gw.stop()
    with pytest.raises(RuntimeError):
        gw.submit("t", lambda node, runner: node)


def test_writer_survives_consumer_errors():
    from repro.data.pipeline import Trajectory

    def boom(traj):
        raise RuntimeError("downstream exploded")

    writer = TrajectoryWriter(capacity=2, on_trajectory=boom)
    for i in range(5):                 # > capacity: would deadlock if the
        writer.write(Trajectory(f"t{i}", "instr", []),  # consumer died
                     timeout=5.0)
    assert writer.drain(timeout=10.0)
    assert len(writer.errors) == 5
    assert all("downstream exploded" in e for e in writer.errors)
    writer.close()


# ------------------------------------------------------- scenario registry
def test_scenario_registry_roundtrip():
    reg = ScenarioRegistry()

    @reg.scenario("custom_term", "terminal", "OS", "Custom terminal flow",
                  profile=ScenarioProfile(step_mean_s=1.0, horizon=(3, 5)),
                  weight=2.0)
    def policy(obs, step_idx):
        return f"thinking at {step_idx}", f"exec('step {step_idx}')"

    assert isinstance(policy, Scenario)
    assert "custom_term" in reg and len(reg) == 1
    tasks = reg.sample(5, seed=0)
    for t in tasks:
        assert t.scenario == "custom_term"
        assert 3 <= t.horizon <= 5
        # dict round-trip resolves back to the registered scenario
        assert reg.resolve(t.to_dict()) is reg.get("custom_term")
    # legacy dicts (no scenario key) fall back to domain matching
    assert reg.resolve({"task_id": "x", "domain": "OS"}).name == "custom_term"
    with pytest.raises(KeyError):
        reg.resolve({"task_id": "y", "domain": "Unknown"})
    with pytest.raises(ValueError):
        reg.register(reg.get("custom_term"))   # duplicate name


def test_default_registry_covers_required_families_and_table3():
    reg = default_registry()
    fams = set(reg.families())
    assert {"office", "browser", "terminal", "coding", "multi_app"} <= fams
    assert set(reg.domains()) == set(TaskSuite.domains())
    # weighted stats drive the virtual-time throughput benchmark
    assert reg.mean_trajectory_s() > 0
    assert 10 <= reg.mean_steps_per_trajectory() <= 25
    # each scenario's policy produces (thought, action) strings
    for s in reg:
        thought, action = s.policy(None, 0)
        assert isinstance(thought, str) and isinstance(action, str)


def test_task_suite_delegates_to_registry():
    suite = TaskSuite(seed=0)
    tasks = suite.sample(40)
    assert all(t.scenario in get_default_registry() for t in tasks)
    assert {t.domain for t in tasks} <= set(suite.domains())
    assert all(10 <= t.horizon <= 25 for t in tasks)
    by_dom = suite.by_domain("Chrome", 3)
    assert len(by_dom) == 3 and all(t.domain == "Chrome" for t in by_dom)


# ------------------------------------------------------ gateway submit API
def test_gateway_nonblocking_submit_and_try_acquire():
    gw, _ = _gateway(n_nodes=2, size=2)

    def episode(node, runner):
        runner.manager.configure({"task_id": "t", "horizon": 2})
        runner.manager.reset()
        return node

    futs = [gw.submit(f"task-{i}", episode) for i in range(6)]
    nodes = [f.result(timeout=30.0) for f in futs]
    assert len(nodes) == 6 and set(nodes) <= {"n0", "n1"}
    # all runners were released by the submit wrapper
    assert all(p.n_free == p.size for p in gw.pools.values())

    # try_acquire never blocks; exhausting the fleet yields None
    held = []
    while True:
        got = gw.try_acquire("drain")
        if got is None:
            break
        held.append(got)
    assert len(held) == 4
    t0 = time.monotonic()
    assert gw.try_acquire("drain") is None
    assert time.monotonic() - t0 < 1.0
    for node, r in held:
        gw.release(node, r)

    # submit surfaces NoRunnerAvailable when nothing frees up in time
    held = [gw.try_acquire("x") for _ in range(4)]
    fut = gw.submit("task-starved", episode, acquire_timeout=0.05)
    with pytest.raises(NoRunnerAvailable):
        fut.result(timeout=10.0)
    for node, r in held:
        gw.release(node, r)
    gw.stop()


def test_gateway_acquire_exclude_forces_other_node():
    gw, _ = _gateway(n_nodes=2, size=2)
    task = "task-affinity"
    preferred = gw._affinity_order(task)[0]
    node, r = gw.acquire(task)
    assert node == preferred
    gw.release(node, r)
    node2, r2 = gw.acquire(task, exclude={preferred})
    assert node2 != preferred
    gw.release(node2, r2)
