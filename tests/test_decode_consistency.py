"""Prefill + incremental decode must reproduce full-sequence logits for every
architecture family (KV cache, SSM state carry, rolling SWA buffers,
cross-attention caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_arch_ids
from repro.models import build_model

# f32 for SSM/hybrid (bf16 chunked-vs-sequential drift is numeric, not logic)
DTYPES = {"mamba2-2.7b": "float32", "jamba-1.5-large-398b": "float32"}


@pytest.mark.parametrize("arch", list_arch_ids())
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype=DTYPES.get(arch, "bfloat16"))
    if cfg.moe is not None:  # capacity drops are batch-size dependent
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, n_dec = 2, 32, 4
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    frames = None
    if cfg.frontend != "none":
        frames = jax.random.normal(key, (B, 8, cfg.frontend_dim),
                                   jnp.bfloat16)
        if cfg.family != "encdec":
            tokens = tokens[:, :S - 8]

    logits_full, _ = model.forward(params, tokens, frames)
    S_b = logits_full.shape[1]

    tok_prefill = tokens[:, :-n_dec]
    lg, cache = model.prefill(params, tok_prefill, frames, cache_size=S + 4)
    outs = [lg]
    for t in range(n_dec - 1):
        nxt = tokens[:, tok_prefill.shape[1] + t][:, None]
        lg, cache = model.decode_step(params, cache, nxt)
        outs.append(lg)
    dec = np.asarray(jnp.concatenate(outs, axis=1), np.float32)
    ref = np.asarray(logits_full[:, S_b - n_dec - 1: S_b - 1], np.float32)
    rel = np.max(np.abs(dec - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 0.02, f"{arch}: rel err {rel}"


def test_sliding_window_rolls_cache():
    cfg = get_reduced("h2o-danube-1.8b")  # window 16
    model = build_model(cfg)
    shapes = model.cache_shapes(batch=2, cache_size=64)
    # SWA cache is clamped to the window
    k = jax.tree.leaves(shapes["blocks"])[0]
    assert k.shape[2] == cfg.sliding_window
