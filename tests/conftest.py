import os
import sys
import types

# Smoke tests and benches must see the real (single) device — only the
# dry-run (its own subprocess) forces 512 placeholder devices.
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")

try:
    from hypothesis import settings, HealthCheck
except ModuleNotFoundError:
    # Degrade gracefully: install a minimal shim so modules that do
    # `from hypothesis import given, strategies as st` still import, with
    # every property-based test collected as an explicit skip instead of
    # killing the whole run at collection time.
    import pytest

    class _Permissive:
        """Stands in for strategies/settings objects: any attribute access,
        call, or chain (`st.lists(st.integers(0, 9)).map(...)`) resolves to
        another permissive object."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement so pytest never tries to resolve the
            # strategy-injected parameters as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed (property-based test)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    shim = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _Permissive()
    shim.given = _given
    shim.settings = _Settings
    shim.HealthCheck = _Permissive()
    shim.strategies = strategies
    shim.assume = lambda *a, **k: True
    shim.note = lambda *a, **k: None
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
else:
    settings.register_profile(
        "ci", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile("ci")
