import os

# Smoke tests and benches must see the real (single) device — only the
# dry-run (its own subprocess) forces 512 placeholder devices.
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")

from hypothesis import settings, HealthCheck

settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
settings.load_profile("ci")
