"""Multi-layer fault recovery (§3.4): the live escalation ladder.

The paper's fourth pillar as one subsystem: step retries (L0) and
autonomous in-place manager recovery (L1) escalate through forced VM
reboots from the shared CoW base image (L2), canary-driven quarantine
and runner recreation (L3 — the layer that finally catches *silent*
failures at runtime), up to node eviction with cluster-side replacement
(L4). ``RecoveryLadder`` binds one pool's layers together; the gateway
installs one per pool and drives the periodic canary sweep.
"""

from repro.recovery.canary import ProbeResult, probe_runner
from repro.recovery.ladder import LAYERS, MTTR_PREFIX, RecoveryLadder, RecoveryPolicy

__all__ = [
    "LAYERS",
    "MTTR_PREFIX",
    "ProbeResult",
    "RecoveryLadder",
    "RecoveryPolicy",
    "probe_runner",
]
