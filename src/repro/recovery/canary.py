"""Canary probe: known-answer detection of silent replica failures.

Silent failures are the §3.4 failure mode that no retry or health check
catches: a replica whose host exhausted an untuned kernel limit keeps
"succeeding" while corrupting every observation, so trajectories rot
without a single exception. The only way to see it is to *ask a question
whose answer is known*: the probe runs a scripted no-op reset/step whose
observation is exactly predictable from the replica's visible state and
checksums the frame against the replica's own known-answer contract
(``canary_probe``). Every ``repro.envs`` backend implements that
contract — SimOS answers with
:func:`repro.core.replica.expected_observation`, other backends salt
the same digest with their backend name — so the whole recovery ladder
works unchanged on a heterogeneous fleet.

A probe costs ``LatencyModel.canary_s`` deterministic virtual seconds
(no jitter — probing never perturbs a replica's latency RNG stream) and
only ever touches *free* runners, so detection latency is bounded by the
sweep interval plus the time a broken runner spends leased.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.runner_pool import Runner


@dataclass(frozen=True)
class ProbeResult:
    healthy: bool
    reason: str  # "ok" | "dead" | "checksum"
    cost_vs: float  # deterministic virtual seconds the probe took


def probe_runner(runner: Runner) -> ProbeResult:
    """One known-answer probe against a runner's replica.

    ``dead`` means the replica is not even alive (crash/hang the health
    layer has not repaired yet) — an L1 matter. ``checksum`` means the
    replica answered, but wrongly: the silent failure mode, which only
    recreation on a host with kernel-limit headroom truly fixes."""
    rep = runner.manager.replica
    if not rep.alive:
        return ProbeResult(False, "dead", rep.latency.canary_s)
    healthy, cost = rep.canary_probe()
    return ProbeResult(healthy, "ok" if healthy else "checksum", cost)
