"""``RecoveryLadder``: one pool's multi-layer fault-recovery escalation.

The paper's §3.4 recovery layers, unified behind one object per pool:

- **L0 — step retry.** Owned by ``RetryPolicy`` inside the state
  manager; the ladder instruments every manager so each retry's backoff
  lands in telemetry as L0 repair time.
- **L1 — in-place manager recovery.** ``recover_if_needed`` on the
  release path and on dead free runners found by the health sweep.
- **L2 — VM reboot from the shared CoW base.** ``force_reboot``: the
  suspect overlay is dropped, a fresh reflink clone of the base image is
  booted and reconfigured, and the provisioning latency is charged on
  the virtual clock. Applied to runners whose task leaked (reclaimed)
  and as the next rung when L1 leaves the replica unhealthy.
- **L3 — runner recreation with quarantine.** Driven by the canary
  probes: a runner that fails the known-answer checksum even after a
  reboot is *silently broken* (kernel-limit exhaustion — a property of
  its VM allocation, unfixable by rebooting). It is quarantined
  permanently, its VM's kernel resources return to the host, and a
  replacement boots on a fresh allocation.
- **L4 — node eviction.** When recreation keeps producing broken
  runners the host itself is exhausted: the ladder evicts the node via
  its ``on_evict`` callback (the cluster control plane replaces the
  capacity elsewhere; a bare gateway just stops routing to it).

Every repair observes ``recovery_mttr_vs:<layer>`` in telemetry, and
every canary detection observes ``silent_detection_latency_vs`` against
the instant the runner broke — the Fig. 6 recovery benchmark's per-layer
MTTR table reads straight out of these series.

The ladder is backend-agnostic: it speaks only the ``EnvBackend``
replica protocol (alive / recover / reboot / ``canary_probe``), so the
same L0–L4 escalation protects SWE sandboxes, headless browsers and
device emulators exactly as it protects OS VMs — each backend's
known-answer canary is what makes L3 detection possible off-platform.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.runner_pool import Runner, RunnerPool
from repro.core.telemetry import Telemetry
from repro.recovery.canary import ProbeResult, probe_runner

LAYERS = ("l0", "l1", "l2", "l3", "l4")
MTTR_PREFIX = "recovery_mttr_vs:"


@dataclass
class RecoveryPolicy:
    """Escalation thresholds for one pool's ladder."""

    # consecutive L3 recreations that came back broken before the node
    # is declared exhausted and evicted (L4)
    evict_after_failed_recreates: int = 3
    # per-runner canary cadence: a runner is checksummed at most this
    # often. The periodic sweep covers idle runners; the release-path
    # probe covers runners a saturated fleet re-leases instantly (they
    # are never free when a sweep fires), so detection latency stays
    # bounded by one interval plus a single lease under any load.
    probe_interval_vs: float = 15.0


class RecoveryLadder:
    """Escalating repair for one pool; see module docstring."""

    def __init__(
        self,
        pool: RunnerPool,
        *,
        telemetry: Optional[Telemetry] = None,
        policy: Optional[RecoveryPolicy] = None,
        on_evict: Optional[Callable[[str], None]] = None,
    ):
        self.pool = pool
        self.telemetry = telemetry or Telemetry()
        self.policy = policy or RecoveryPolicy()
        self.on_evict = on_evict
        self.layer_events = {layer: 0 for layer in LAYERS}
        self.detected_at: dict[str, float] = {}  # runner_id -> vt
        self.quarantined_at: dict[str, float] = {}  # runner_id -> vt
        self._failed_recreates = 0  # consecutive, the L4 fuse
        for r in list(pool._all.values()):
            self.watch(r)
        pool.attach_recovery(self)

    # ---------------------------------------------------- instrumentation
    def watch(self, runner: Runner) -> None:
        """Route a manager's L0/L1/L2 repairs into per-layer telemetry."""
        runner.manager.recovery_observer = self._observe

    def _observe(self, layer: str, dur: float) -> None:
        self.layer_events[layer] += 1
        self.telemetry.observe(MTTR_PREFIX + layer, dur)
        self.telemetry.count(f"recovery_events:{layer}")

    # ------------------------------------------------------- release path
    def heal(self, runner: Runner) -> float:
        """L1 with L2 escalation, on the pool's recycle-release path.

        Called under the pool lock (like the bare ``recover_if_needed``
        it replaces) so reclamation cannot observe the runner
        mid-recovery. Returns the repair's virtual seconds."""
        mgr = runner.manager
        if mgr.replica.alive:
            return 0.0
        dur = mgr.recover_if_needed()  # L1
        if not mgr.replica.alive:  # L1 did not stick -> L2
            dur += mgr.force_reboot()
        return dur

    def on_reclaimed(self, runner: Runner) -> float:
        """A leaked task marks the VM wedged: reboot from the CoW base
        (L2) before the runner serves again."""
        return runner.manager.force_reboot()

    # ------------------------------------------------------- health sweep
    def heal_free_dead(self) -> int:
        """Health-sweep hook: proactively repair dead *free* runners
        instead of waiting for an acquire to trip over them. On the
        event loop each repaired runner returns to service only after
        its recovery latency has elapsed."""
        pool = self.pool
        healed = 0
        for r in pool.free_runners():
            if r.manager.replica.alive:
                continue
            if not pool.hold_for_probe(r):
                continue
            pool.end_probe(r, after_vs=self.heal(r))
            healed += 1
        return healed

    # ------------------------------------------------------- canary sweep
    def canary_sweep(self) -> dict:
        """Probe every free runner with the known-answer check and
        escalate failures: L1 -> L2 -> L3 (quarantine + recreate) -> L4
        (evict). Returns a sweep report for tests and benchmarks.

        Healthy runners are probed *in place* (the check piggybacks the
        health plane's sweep; its cost shows up in the
        ``canary_probe_vs`` series, never as scheduling interference —
        holding healthy runners would perturb the task->runner mapping
        of a saturated fleet). An *unhealthy* runner is taken out of
        circulation and only returns once its actual repair latency has
        elapsed on the virtual clock."""
        pool = self.pool
        now = pool.vt
        report = {
            "probed": 0,
            "detected": 0,
            "healed": 0,
            "recreated": 0,
            "quarantined": 0,
            "evicted": False,
        }
        for runner in pool.free_runners():
            if pool.evicted:
                break
            if now - runner.last_probe_vt < self.policy.probe_interval_vs:
                continue  # the per-runner cadence bound: a runner probed
                #           recently (e.g. on release) is not re-probed
            res = probe_runner(runner)
            runner.last_probe_vt = now
            report["probed"] += 1
            self.telemetry.observe("canary_probe_vs", res.cost_vs)
            if res.healthy:
                continue
            if not pool.hold_for_probe(runner):
                continue  # an acquire won the race; probe next sweep
            outcome, _dur = self._escalate_held(runner, res, now)
            if outcome in report:
                report[outcome] += 1
            if res.reason == "checksum":
                report["detected"] += 1
            if pool.evicted:
                report["evicted"] = True
        return report

    def maybe_probe_released(self, runner: Runner) -> float:
        """Release-path canary (called by the pool right after a recycle
        release puts the runner back in the free set).

        A saturated fleet re-leases runners the instant they free, so
        the periodic sweep — which only sees *idle* runners — would
        never probe them and a silently-broken runner could corrupt
        trajectories indefinitely. This hook checksums the released
        runner when its last probe is older than the canary interval;
        healthy runners are probed in place (no scheduling
        interference), unhealthy ones are pulled straight into the
        escalation path. Returns the repair's virtual seconds."""
        pool = self.pool
        now = pool.vt
        if now - runner.last_probe_vt < self.policy.probe_interval_vs:
            return 0.0
        if not pool.hold_for_probe(runner):
            return 0.0  # already re-leased; probed at its next release
        # hold BEFORE probing: in thread mode a waiter can lease the
        # just-freed runner concurrently, and a probe racing a live
        # step() would read torn obs_nonce/step_count and flag a healthy
        # replica. Held probes are race-free in both modes; a healthy
        # runner returns to the same end-of-deque slot with zero virtual
        # cost, so event-mode schedules are unperturbed.
        res = probe_runner(runner)
        runner.last_probe_vt = now
        self.telemetry.observe("canary_probe_vs", res.cost_vs)
        if res.healthy:
            pool.end_probe(runner)
            return 0.0
        _outcome, dur = self._escalate_held(runner, res, now)
        return dur

    def _escalate_held(
        self, runner: Runner, res: ProbeResult, now: float
    ) -> tuple[str, float]:
        """L1 -> L2 -> L3 -> L4 escalation for a runner that failed its
        probe and is already held out of circulation. Returns
        ``(outcome, repair_virtual_seconds)``; outcome is ``"healed"``,
        ``"recreated"``, or ``"quarantined"`` (recreation refused or
        born broken)."""
        pool = self.pool
        dur = res.cost_vs
        mgr = runner.manager
        if res.reason == "checksum":
            self.note_detected(runner, now)
        if not mgr.replica.alive:
            dur += mgr.recover_if_needed()  # L1
        if not self._recheck_ok(runner):
            dur += mgr.force_reboot()  # L2
            dur += mgr.replica.latency.canary_s  # verification probe
        if self._recheck_ok(runner):
            pool.end_probe(runner, after_vs=dur)
            return "healed", dur
        # L3: the corruption survives reboots — quarantine the runner
        # and recreate it on a fresh VM allocation
        replacement, boot_vs = pool.recreate(runner)
        self.note_quarantined(runner, now)
        self._observe("l3", dur + boot_vs)
        if replacement is None:
            # resource-guard refusal: transient RAM pressure, not kernel
            # exhaustion — it must NOT arm the eviction fuse (the node is
            # not evidently broken, just momentarily tight); the pool
            # shrinks by one until capacity frees up
            self.telemetry.count("recreations_refused")
            return "quarantined", dur
        if probe_runner(replacement).healthy:
            self._failed_recreates = 0
            if pool._loop is not None and boot_vs > 0:
                # provisioning latency on the virtual clock: the
                # replacement serves only once its boot completes
                pool._loop.call_later(boot_vs, pool.put_in_service, replacement)
            else:
                pool.put_in_service(replacement)
            return "recreated", dur
        # born broken: the host's kernel limits are still exhausted
        self._failed_recreates += 1
        pool.quarantine(replacement)
        self.note_quarantined(replacement, now)
        if self._failed_recreates >= self.policy.evict_after_failed_recreates:
            self.evict(now)  # L4
        return "quarantined", dur

    def _recheck_ok(self, runner: Runner) -> bool:
        rep = runner.manager.replica
        return rep.alive and rep.canary_probe()[0]

    # ----------------------------------------------------------- L4 evict
    def evict(self, now: Optional[float] = None) -> None:
        """Declare this node exhausted: stop routing to it, quarantine
        its remaining broken free runners (leased broken runners are
        quarantined as their leases release), and hand the node to the
        ``on_evict`` sink — the cluster control plane replaces the
        capacity on other hosts."""
        pool = self.pool
        if pool.evicted:
            return
        now = pool.vt if now is None else now
        pool.evicted = True
        self.layer_events["l4"] += 1
        self.telemetry.count("nodes_evicted")
        for r in pool.free_runners():
            if r.silent_broken:
                pool.quarantine(r)
                self.note_quarantined(r, now)
        if self.on_evict is not None:
            self.on_evict(pool.node_id)

    # --------------------------------------------------------- accounting
    def note_detected(self, runner: Runner, now: Optional[float] = None) -> None:
        """First detection of a silently-broken runner: observe the
        detection latency against the instant it broke."""
        if runner.runner_id in self.detected_at:
            return
        now = self.pool.vt if now is None else now
        self.detected_at[runner.runner_id] = now
        anchor = runner.broken_since_vt if runner.broken_since_vt is not None else now
        self.telemetry.observe("silent_detection_latency_vs", now - anchor)
        self.telemetry.count("canary_detections")

    def note_quarantined(self, runner: Runner, now: Optional[float] = None) -> None:
        if runner.runner_id in self.quarantined_at:
            return
        now = self.pool.vt if now is None else now
        self.note_detected(runner, now)
        self.quarantined_at[runner.runner_id] = now
        self.telemetry.count("runners_quarantined")

    def summary(self) -> dict:
        """Ladder state snapshot (tests / benchmark reporting)."""
        return {
            "node": self.pool.node_id,
            "layer_events": dict(self.layer_events),
            "detected": len(self.detected_at),
            "quarantined": len(self.quarantined_at),
            "evicted": self.pool.evicted,
        }
