"""Metered WAN links between regions (the federation's §3.2-at-geo-scale
cost model).

Intra-region traffic keeps today's latency model untouched; anything that
crosses a region boundary goes through a :class:`WanLink`, which

- **meters bytes** — every transfer lands in the link's own ledger
  (``bytes_total`` / ``transfers`` / per-kind breakdown) *and* in shared
  Telemetry counters (``wan_bytes``, ``wan_bytes:<src>-><dst>``,
  ``wan_bytes_kind:<kind>``, ``wan_transfers``), so cross-region byte
  claims (e.g. DiLoCo's ~H× reduction) are measured, never modeled;
- **prices virtual time** — ``cost(nbytes) = latency + nbytes/bandwidth``
  from the pair's :class:`WanProfile`. Callers either ``yield
  Sleep(link.send(...))`` inline (control-plane round trips) or hand a
  completion to ``deliver(...)``, which schedules it at the transfer's
  virtual arrival through one :class:`~repro.core.event_loop.VecTimer`
  family per link (bulk trajectory shipping: one kernel interaction per
  batch of arrivals, and the pending transfer keeps the loop alive until
  the payload lands).

Profiles per region pair are drawn deterministically from a seed
(:meth:`WanTopology.seeded`): an unordered pair gets one of the three WAN
latency classes below, both directions symmetric, stable across processes
and region-construction order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.event_loop import EventLoop, VecTimer
from repro.core.seeding import stable_seed
from repro.core.telemetry import Telemetry

# header + one uint8 screenshot (48*64*3) + action/thought text per step:
# the wire size of one trajectory shipped home across regions
TRAJ_HEADER_BYTES = 4096
TRAJ_STEP_BYTES = 9216


def trajectory_bytes(traj) -> int:
    """Wire bytes for shipping one trajectory between regions."""
    return TRAJ_HEADER_BYTES + len(traj.steps) * TRAJ_STEP_BYTES


@dataclass(frozen=True)
class WanProfile:
    """One WAN latency class: one-way latency plus shared bandwidth."""

    name: str
    latency_s: float     # one-way propagation + queuing floor
    gbps: float          # provisioned inter-region bandwidth

    def cost(self, nbytes: int) -> float:
        """Virtual seconds for ``nbytes`` to land on the far side."""
        return self.latency_s + (nbytes * 8.0) / (self.gbps * 1e9)


# the seeded classes a region pair can draw (roughly metro peering /
# same-continent backbone / intercontinental submarine path)
WAN_CLASSES = (
    WanProfile("metro", 0.002, 100.0),
    WanProfile("continental", 0.040, 10.0),
    WanProfile("intercontinental", 0.120, 2.5),
)


class WanLink:
    """One directed region pair: byte ledger + virtual-time delivery."""

    def __init__(self, src: str, dst: str, profile: WanProfile, *,
                 telemetry: Optional[Telemetry] = None):
        self.src = src
        self.dst = dst
        self.profile = profile
        self.telemetry = telemetry or Telemetry()
        self.bytes_total = 0
        self.transfers = 0
        self.by_kind: dict[str, int] = {}
        self._loop: Optional[EventLoop] = None
        self._timer: Optional[VecTimer] = None
        # in-flight deliveries: token -> completion callback
        self._pending: dict[int, Callable[[], None]] = {}
        self._token = 0

    # ------------------------------------------------------------- metering
    def send(self, nbytes: int, kind: str = "data") -> float:
        """Meter ``nbytes`` over this link; returns the virtual cost.

        The caller owns the time accounting (sleep the cost, or schedule
        at ``now + cost``); the bytes are charged here either way."""
        nbytes = int(nbytes)
        self.bytes_total += nbytes
        self.transfers += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0) + nbytes
        self.telemetry.count("wan_bytes", nbytes)
        self.telemetry.count(f"wan_bytes:{self.src}->{self.dst}", nbytes)
        self.telemetry.count(f"wan_bytes_kind:{kind}", nbytes)
        self.telemetry.count("wan_transfers")
        return self.profile.cost(nbytes)

    # ------------------------------------------------------------- delivery
    def attach_loop(self, loop: EventLoop) -> None:
        """Bind the link's delivery timer family to an event loop.

        Non-daemon: a trajectory in flight over the WAN must land (and run
        its commit) before the loop is allowed to finish."""
        if self._loop is loop:
            return
        self._loop = loop
        self._timer = loop.vec_timer(self._fire)

    def detach_loop(self) -> None:
        self._loop = None
        self._timer = None
        self._pending.clear()

    def deliver(self, nbytes: int, kind: str,
                fn: Callable[[], None]) -> float:
        """Meter a transfer and run ``fn`` at its virtual arrival time.

        Requires an attached loop. Returns the transfer cost."""
        assert self._timer is not None, "attach_loop() before deliver()"
        cost = self.send(nbytes, kind)
        self._token += 1
        self._pending[self._token] = fn
        self._timer.schedule(
            np.asarray([self._loop.now + cost]),
            np.asarray([self._token]))
        return cost

    def _fire(self, ats, idx) -> None:
        # one callback may carry a whole bucket of arrivals (batched
        # kernel); deliver in (time, seq) order as handed to us
        for token in np.asarray(idx).tolist():
            fn = self._pending.pop(int(token), None)
            if fn is not None:
                fn()


class WanTopology:
    """All pairwise links between a set of regions, lazily materialized."""

    def __init__(self, profiles: dict[tuple[str, str], WanProfile], *,
                 telemetry: Optional[Telemetry] = None):
        # unordered-pair profiles; both directions share one class
        self._profiles = dict(profiles)
        self.telemetry = telemetry or Telemetry()
        self._links: dict[tuple[str, str], WanLink] = {}
        self._loop: Optional[EventLoop] = None

    @classmethod
    def seeded(cls, names: list[str], *, seed: int = 0,
               telemetry: Optional[Telemetry] = None) -> "WanTopology":
        """Draw one WAN class per unordered region pair from ``seed``.

        The draw keys on the sorted pair names, so the profile table is
        independent of region declaration order."""
        profiles = {}
        for i, a in enumerate(sorted(names)):
            for b in sorted(names)[i + 1:]:
                k = stable_seed(seed, "wan-class", a, b) % len(WAN_CLASSES)
                profiles[(a, b)] = WAN_CLASSES[k]
        return cls(profiles, telemetry=telemetry)

    def profile(self, src: str, dst: str) -> WanProfile:
        key = (src, dst) if src <= dst else (dst, src)
        try:
            return self._profiles[key]
        except KeyError:
            raise KeyError(f"no WAN profile for region pair {key}") from None

    def link(self, src: str, dst: str) -> WanLink:
        """The directed link ``src -> dst`` (created on first use)."""
        assert src != dst, "intra-region traffic never touches the WAN"
        key = (src, dst)
        lk = self._links.get(key)
        if lk is None:
            lk = WanLink(src, dst, self.profile(src, dst),
                         telemetry=self.telemetry)
            if self._loop is not None:
                lk.attach_loop(self._loop)
            self._links[key] = lk
        return lk

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop: EventLoop) -> None:
        self._loop = loop
        for lk in self._links.values():
            lk.attach_loop(loop)

    def detach_loop(self) -> None:
        self._loop = None
        for lk in self._links.values():
            lk.detach_loop()

    # -------------------------------------------------------------- ledgers
    def total_bytes(self) -> int:
        return sum(lk.bytes_total for lk in self._links.values())

    def bytes_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for lk in self._links.values():
            for kind, n in lk.by_kind.items():
                out[kind] = out.get(kind, 0) + n
        return {k: out[k] for k in sorted(out)}

    def ledger(self) -> dict:
        """Per-link byte totals keyed ``src->dst`` (sorted, stable)."""
        rows = {f"{s}->{d}": lk.bytes_total
                for (s, d), lk in self._links.items()}
        return {k: rows[k] for k in sorted(rows)}
