"""A Region: one geographic cluster with its own price sheet and spot tier.

Each region wraps a full :class:`repro.cluster.Cluster` — hosts, placer,
gateway, recovery ladders, autoscaler hooks — and adds the geo-layer
state the federation routes on:

- **regional price sheet** — every host's Table-1 price is scaled by the
  region's ``price_multiplier`` (regional market premium/discount);
- **spot/preemptible tier** — the last ``ceil(spot_frac * n_hosts)``
  hosts are spot: priced at ``spot_discount`` of the regional rate, but
  their runners carry a per-step ``preempt_rate`` (the
  ``FaultType.PREEMPT`` fault class). A reclaimed VM aborts its episode;
  the state manager recovers the replica at L2 (fresh respawn from the
  base image — the allocation is *gone*, an in-place L1 repair is
  meaningless) and the rollout engine's failover re-dispatches the task,
  possibly onto another host or, via federation spill, another region;
- **brownout flag** — ``dark`` marks a regional network partition: the
  federated gateway stops routing to the region, whatever its pools'
  local health machinery says. The flag models unreachability, not
  destruction — local heal daemons keep running, and clearing the flag
  restores the region's capacity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.cluster.cluster import DEFAULT_MACHINE, Cluster
from repro.cluster.host import Host
from repro.core.faults import spot_rates
from repro.core.orchestrator import MachineSpec
from repro.core.replica import LatencyModel
from repro.core.telemetry import Telemetry


@dataclass
class RegionSpec:
    """Declarative shape of one region in a federation."""

    name: str
    n_replicas: int
    runners_per_node: int = 32
    machine: Optional[MachineSpec] = None   # default: Table-1 E5-2699
    price_multiplier: float = 1.0           # regional market scale
    spot_frac: float = 0.0                  # fraction of hosts on spot
    spot_discount: float = 0.35             # spot price vs regional rate
    preempt_rate: float = 0.002             # per-step reclaim probability
    routing: str = "least_loaded"
    seed: Optional[int] = None              # default: derived by Federation
    node_prefix: Optional[str] = None       # default: "<name>:node"


class Region:
    """One live cluster plus the federation-facing geo state."""

    def __init__(self, spec: RegionSpec, *, seed: int,
                 telemetry: Optional[Telemetry] = None,
                 latency: Optional[LatencyModel] = None,
                 faults: bool = True):
        self.spec = spec
        self.name = spec.name
        self.dark = False       # brownout: unreachable to the federation
        machine = spec.machine or DEFAULT_MACHINE
        n_hosts = max(math.ceil(spec.n_replicas / spec.runners_per_node), 1)
        n_spot = min(math.ceil(spec.spot_frac * n_hosts), n_hosts)
        # spot tier at the tail of the host list: the placer fills hosts
        # in order, so on-demand capacity is packed first and the spot
        # hosts are exactly the ones a preemption storm can empty
        self._spot_hosts = {f"host{i}" for i in
                            range(n_hosts - n_spot, n_hosts)}

        def fault_profile(host: Host) -> Optional[dict]:
            if host.host_id in self._spot_hosts:
                return spot_rates(spec.preempt_rate)
            return None

        self.cluster = Cluster(
            [machine] * n_hosts, spec.n_replicas,
            runners_per_node=spec.runners_per_node,
            seed=seed,
            routing=spec.routing,
            node_prefix=(spec.node_prefix or f"{spec.name}:node"),
            faults=faults,
            latency=latency,
            telemetry=telemetry,
            fault_profile=fault_profile if n_spot else None,
        )
        for host in self.cluster.hosts:
            mult = spec.price_multiplier
            if host.host_id in self._spot_hosts:
                mult *= spec.spot_discount
            host.price_multiplier = mult

    # -------------------------------------------------------------- surface
    @property
    def gateway(self):
        return self.cluster.gateway

    @property
    def pools(self):
        return self.cluster.pools

    @property
    def n_replicas(self) -> int:
        return self.cluster.n_replicas

    def is_spot_host(self, host: Host) -> bool:
        return host.host_id in self._spot_hosts

    def reachable(self) -> bool:
        """Routable by the federation: not dark, and at least one node
        the regional gateway still considers healthy."""
        if self.dark:
            return False
        return any(st.healthy for st in self.gateway.status.values())

    def free_runners(self) -> int:
        return sum(p.n_free for p in self.pools)

    def price_per_day(self) -> float:
        return self.cluster.price_per_day()

    def usd_per_replica_day(self) -> float:
        return self.cluster.usd_per_replica_day()

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop) -> None:
        self.cluster.attach_loop(loop)

    def detach_loop(self) -> None:
        self.cluster.detach_loop()

    def close(self) -> None:
        self.cluster.close()
