"""Per-region learner replicas synchronized by DiLoCo outer steps.

Each region runs its own ingest → replay → inner-step loop against
region-local rollouts (a :class:`RegionLearner` wraps one
:class:`~repro.pipeline.learner.LearnerLoop`); every ``H`` inner steps
the regions exchange int8-compressed parameter *deltas* through the
federation's metered WAN links and apply one shared Nesterov outer
update (:mod:`repro.distributed.diloco` math, cross-region instead of
cross-pod).

Two deliberate design points:

- **one trainer, many regions** — every region's learner shares a single
  ``PPOTrainer`` instance and swaps its ``(params, opt_state)`` in and
  out around each step. The jitted train step and the ingest closures
  are pure in those arguments, so N regions cost exactly one XLA
  compilation instead of N.
- **bit-identical anchors** — each region computes its own delta; the
  deltas are averaged once and the *same* outer update is applied to
  every region's anchor. Anchors start identical (one init snapshot) and
  receive identical updates, so after every sync the regions' anchors —
  and their post-sync params — agree bit for bit, with no parameter
  broadcast on the wire beyond the delta exchange itself.

``stream_sync`` is the measured baseline the DiLoCo claim is judged
against: per-inner-step bf16 delta streaming (ring all-reduce bytes),
metered over the same WAN links, kind ``"stream"`` vs ``"diloco"``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.telemetry import Telemetry
from repro.data.replay_buffer import ReplayBuffer
from repro.distributed.collectives import compress_roundtrip
from repro.distributed.diloco import (
    DiLoCoConfig,
    init_outer_state,
    param_count,
)
from repro.federation.wan import WanTopology
from repro.pipeline.learner import LearnerConfig, LearnerLoop
from repro.pipeline.policy_store import PolicyVersionStore


class RegionLearner:
    """One region's learner replica over a shared trainer.

    Holds the region's own ``(params, opt_state)`` and swaps them into
    the shared trainer around each ``LearnerLoop.step()`` — the loop,
    replay buffer, and policy store are region-local; only the compiled
    step is shared."""

    def __init__(self, name: str, trainer, replay: ReplayBuffer,
                 store: PolicyVersionStore, *,
                 cfg: Optional[LearnerConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        self.name = name
        self.trainer = trainer
        self.replay = replay
        self.store = store
        self.loop = LearnerLoop(trainer, replay, store, cfg=cfg,
                                telemetry=telemetry)
        # region-local copies of the shared trainer's initial state: every
        # region starts from the same snapshot (the DiLoCo anchor)
        self.params = jax.tree.map(lambda p: p, trainer.params)
        self.opt_state = trainer.opt.init(self.params)
        self.inner_steps = 0

    def ready(self) -> bool:
        return self.loop.ready()

    def step(self) -> Optional[dict]:
        """One inner step on this region's data, under its own params."""
        self.trainer.params = self.params
        self.trainer.opt_state = self.opt_state
        try:
            metrics = self.loop.step()
        finally:
            self.params = self.trainer.params
            self.opt_state = self.trainer.opt_state
        if metrics is not None:
            self.inner_steps += 1
        return metrics

    def set_params(self, params) -> None:
        """Install post-sync params (inner optimizer state is kept, as in
        DiLoCo: the outer step moves the anchor, not Adam's moments)."""
        self.params = params
        self.store.publish(params)

    def losses(self) -> list[float]:
        return self.loop.losses

    def loss_trend(self) -> dict:
        return self.loop.loss_trend()


class FederatedLearners:
    """The cross-region sync plane over a set of ``RegionLearner``s."""

    def __init__(self, learners: list[RegionLearner], *,
                 cfg: Optional[DiLoCoConfig] = None,
                 wan: Optional[WanTopology] = None,
                 telemetry: Optional[Telemetry] = None):
        assert learners, "need at least one regional learner"
        self.learners = learners
        self.cfg = cfg or DiLoCoConfig()
        self.wan = wan
        self.telemetry = telemetry or Telemetry()
        self.n_params = param_count(learners[0].params)
        # one outer state per region, initialized from each region's own
        # (identical) start params — anchors are bit-identical from step 0
        self.outer = {lr.name: init_outer_state(lr.params)
                      for lr in learners}
        self.syncs = 0

    # ------------------------------------------------------------- metering
    def _meter_ring(self, nbytes_per_region: int, kind: str) -> float:
        """Charge one ring exchange: every region ships its payload to its
        ring neighbor. Returns the slowest link's virtual cost (the
        barrier time of the synchronous exchange)."""
        names = [lr.name for lr in self.learners]
        if self.wan is None or len(names) < 2:
            return 0.0
        worst = 0.0
        for i, src in enumerate(names):
            dst = names[(i + 1) % len(names)]
            cost = self.wan.link(src, dst).send(nbytes_per_region, kind)
            worst = max(worst, cost)
        return worst

    def diloco_bytes_per_region(self) -> int:
        """Wire bytes one region ships per DiLoCo outer sync."""
        return self.n_params * (1 if self.cfg.compress_int8 else 4)

    def stream_bytes_per_region(self) -> int:
        """Wire bytes one region ships per *inner step* under per-step
        delta streaming (ring all-reduce, bf16): the baseline."""
        return 2 * self.n_params * 2

    # ----------------------------------------------------------- sync modes
    def outer_sync(self) -> float:
        """One DiLoCo outer step across regions; returns the WAN barrier
        cost in virtual seconds.

        Per region: ``delta = anchor - params`` (int8 round-tripped when
        ``compress_int8`` — compression error is *inside* the averaged
        quantity, exactly what lands on the wire). Deltas are averaged,
        then every region applies the identical Nesterov outer update to
        its own anchor. Identical anchors + identical updates keep the
        regions' anchors bit-for-bit equal after every sync."""
        cfg = self.cfg
        deltas = []
        for lr in self.learners:
            st = self.outer[lr.name]
            delta = jax.tree.map(
                lambda a, p: a - p.astype(jnp.float32),
                st["anchor"], lr.params)
            if cfg.compress_int8:
                delta = jax.tree.map(compress_roundtrip, delta)
            deltas.append(delta)
        n = float(len(deltas))
        mean = jax.tree.map(lambda *ds: sum(ds) / n, *deltas)
        cost = self._meter_ring(self.diloco_bytes_per_region(), "diloco")
        for lr in self.learners:
            st = self.outer[lr.name]
            m_new = jax.tree.map(
                lambda m, d: cfg.outer_momentum * m + d,
                st["momentum"], mean)
            if cfg.nesterov:
                step_dir = jax.tree.map(
                    lambda d, m: d + cfg.outer_momentum * m, mean, m_new)
            else:
                step_dir = m_new
            anchor_new = jax.tree.map(
                lambda a, s: a - cfg.outer_lr * s, st["anchor"], step_dir)
            self.outer[lr.name] = {"anchor": anchor_new, "momentum": m_new}
            lr.set_params(jax.tree.map(
                lambda a, p: a.astype(p.dtype), anchor_new, lr.params))
        self.syncs += 1
        self.telemetry.count("diloco_outer_syncs")
        return cost

    def stream_sync(self) -> float:
        """Per-step baseline: average raw params across regions every
        inner step, ring all-reduce bytes (bf16 both directions) metered
        per region. Returns the WAN barrier cost."""
        n = float(len(self.learners))
        mean = jax.tree.map(
            lambda *ps: sum(p.astype(jnp.float32) for p in ps) / n,
            *[lr.params for lr in self.learners])
        cost = self._meter_ring(self.stream_bytes_per_region(), "stream")
        for lr in self.learners:
            lr.set_params(jax.tree.map(
                lambda m, p: m.astype(p.dtype), mean, lr.params))
        self.telemetry.count("stream_syncs")
        return cost

    def maybe_sync(self) -> Optional[float]:
        """DiLoCo cadence helper: outer-sync when every region has run
        ``inner_steps`` more inner steps since the last sync."""
        due = (self.syncs + 1) * self.cfg.inner_steps
        if all(lr.inner_steps >= due for lr in self.learners):
            return self.outer_sync()
        return None

    # ------------------------------------------------------------ reporting
    def anchors_equal(self) -> bool:
        """True when every region's anchor is bit-identical (the sync
        invariant the tests pin)."""
        ref = self.outer[self.learners[0].name]["anchor"]
        for lr in self.learners[1:]:
            other = self.outer[lr.name]["anchor"]
            leaves = zip(jax.tree.leaves(ref), jax.tree.leaves(other))
            if not all(bool(jnp.array_equal(a, b)) for a, b in leaves):
                return False
        return True


__all__ = ["RegionLearner", "FederatedLearners", "DiLoCoConfig"]
