"""Geo-distributed federation: a Region layer above the Cluster.

Regions are full clusters with their own price sheets (regional
multipliers, spot/preemptible tiers with mid-episode reclaim); a
``Federation`` routes episodes region-locally with WAN-priced spill on
brownout or exhaustion, ships spilled trajectories home over byte-
metered ``WanLink``s, and synchronizes per-region learner replicas with
DiLoCo outer steps that move ~H× fewer cross-region bytes than per-step
delta streaming. A single-region federation is bit-identical to the
bare ``Cluster`` stack.
"""
from repro.federation.federation import (
    CONTROL_BYTES,
    FederatedGateway,
    Federation,
)
from repro.federation.learner import FederatedLearners, RegionLearner
from repro.federation.region import Region, RegionSpec
from repro.federation.wan import (
    WAN_CLASSES,
    WanLink,
    WanProfile,
    WanTopology,
    trajectory_bytes,
)

__all__ = [
    "CONTROL_BYTES",
    "FederatedGateway",
    "Federation",
    "FederatedLearners",
    "RegionLearner",
    "Region",
    "RegionSpec",
    "WAN_CLASSES",
    "WanLink",
    "WanProfile",
    "WanTopology",
    "trajectory_bytes",
]
