"""Geo-distributed federation: Regions + WAN-aware routing above them.

A :class:`Federation` owns a set of :class:`~repro.federation.region.Region`
clusters and presents the same duck-typed surface the
:class:`~repro.rollout.engine.RolloutEngine` already accepts for a
``Cluster``: ``.gateway`` (acquire/release/attach), ``attach_loop`` /
``detach_loop``, and the optional ``deliver_trajectory`` hook. That makes
geo-distribution a constructor swap — ``RolloutEngine(federation, ...)``
— with no engine changes beyond the hook.

Routing policy (the tentpole's WAN-awareness):

- **episodes stay in-region** — every task has a *home* region (explicit
  assignment via :meth:`assign` / ``task["region"]``, else a stable hash
  of the task id), and the federated acquire tries the home gateway
  first. A task served at home pays zero WAN cost — byte-identical to
  running the home cluster alone.
- **spill on brownout or exhaustion** — routing is decided when the
  acquire arrives: a *dark* home (regional partition) routes to the
  cheapest reachable peer (free capacity preferred; USD/replica-day
  with a deterministic hash tie-break), while a healthy home spills
  only when some peer has *idle* runners at that moment — parking at
  home is free, so burning WAN money to stand in a remote queue is
  never rational. Each cross-region route pays one control-plane round
  trip on the metered WAN; the task then parks on the chosen region's
  condition queue for its full remaining timeout, so a saturated
  federation costs zero polling wakeups.
- **trajectories ship home** — an episode served by a peer region ships
  its finished trajectory back over the WAN (``trajectory_bytes``,
  vec-timer delivery at the transfer's virtual arrival), so the home
  region's learner always ingests its own tasks' data and the bytes are
  metered where they physically flow.

With a single region every call path delegates verbatim to the regional
gateway — same generators, same timeouts, same condition-queue order —
so ``federation=off`` is bit-identical to today's ``Cluster`` stack.
"""
from __future__ import annotations

import hashlib
from typing import Collection, Optional, Sequence

from repro.core.event_loop import EventLoop, Sleep
from repro.core.replica import LatencyModel
from repro.core.runner_pool import Runner
from repro.core.seeding import stable_seed
from repro.core.telemetry import Telemetry
from repro.federation.region import Region, RegionSpec
from repro.federation.wan import WanTopology, trajectory_bytes

# bytes of one cross-region control-plane round trip (acquire RPC,
# lease bookkeeping) — charged per spill attempt
CONTROL_BYTES = 2048


class Federation:
    """Regions + WAN + federated routing, behind a Cluster-shaped surface."""

    def __init__(self, specs: Sequence[RegionSpec], *, seed: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 wan: Optional[WanTopology] = None,
                 latency: Optional[LatencyModel] = None,
                 faults: bool = True,
                 spill_after_vs: float = 5.0,
                 control_bytes: int = CONTROL_BYTES):
        assert specs, "a federation needs at least one region"
        names = [s.name for s in specs]
        assert len(set(names)) == len(names), f"duplicate regions: {names}"
        self.seed = seed
        self.telemetry = telemetry or Telemetry()
        self.spill_after_vs = spill_after_vs
        self.control_bytes = control_bytes
        self.regions = [
            Region(s,
                   seed=(s.seed if s.seed is not None
                         else stable_seed(seed, "region", s.name)),
                   telemetry=self.telemetry, latency=latency, faults=faults)
            for s in specs
        ]
        self._by_name = {r.name: r for r in self.regions}
        self._names = names
        self.wan = wan or WanTopology.seeded(
            names, seed=stable_seed(seed, "wan"), telemetry=self.telemetry)
        self._home_by_task: dict[str, str] = {}
        self._loop: Optional[EventLoop] = None
        self.gateway = FederatedGateway(self)

    # -------------------------------------------------------------- lookup
    def region(self, name: str) -> Region:
        return self._by_name[name]

    def home_region(self, task) -> Region:
        """Resolve a task's home region (dict or task-id string).

        Explicit assignments (:meth:`assign` / :meth:`set_home`) win;
        otherwise a ``task["region"]`` stamp; otherwise a stable hash of
        the task id — the same resolution on the acquire path (which only
        sees the id) and the delivery path (which sees the dict), so a
        task's home never shifts between lease and commit."""
        if isinstance(task, dict):
            tid = task["task_id"]
            name = self._home_by_task.get(tid) or task.get("region")
        else:
            tid = task
            name = self._home_by_task.get(tid)
        if name is None:
            name = self._names[
                stable_seed(self.seed, "home", tid) % len(self._names)]
        return self._by_name[name]

    def set_home(self, task_id: str, region: str) -> None:
        assert region in self._by_name, region
        self._home_by_task[task_id] = region

    def assign(self, tasks: Sequence[dict],
               regions: Optional[Sequence[str]] = None) -> None:
        """Pin tasks' home regions (round-robin over ``regions`` or all
        regions, in order) and stamp ``task["region"]`` for the record."""
        names = list(regions or self._names)
        for i, t in enumerate(tasks):
            name = names[i % len(names)]
            t["region"] = name
            self.set_home(t["task_id"], name)

    def region_of_node(self, node_id: str) -> Region:
        """Owner of a node id, by the longest matching node prefix."""
        best = None
        for r in self.regions:
            prefix = r.cluster.node_prefix
            if node_id.startswith(prefix):
                if best is None or len(prefix) > len(best.cluster.node_prefix):
                    best = r
        if best is None:
            raise KeyError(f"node {node_id!r} belongs to no region")
        return best

    # ------------------------------------------------------------ brownout
    def brownout(self, name: str, *, kill_running: bool = True) -> int:
        """Partition a region: mark it dark and (by default) crash every
        runner it is serving, so in-flight episodes abort and fail over.
        Returns the number of runners crashed."""
        region = self._by_name[name]
        region.dark = True
        self.telemetry.count("region_brownouts")
        killed = 0
        if kill_running:
            for pool in region.pools:
                for r in pool._all.values():
                    r.manager.replica.crash()
                    killed += 1
        return killed

    def restore(self, name: str) -> None:
        """Clear a brownout: the region is routable again (its local heal
        machinery has been repairing crashed runners all along)."""
        self._by_name[name].dark = False
        self.telemetry.count("region_restores")

    # ---------------------------------------------------------- spill order
    def spill_target(self, task_id: str, home: Region, *,
                     require_free: bool) -> Optional[Region]:
        """Cheapest reachable peer region for one spill attempt.

        Peers with free runner capacity always win (cheapest among them
        by USD/replica-day, deterministic per-task hash tie-break so
        equal-priced peers share spill load) — spilling into a queue
        while another region has idle runners would strand capacity.
        When *no* peer has free capacity, ``require_free`` decides:
        demand it (the healthy-home case, where remote queueing can
        never beat parking at home — return None) or fall back to the
        cheapest reachable queue (the dark-home case, where waiting
        somewhere remote is the only option)."""
        def tie(r: Region) -> int:
            h = hashlib.blake2b(f"{task_id}/{r.name}".encode(),
                                digest_size=8).digest()
            return int.from_bytes(h, "little")

        cands = [r for r in self.regions
                 if r is not home and r.reachable()]
        free = [r for r in cands if r.free_runners() > 0]
        if free:
            cands = free
        elif require_free:
            return None
        if not cands:
            return None
        return min(cands, key=lambda r: (round(r.usd_per_replica_day(), 9),
                                         tie(r)))

    # ----------------------------------------------------- trajectory plane
    def deliver_trajectory(self, task: dict, result, traj, commit) -> bool:
        """Rollout-engine hook: route a finished trajectory to its commit.

        Served at home (or no loop attached): return False — the engine
        commits inline, bit-identical to the non-federated path. Served by
        a peer: meter the trajectory over the WAN and schedule the commit
        at its virtual arrival; returns True (the engine must not commit
        inline)."""
        if self._loop is None or not result.nodes:
            return False
        serving = self.region_of_node(result.nodes[-1])
        home = self.home_region(task)
        if serving is home:
            return False
        link = self.wan.link(serving.name, home.name)
        self.telemetry.count("wan_trajectories")
        link.deliver(trajectory_bytes(traj), "traj", commit)
        return True

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop: EventLoop) -> None:
        self._loop = loop
        for r in self.regions:
            r.attach_loop(loop)
        if len(self.regions) > 1:
            # single-region federations never touch the WAN; skipping the
            # timer family keeps the event stream identical to a bare
            # Cluster run
            self.wan.attach_loop(loop)

    def detach_loop(self) -> None:
        for r in self.regions:
            r.detach_loop()
        self.wan.detach_loop()
        self._loop = None

    def close(self) -> None:
        self.detach_loop()
        for r in self.regions:
            r.close()

    # ------------------------------------------------------------- metrics
    @property
    def n_replicas(self) -> int:
        return sum(r.n_replicas for r in self.regions)

    def price_per_day(self) -> float:
        return sum(r.price_per_day() for r in self.regions)

    def replica_seconds(self) -> float:
        return sum(r.cluster.replica_seconds() for r in self.regions)

    def health(self) -> dict:
        return {r.name: {"dark": r.dark,
                         "replicas": r.n_replicas,
                         "free": r.free_runners(),
                         "usd_per_day": round(r.price_per_day(), 2)}
                for r in self.regions}


class FederatedGateway:
    """The Gateway surface the rollout engine drives, federated.

    One region: every method delegates verbatim — same generator, same
    timeout, same position in the regional condition queue — so a
    single-region federation is bit-identical to the bare cluster.
    Multiple regions: home-first acquire with WAN-priced spill."""

    def __init__(self, fed: Federation):
        self.fed = fed

    # pools view: the engine indexes pools[node] for latency_scale
    @property
    def pools(self) -> dict:
        if len(self.fed.regions) == 1:
            return self.fed.regions[0].gateway.pools
        merged = {}
        for r in self.fed.regions:
            merged.update(r.gateway.pools)
        return merged

    @property
    def failovers(self) -> int:
        return sum(r.gateway.failovers for r in self.fed.regions)

    def drain_wait_samples(self) -> list:
        out = []
        for r in self.fed.regions:
            out.extend(r.gateway.drain_wait_samples())
        return out

    # ------------------------------------------------------------- acquire
    def acquire_ev(self, task_id: str, timeout: Optional[float] = 1.0,
                   exclude: Collection[str] = (),
                   tenant: Optional[str] = None,
                   backend: Optional[str] = None):
        """Event-loop acquire: route once, then park — never poll.

        The spill decision is made when the acquire arrives (and again
        only if a park ends without a runner): a *dark* home routes to
        the cheapest reachable peer (free capacity preferred); a healthy
        home spills only when some peer has idle runners at that moment
        — otherwise the task parks on the home region's condition queue
        for the full remaining timeout, exactly like a plain gateway
        acquire, so a saturated-but-healthy federation costs zero extra
        wakeups, zero WAN bytes, and keeps the FIFO handoff on release.
        Each cross-region routing pays one control round trip on the
        metered WAN; every successful spill is counted (global + per
        region pair)."""
        fed = self.fed
        if len(fed.regions) == 1:
            return (yield from fed.regions[0].gateway.acquire_ev(
                task_id, timeout=timeout, exclude=exclude, tenant=tenant,
                backend=backend))
        loop = fed._loop
        assert loop is not None, "attach_loop() before acquire_ev()"
        home = fed.home_region(task_id)
        deadline = None if timeout is None else loop.now + timeout
        while True:
            remaining = None if deadline is None else deadline - loop.now
            if remaining is not None and remaining <= 0:
                return None
            round_t0 = loop.now
            if home.dark:
                target = fed.spill_target(task_id, home, require_free=False)
            elif home.free_runners() > 0:
                target = home
            else:
                # exhaustion spill: home is full right now, so take idle
                # capacity elsewhere if any exists — but never trade the
                # free home queue for a busy peer's queue plus WAN money
                target = fed.spill_target(task_id, home, require_free=True)
                if target is None:
                    target = home
            if target is not None:
                if target is not home:
                    # pay the cross-region control round trip, honestly,
                    # on the virtual clock, then contend remotely
                    link = fed.wan.link(home.name, target.name)
                    cost = link.send(fed.control_bytes, "control")
                    fed.telemetry.count("spill_attempts")
                    if cost > 0:
                        yield Sleep(cost)
                    remaining = (None if deadline is None
                                 else deadline - loop.now)
                    if remaining is not None and remaining <= 0:
                        return None
                got = yield from target.gateway.acquire_ev(
                    task_id, timeout=remaining, exclude=exclude,
                    tenant=tenant, backend=backend)
                if got is not None:
                    if target is not home:
                        fed.telemetry.count("episodes_spilled")
                        fed.telemetry.count(
                            f"episodes_spilled:{home.name}->{target.name}")
                    return got
            if loop.now == round_t0:
                # no virtual time passed (home dark with no reachable
                # peer, or an instant all-unhealthy return): park one
                # spill interval instead of spinning the clock in place
                t = (fed.spill_after_vs if remaining is None
                     else min(fed.spill_after_vs, remaining))
                if t <= 0:
                    return None
                yield Sleep(t)

    def acquire(self, task_id: str, timeout: Optional[float] = 1.0,
                exclude: Collection[str] = (),
                backend: Optional[str] = None):
        """Threaded acquire (parity surface): home first, then reachable
        peers in spill order. No WAN pricing — wall-clock mode has no
        virtual clock to charge; the event path is the measured one."""
        fed = self.fed
        home = fed.home_region(task_id)
        order = [home] if not home.dark else []
        seen = {home.name}
        while True:
            nxt = fed.spill_target(task_id, home, require_free=False)
            if nxt is None or nxt.name in seen:
                break
            order.append(nxt)
            seen.add(nxt.name)
            break  # one spill candidate is enough for the threaded path
        for region in order:
            got = region.gateway.acquire(task_id, timeout=timeout,
                                         exclude=exclude, backend=backend)
            if got is not None:
                return got
        return None

    # ------------------------------------------------------------- release
    def release(self, node: str, runner: Runner, **kw) -> float:
        return self.fed.region_of_node(node).gateway.release(
            node, runner, **kw)

    # ----------------------------------------------------------- lifecycle
    def attach_loop(self, loop: EventLoop, **kw) -> None:
        # engines holding only the gateway still bind the whole federation
        self.fed.attach_loop(loop)

    def detach_loop(self) -> None:
        self.fed.detach_loop()

    def stop(self) -> None:
        for r in self.fed.regions:
            r.gateway.stop()

    def check_now(self) -> dict:
        report = {}
        for r in self.fed.regions:
            report.update(r.gateway.check_now())
        return report

    def healthy_nodes(self) -> list[str]:
        out = []
        for r in self.fed.regions:
            if not r.dark:
                out.extend(r.gateway.healthy_nodes())
        return out
