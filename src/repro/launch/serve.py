"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched prefill+decode with the ServeEngine (reduced configs on CPU; full
configs are exercised via the dry-run decode/prefill cells)."""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config, get_reduced, list_arch_ids
from repro.models import build_model
from repro.serve import ServeEngine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_arch_ids())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(8, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    frames = None
    if cfg.frontend != "none":
        frames = rng.standard_normal(
            (args.batch, 8, cfg.frontend_dim)).astype(np.float32)

    t0 = time.time()
    out = engine.generate(prompts, frames,
                          cfg=ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature))
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"decoded {out['decode_steps']} steps in {dt:.2f}s "
          f"({args.batch * out['decode_steps'] / dt:.1f} tok/s)")
    print("sample token ids:", out["sequences"][0, -args.max_new:].tolist())


if __name__ == "__main__":
    main()
