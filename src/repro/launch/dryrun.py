import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
# cell with ShapeDtypeStruct stand-ins (no allocation), record
# memory_analysis() / cost_analysis(), and parse the partitioned HLO for
# per-device collective bytes. This is the proof that the distribution config
# is coherent, and the source of every §Roofline number.
#
# FLOPs accounting: XLA's cost_analysis counts a while-loop body ONCE,
# regardless of trip count, and our models scan over layers (and gradient
# accumulation scans over microbatches). Fully unrolling for the dry-run is
# compile-time-prohibitive at 512 devices, so each cell additionally lowers
# tiny "correction modules" (one layer-period body; one microbatch grad) and
# combines:   T = R_full + (mb-1)*R_mb + mb*(n_blocks-1)*R_layer
# (exact by linearity; same combination applies to HLO bytes and collective
# bytes). Memory analysis comes from the full rolled module — that is the
# buffer assignment that would really execute.

import argparse
import dataclasses
import gc
import json
import re
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (get_config, get_reduced, get_shape, list_arch_ids,
                           SHAPES, shape_applicable)
from repro.configs.shapes import input_specs, cache_len, frontend_len
from repro.distributed.sharding import (train_rules, serve_rules,
                                        configure_moe, tree_shardings,
                                        tree_pspecs, AxisRules)
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.kernels import ref as kernels_ref
from repro.models import build_model
from repro.models import attention as attn_mod
from repro.models import blocks as blk
from repro.models.param import param_shapes, param_axes
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_step, make_grad_fn

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# TPU v5e constants (assignment-specified)
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9,
      "hbm_bytes": 16e9}

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo: str) -> dict:
    """Per-device collective wire bytes from the partitioned HLO."""
    out = {k: 0.0 for k in _COLL_FACTOR}
    counts = {k: 0 for k in _COLL_FACTOR}
    for line in hlo.splitlines():
        for op, factor in _COLL_FACTOR.items():
            if f" {op}(" not in line and f" {op}-start(" not in line:
                continue
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                continue
            result = lhs[1].split(op, 1)[0]
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(result):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            out[op] += nbytes * factor
            counts[op] += 1
            break
    return {"per_device_bytes": out, "counts": counts,
            "total_per_device": sum(out.values())}


def pick_microbatches(cfg, shape, n_chips: int,
                      target: Optional[float] = None) -> int:
    """Bound per-device activation memory: saved residuals across the layer
    scan plus the f32 logits + CE temporaries of one microbatch."""
    if target is None:
        target = 0.6e9 if cfg.param_count() >= 1e11 else 1.5e9
    per_token = (cfg.n_layers * cfg.d_model * 2       # saved residuals (bf16)
                 + cfg.vocab_size * 6)                # logits f32 + CE temps
    act = shape.tokens * per_token / n_chips
    mb = 1
    while act / mb > target and mb < shape.global_batch:
        mb *= 2
    while shape.global_batch % mb:
        mb //= 2
    return max(mb, 1)


def batch_shardings(rules: AxisRules, specs: dict):
    out = {}
    for k, v in specs.items():
        logical = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = rules.sharding(v.shape, logical)
    return out


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 new token


# -------------------------------------------------------- correction modules
def _period_specs(model, cfg):
    return {f"l{j}": blk.block_spec(cfg, model.prefix_len + j,
                                    cross=model.is_encdec)
            for j in range(model.period)}


def lower_layer_module(model, cfg, rules, *, mode: str, batch: int, seq: int,
                       cache_size: int = 0, enc_len: int = 0,
                       remat: Optional[str] = None):
    """One scan-period of layers, standalone: mode train (fwd+bwd), fwd, or
    decode. Its cost_analysis gives the exact per-body FLOPs/bytes/collective
    contribution that the rolled scan hides."""
    spec = _period_specs(model, cfg)
    pshapes = param_shapes(spec, cfg.dtype)
    pshard = tree_shardings(rules, pshapes, param_axes(spec))
    D = cfg.d_model

    def chain(bp, x, enc_out=None):
        aux = jnp.zeros((), jnp.float32)
        for j in range(model.period):
            i = model.prefix_len + j
            enc_kv = (attn_mod.cross_kv(bp[f"l{j}"]["cross"], cfg, enc_out)
                      if model.is_encdec else None)
            x, a = blk.block_apply(bp[f"l{j}"], cfg, i, x, rules=rules,
                                   enc_kv=enc_kv)
            aux = aux + a
        return x, aux

    if mode in ("train", "fwd"):
        xs = jax.ShapeDtypeStruct((batch, seq, D), jnp.bfloat16)
        xsh = rules.sharding(xs.shape, ("batch", None, None))
        args, shards = [pshapes, xs], [pshard, xsh]
        if model.is_encdec:
            es = jax.ShapeDtypeStruct((batch, enc_len, D), jnp.bfloat16)
            args.append(es)
            shards.append(rules.sharding(es.shape, ("batch", None, None)))

        if mode == "fwd":
            fn = lambda bp, x, *e: chain(bp, x, *e)[0]
        else:
            body = chain if remat is None else jax.checkpoint(chain)

            def scalar(bp, x, *e):
                y, aux = body(bp, x, *e)
                return jnp.sum(y.astype(jnp.float32)) + aux
            fn = jax.grad(scalar, argnums=(0, 1))
        return jax.jit(fn, in_shardings=tuple(shards)).lower(*args)

    # decode
    cshapes = {f"l{j}": blk.block_cache_shapes(cfg, model.prefix_len + j,
                                               batch, cache_size)
               for j in range(model.period)}
    caxes = {f"l{j}": blk.block_cache_axes(cfg, model.prefix_len + j)
             for j in range(model.period)}
    cshard = tree_shardings(rules, cshapes, caxes)
    xs = jax.ShapeDtypeStruct((batch, 1, D), jnp.bfloat16)
    xsh = rules.sharding(xs.shape, ("batch", None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = [pshapes, cshapes, xs, pos]
    shards = [pshard, cshard, xsh, None]
    if model.is_encdec:
        hd = cfg.resolved_head_dim
        ekv = jax.ShapeDtypeStruct((batch, enc_len, cfg.n_kv_heads, hd),
                                   jnp.bfloat16)
        esh = rules.sharding(ekv.shape,
                             ("cache_batch", "cache_seq", "cache_kv", None))
        args += [ekv, ekv]
        shards += [esh, esh]

    def dec(bp, caches, x, pos, *ekv):
        new = {}
        for j in range(model.period):
            i = model.prefix_len + j
            x, c = blk.block_decode(bp[f"l{j}"], cfg, i, x, caches[f"l{j}"],
                                    pos, rules=rules,
                                    enc_kv=(ekv if ekv else None))
            new[f"l{j}"] = c
        return x, new

    return jax.jit(dec, in_shardings=tuple(shards),
                   donate_argnums=(1,)).lower(*args)


def lower_enc_module(model, cfg, rules, *, batch: int, enc_len: int,
                     with_grad: bool, remat: Optional[str] = None):
    spec = {"l0": blk.block_spec(cfg, 0)}
    pshapes = param_shapes(spec, cfg.dtype)
    pshard = tree_shardings(rules, pshapes, param_axes(spec))
    xs = jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model), jnp.bfloat16)
    xsh = rules.sharding(xs.shape, ("batch", None, None))

    def chain(bp, x):
        y, _ = blk.block_apply(bp["l0"], cfg, 0, x, causal=False)
        return y

    if not with_grad:
        return jax.jit(chain, in_shardings=(pshard, xsh)).lower(pshapes, xs)
    body = chain if remat is None else jax.checkpoint(chain)
    scalar = lambda bp, x: jnp.sum(body(bp, x).astype(jnp.float32))
    return jax.jit(jax.grad(scalar, argnums=(0, 1)),
                   in_shardings=(pshard, xsh)).lower(pshapes, xs)


def lower_mb_grad(model, cfg, rules, specs, mb: int, remat, pshard, pshapes,
                  ppspecs=None):
    """value_and_grad of the loss at microbatch size (rolled layer scan)."""
    tc = TrainConfig(microbatches=1, remat=remat)
    grad_fn = make_grad_fn(model, rules, tc, param_pspecs=ppspecs)
    mb_specs = {k: jax.ShapeDtypeStruct((v.shape[0] // mb,) + v.shape[1:],
                                        v.dtype)
                for k, v in specs.items()}
    bshard = batch_shardings(rules, mb_specs)
    return jax.jit(grad_fn,
                   in_shardings=(pshard, bshard)).lower(pshapes, mb_specs)


def _cost_of(lowered) -> dict:
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes": float(ca.get("bytes accessed", 0.0)),
           "coll": coll["total_per_device"],
           "coll_by_op": coll["per_device_bytes"],
           "coll_counts": coll["counts"]}
    del compiled
    gc.collect()
    return out


# ------------------------------------------------------------------- cells
def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               reduced: bool = False, overrides: Optional[dict] = None):
    cfg = get_reduced(arch_id) if reduced else get_config(arch_id)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_chips(mesh)
    model = build_model(cfg)
    over = overrides or {}
    # Chunk-scan unrolling: OFF for the main module (its memory_analysis is
    # the deliverable — rolled scans are what would really execute), ON for
    # the correction modules so cost_analysis sees every attention/SSD chunk.
    kernels_ref.SCAN_UNROLL = False

    pshapes = model.param_shapes()
    paxes = model.param_logical_axes()
    specs = input_specs(cfg, shape)
    nb = model.n_blocks
    corrections = []   # (multiplier, lowered)

    if over.get("moe_ep") and cfg.moe is not None:
        # expert-parallel variant: tokens move (shard_map all_to_all),
        # expert weights stay put. Storage may split the hidden dim so the
        # (expert, slice) dim exactly covers the data axis (grok: 8e x 2).
        R = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
        fs = R // cfg.moe.n_experts if cfg.moe.n_experts < R else 1
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, ep_fsplit=max(fs, 1)))
        model = build_model(cfg)
        pshapes = model.param_shapes()
        paxes = model.param_logical_axes()
        specs = input_specs(cfg, shape)

    if shape.kind == "train":
        rules = over.get("rules") or train_rules(
            mesh, wide_fsdp=(cfg.param_count() >= 1e11 and multi_pod))
        if cfg.moe is not None:
            rules = configure_moe(rules, cfg.moe.n_experts)
        if over.get("moe_ep") and cfg.moe is not None:
            rules = rules.with_overrides(
                moe_impl=("ep",), expert=("data",), expert_mlp=("model",))
        mb = over.get("microbatches") or pick_microbatches(cfg, shape, n_chips)
        remat = over.get("remat", "full")
        opt = Optimizer(OptimizerConfig(
            name=over.get("optimizer", "adamw"),
            moment_dtype=("bfloat16" if cfg.param_count() >= 5e10
                          else "float32")))
        tc = TrainConfig(
            microbatches=mb, remat=remat,
            accum_dtype=("bfloat16" if cfg.param_count() >= 1e11
                         else "float32"))
        ppspecs = tree_pspecs(rules, pshapes, paxes)
        step_fn = make_train_step(model, opt, rules, tc,
                                  param_pspecs=ppspecs)
        oshapes = jax.eval_shape(opt.init, pshapes)
        oaxes = opt.state_logical_axes(paxes)
        pshard = tree_shardings(rules, pshapes, paxes)
        oshard = tree_shardings(rules, oshapes, oaxes)
        bshard = batch_shardings(rules, specs)
        jitted = jax.jit(step_fn, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(pshapes, oshapes, specs)
        kernels_ref.SCAN_UNROLL = over.get("unroll_chunks", True)

        b_mb = shape.global_batch // mb
        if mb > 1:
            corrections.append((mb - 1, lower_mb_grad(
                model, cfg, rules, specs, mb, remat, pshard, pshapes,
                ppspecs=ppspecs)))
        if nb > 1:
            corrections.append((mb * (nb - 1), lower_layer_module(
                model, cfg, rules, mode="train", batch=b_mb,
                seq=shape.seq_len, remat=remat,
                enc_len=frontend_len(cfg, shape))))
        if model.is_encdec and cfg.enc_layers > 1:
            corrections.append((mb * (cfg.enc_layers - 1), lower_enc_module(
                model, cfg, rules, batch=b_mb,
                enc_len=frontend_len(cfg, shape), with_grad=True,
                remat=remat)))
        extra = {"microbatches": mb, "optimizer_moments": opt.cfg.moment_dtype}

    elif shape.kind == "prefill":
        rules = over.get("rules") or serve_rules(mesh)
        if cfg.moe is not None:
            rules = configure_moe(rules, cfg.moe.n_experts)
        pshard = tree_shardings(rules, pshapes, paxes)
        bshard = batch_shardings(rules, specs)
        frames = "frames" in specs

        if frames:
            def fn(params, tokens, fr):
                return model.prefill(params, tokens, fr,
                                     cache_size=shape.seq_len, rules=rules)
            args = (pshapes, specs["tokens"], specs["frames"])
            in_sh = (pshard, bshard["tokens"], bshard["frames"])
        else:
            def fn(params, tokens):
                return model.prefill(params, tokens, None,
                                     cache_size=shape.seq_len, rules=rules)
            args = (pshapes, specs["tokens"])
            in_sh = (pshard, bshard["tokens"])
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=None)
        lowered = jitted.lower(*args)
        kernels_ref.SCAN_UNROLL = over.get("unroll_chunks", True)
        if nb > 1:
            corrections.append((nb - 1, lower_layer_module(
                model, cfg, rules, mode="fwd", batch=shape.global_batch,
                seq=(shape.seq_len if cfg.family != "encdec"
                     else shape.seq_len),
                enc_len=frontend_len(cfg, shape))))
        if model.is_encdec and cfg.enc_layers > 1:
            corrections.append((cfg.enc_layers - 1, lower_enc_module(
                model, cfg, rules, batch=shape.global_batch,
                enc_len=frontend_len(cfg, shape), with_grad=False)))
        extra = {}

    else:  # decode
        long = shape.name == "long_500k"
        rules = over.get("rules") or serve_rules(mesh, long_context=long)
        if cfg.moe is not None:
            rules = configure_moe(rules, cfg.moe.n_experts)
        clen = cache_len(cfg, shape)
        enc_len = frontend_len(cfg, shape) if cfg.family == "encdec" else 0
        cshapes = model.cache_shapes(shape.global_batch, clen,
                                     enc_len=enc_len)
        caxes = model.cache_logical_axes()
        pshard = tree_shardings(rules, pshapes, paxes)
        cshard = tree_shardings(rules, cshapes, caxes)
        bshard = batch_shardings(rules, specs)

        def fn(params, cache, token):
            return model.decode_step(params, cache, token, rules=rules)

        jitted = jax.jit(fn, in_shardings=(pshard, cshard, bshard["token"]),
                         out_shardings=(None, cshard), donate_argnums=(1,))
        lowered = jitted.lower(pshapes, cshapes, specs["token"])
        kernels_ref.SCAN_UNROLL = over.get("unroll_chunks", True)
        if nb > 1:
            corrections.append((nb - 1, lower_layer_module(
                model, cfg, rules, mode="decode", batch=shape.global_batch,
                seq=1, cache_size=clen, enc_len=enc_len)))
        extra = {"cache_len": clen}

    # analytic persistent per-device bytes from the actual sharded shapes
    # (exact; immune to XLA:CPU's bf16-via-f32 emulation, which inflates
    # temp_bytes ~2x relative to a real TPU lowering)
    def _per_device(shapes_tree, shard_tree):
        total = 0
        for s, sh in zip(jax.tree.leaves(shapes_tree),
                         jax.tree.leaves(shard_tree)):
            n = 1
            for d in sh.shard_shape(s.shape):
                n *= d
            total += n * s.dtype.itemsize
        return total

    persistent = _per_device(pshapes, pshard)
    if shape.kind == "train":
        persistent += _per_device(oshapes, oshard)
        # accumulated grads live across the microbatch scan
        gmul = 1 if cfg.param_count() >= 1e11 else 2
        persistent += gmul * _per_device(pshapes, pshard)
    elif shape.kind == "decode":
        persistent += _per_device(cshapes, cshard)

    return {"arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "lowered", "lowered": lowered, "cfg": cfg,
            "shape_cfg": shape, "n_chips": n_chips, "extra": extra,
            "persistent_bytes_per_device": persistent,
            "corrections": corrections}


def compile_and_analyze(cell: dict, verbose: bool = True) -> dict:
    if cell["status"] == "skipped":
        return cell
    lowered = cell.pop("lowered")
    corrections = cell.pop("corrections")
    cfg, shape = cell.pop("cfg"), cell.pop("shape_cfg")
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
    }
    mem["peak_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                         + mem["temp_bytes"] - mem["alias_bytes"])
    # XLA:CPU emulates bf16 through f32 (converts inserted around every
    # bf16 op), roughly doubling transients vs a TPU lowering. Adjusted
    # peak = exact persistent bytes + temps discounted by that factor.
    persistent = cell.pop("persistent_bytes_per_device", 0)
    transient = max(mem["peak_bytes"] - persistent, 0)
    mem["persistent_bytes"] = persistent
    mem["tpu_adjusted_peak_bytes"] = int(persistent + transient * 0.5)
    ca = compiled.cost_analysis() or {}
    base = {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}
    coll0 = collective_bytes(compiled.as_text())
    del compiled
    gc.collect()

    flops = base["flops"]
    hbytes = base["bytes"]
    cbytes = coll0["total_per_device"]
    coll_by_op = dict(coll0["per_device_bytes"])
    coll_counts = dict(coll0["counts"])
    for mult, low in corrections:
        c = _cost_of(low)
        flops += mult * c["flops"]
        hbytes += mult * c["bytes"]
        cbytes += mult * c["coll"]
        for k, v in c["coll_by_op"].items():
            coll_by_op[k] = coll_by_op.get(k, 0.0) + mult * v
        for k, v in c["coll_counts"].items():
            coll_counts[k] = coll_counts.get(k, 0) + v

    n = cell["n_chips"]
    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": hbytes / HW["hbm_bw"],
        "collective_s": cbytes / HW["link_bw"],
    }
    dominant = max(terms, key=terms.get)
    result = {
        **cell,
        "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "memory": mem,
        "fits_hbm": mem["tpu_adjusted_peak_bytes"] <= HW["hbm_bytes"],
        "fits_hbm_raw_cpu_lowering": mem["peak_bytes"] <= HW["hbm_bytes"],
        "flops_per_device": flops,
        "hlo_bytes_per_device": hbytes,
        "collective_bytes_per_device": cbytes,
        "collectives_by_op": coll_by_op,
        "collective_counts": coll_counts,
        "roofline": {**terms, "dominant": dominant},
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops * n) if flops else 0.0),
    }
    if verbose:
        print(f"[{cell['mesh']}] {cell['arch']} x {cell['shape']}: "
              f"compile {compile_s:.0f}s, peak/dev "
              f"{mem['peak_bytes']/1e9:.2f} GB "
              f"(tpu-adj {mem['tpu_adjusted_peak_bytes']/1e9:.2f}, "
              f"persist {mem['persistent_bytes']/1e9:.2f}), "
              f"compute {terms['compute_s']*1e3:.2f} ms, "
              f"memory {terms['memory_s']*1e3:.2f} ms, "
              f"collective {terms['collective_s']*1e3:.2f} ms "
              f"-> {dominant}; useful-flops "
              f"{result['useful_flops_ratio']:.2f}", flush=True)
    del lowered
    gc.collect()
    return result


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             reduced: bool = False, save: bool = True,
             overrides: Optional[dict] = None, tag: str = "") -> dict:
    cell = lower_cell(arch_id, shape_name, multi_pod=multi_pod,
                      reduced=reduced, overrides=overrides)
    result = compile_and_analyze(cell)
    if result["status"] == "skipped":
        print(f"[{result['mesh']}] {arch_id} x {shape_name}: SKIP "
              f"({result['reason']})", flush=True)
    if save:
        os.makedirs(ARTIFACTS, exist_ok=True)
        mesh_tag = result["mesh"].replace("x", "_")
        suffix = f"-{tag}" if tag else ""
        fn = os.path.join(
            ARTIFACTS, f"{arch_id}--{shape_name}--{mesh_tag}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--reduced", action="store_true",
                    help="use reduced configs (CI smoke)")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_arch_ids()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, reduced=args.reduced,
                             save=not args.no_save)
                except Exception as e:  # a failed cell is a bug to fix
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAILED {arch} x {shape} multi_pod={mp}: {e}",
                          flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}x{s}" for a, s, _, _ in failures))
    print("dry-run complete: all cells lowered + compiled.")


if __name__ == "__main__":
    main()
