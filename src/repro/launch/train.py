"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (executed, not dry-run) training loop for any registered
architecture at an executable scale: the full configs are exercised via the
dry-run; on this CPU container use --reduced (default) for the smoke-scale
variant of the same family. On a TPU cluster the same driver runs the full
config — the mesh/sharding/step code paths are identical.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, list_arch_ids
from repro.data import (ByteTokenizer, encode_trajectory, pack_batches,
                        synthetic_trajectories, PrefetchIterator)
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import train_rules
from repro.models import build_model
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_arch_ids())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU cluster scale)")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    rules = train_rules()          # unbound on 1 device; mesh-bound on TPU
    opt = Optimizer(OptimizerConfig(lr=args.lr, warmup_steps=20,
                                    decay_steps=max(args.steps, 2)))
    tc = TrainConfig(microbatches=args.microbatches, remat=None)
    step_fn = jax.jit(make_train_step(model, opt, rules, tc))

    tok = ByteTokenizer()
    trajs = synthetic_trajectories(64, seed=args.seed, steps_range=(4, 8))
    enc = [encode_trajectory(t, tok, cfg.vocab_size) for t in trajs]

    def batches():
        while True:
            yield from pack_batches(enc, batch=args.batch, seq_len=args.seq,
                                    seed=args.seed)

    it = PrefetchIterator(batches())
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    ckpt = CheckpointManager(keep=2)

    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == 1:
            dt = time.time() - t0
            tps = step * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tps:,.0f}")
        if step % args.checkpoint_every == 0:
            stats = ckpt.save(step, {"params": params, "opt": opt_state})
            print(f"  checkpoint @{step}: {stats['logical_bytes']/1e6:.1f} MB "
                  f"logical, +{stats['new_physical_bytes']/1e6:.1f} MB "
                  f"physical (dedup)")
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
