"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 v5e chips, axes
(data, model). Multi-pod: 2 pods = 512 chips, axes (pod, data, model) —
the pod axis crosses the inter-pod (DCN-class) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
