"""Byte-level tokenizer with specials for trajectory structure.

The paper's SFT data is `instruction -> screenshot_1 -> thought_1 ->
action_1 -> ...`; screenshots enter as frontend embeddings (or hashed
placeholder tokens for text-only backbones), everything else is bytes.
"""

from __future__ import annotations

import hashlib

import numpy as np

PAD, BOS, EOS, SEP, IMG = 0, 1, 2, 3, 4
N_SPECIAL = 8
BYTE_OFFSET = N_SPECIAL


class ByteTokenizer:
    vocab_size = 256 + N_SPECIAL

    def encode(self, text: str) -> list[int]:
        return [b + BYTE_OFFSET for b in text.encode("utf-8")]

    def decode(self, ids) -> str:
        bs = bytes(
            max(0, min(255, int(i) - BYTE_OFFSET))
            for i in ids
            if int(i) >= BYTE_OFFSET
        )
        return bs.decode("utf-8", errors="replace")


def screenshot_tokens(
    obs: np.ndarray, n_tokens: int = 16, vocab_size: int = 264
) -> list[int]:
    """Hash a screenshot into placeholder observation tokens (text-only
    backbones); VLM backbones get real patch embeddings instead."""
    h = hashlib.blake2b(
        np.ascontiguousarray(obs).tobytes(), digest_size=2 * n_tokens
    ).digest()
    lo = N_SPECIAL
    span = max(vocab_size - lo, 1)
    return [
        lo + (int.from_bytes(h[2 * i : 2 * i + 2], "little") % span)
        for i in range(n_tokens)
    ]
