"""Trajectory data pipeline: data-server episodes -> packed token batches.

Sequence layout per the paper (§4.2): instruction, then per step
[IMG screenshot-tokens SEP thought-bytes SEP action-bytes]; the loss mask is
1 on thought/action tokens and 0 on instruction/screenshot tokens (the model
is conditioned on them, not trained to produce them).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.data.tokenizer import BOS, EOS, IMG, SEP, ByteTokenizer, screenshot_tokens


@dataclass
class TrajectoryStep:
    observation: np.ndarray
    thought: str
    action: str


@dataclass
class Trajectory:
    task_id: str
    instruction: str
    steps: list[TrajectoryStep]
    score: float = 0.0
    # originating task dict (TaskSpec.to_dict shape): carries the scenario
    # name and horizon downstream so the online pipeline can shape rewards
    # per family without re-deriving the task from the id
    task: Optional[dict] = None


def encode_trajectory(
    traj: Trajectory,
    tok: ByteTokenizer,
    vocab_size: int,
    obs_tokens: int = 16,
    return_step_ends: bool = False,
):
    """Returns (token_ids, loss_mask)[, step_ends].

    ``step_ends`` (opt-in) holds, per environment step, the index of the
    token that completes that step's action — the position the online RL
    ingest credits step rewards to."""
    ids: list[int] = [BOS] + tok.encode(traj.instruction)
    mask: list[int] = [0] * len(ids)
    step_ends: list[int] = []
    for st in traj.steps:
        img = [IMG] + screenshot_tokens(st.observation, obs_tokens, vocab_size)
        ids += img
        mask += [0] * len(img)
        for text in (st.thought, st.action):
            seg = [SEP] + tok.encode(text)
            ids += seg
            mask += [0] + [1] * (len(seg) - 1)
        step_ends.append(len(ids) - 1)
    ids.append(EOS)
    mask.append(1)
    ids = [min(i, vocab_size - 1) for i in ids]
    out = (np.asarray(ids, np.int32), np.asarray(mask, np.float32))
    return out + (step_ends,) if return_step_ends else out


def pad_stack(rows, *, width: Optional[int] = None, dtype=np.float32) -> np.ndarray:
    """Zero-pad variable-length 1-D rows to a common width and stack them
    into one contiguous ``(len(rows), width)`` block — the building move
    for micro-batched ingest flushes and the SoA replay arena."""
    width = width if width is not None else max((len(r) for r in rows), default=0)
    out = np.zeros((len(rows), width), dtype)
    for i, r in enumerate(rows):
        n = min(len(r), width)
        out[i, :n] = r[:n]
    return out


def pack_batches(
    encoded: list[tuple[np.ndarray, np.ndarray]],
    *,
    batch: int,
    seq_len: int,
    seed: int = 0,
) -> Iterator[dict]:
    """Greedy sequence packing into fixed (batch, seq_len) training batches.

    Yields {"tokens", "targets", "mask"}: next-token prediction with the
    mask shifted alongside the targets."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(encoded))
    stream_ids: list[int] = []
    stream_mask: list[float] = []
    rows_t, rows_m = [], []
    need = seq_len + 1
    for idx in order:
        ids, mask = encoded[idx]
        stream_ids.extend(ids.tolist())
        stream_mask.extend(mask.tolist())
        while len(stream_ids) >= need:
            chunk = np.asarray(stream_ids[:need], np.int32)
            cmask = np.asarray(stream_mask[:need], np.float32)
            del stream_ids[:seq_len], stream_mask[:seq_len]
            rows_t.append(chunk)
            rows_m.append(cmask)
            if len(rows_t) == batch:
                t = np.stack(rows_t)
                m = np.stack(rows_m)
                yield {"tokens": t[:, :-1], "targets": t[:, 1:], "mask": m[:, 1:]}
                rows_t, rows_m = [], []


def synthetic_trajectories(n: int, *, seed: int = 0, steps_range=(10, 25)):
    """Deterministic synthetic demonstrations (offline smoke/bench data)."""
    rng = np.random.default_rng(seed)
    out = []
    actions = [
        "click(120, 80)",
        "type('hello')",
        "scroll(-3)",
        "key('ctrl+s')",
        "drag(10,10,50,60)",
    ]
    for i in range(n):
        n_steps = int(rng.integers(*steps_range))
        steps = []
        for _ in range(n_steps):
            obs = rng.integers(0, 256, (48, 64, 3), np.uint8)
            planned = actions[int(rng.integers(len(actions)))]
            steps.append(
                TrajectoryStep(
                    observation=obs,
                    thought=f"I should {planned[:-1]} next",
                    action=actions[int(rng.integers(len(actions)))],
                )
            )
        out.append(
            Trajectory(
                f"task-{i}", f"Complete workflow #{i}", steps, float(rng.random())
            )
        )
    return out


class PrefetchIterator:
    """Background-thread prefetch so the accelerator never waits on packing."""

    def __init__(self, it: Iterator[dict], depth: int = 4):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()

        def worker():
            for x in it:
                self._q.put(x)
            self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
