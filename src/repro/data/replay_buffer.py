"""Replay buffer for the semi-online asynchronous RL pipeline (§4.2):
rollout workers append experiences while the learner samples batches —
producers and consumers are decoupled exactly as in the paper.

Two storage backends sit behind one dict-shaped API:

- ``backend="list"`` — a deque of sample dicts holding any payload. This
  is the bit-exact oracle; SFT and offline callers keep using it
  unchanged.
- ``backend="soa"`` — a packed structure-of-arrays ring arena:
  contiguous ``(capacity, seq_len)`` numpy planes for tokens / actions /
  action_mask / rewards / old_logp / values plus 1-D version /
  ingest_wall / length columns. ``extend`` and ``extend_columns`` write
  one vectorized block per plane under a single lock acquisition,
  ``sample_columns`` gathers stacked arrays with one fancy-index per
  plane (no per-sample Python work), and ``prune_where`` compacts with
  one boolean gather. Non-array payload keys (task ids, ``tokens_full``,
  scores, …) ride in a per-slot meta list so ``sample()`` still returns
  complete dicts.

Both backends preserve logical FIFO order (oldest → newest), evict
oldest-first on overflow, and draw sampling indices from the same seeded
generator — the equivalences ``tests/test_dataplane.py`` locks down.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Optional, Sequence, Union

import numpy as np

# (plane, dtype) for the packed per-token arenas; rows are zero-padded to
# the arena width beyond each sample's ``length``.
ARENA_PLANES = (
    ("tokens", np.int32),
    ("actions", np.int32),
    ("action_mask", np.float32),
    ("rewards", np.float32),
    ("old_logp", np.float32),
    ("values", np.float32),
)
ARENA_PLANE_KEYS = frozenset(name for name, _ in ARENA_PLANES)
# sample keys stored in dedicated 1-D columns rather than the meta list
ARENA_SCALAR_KEYS = frozenset({"version", "ingest_wall"})


class ReplayBuffer:
    def __init__(
        self,
        capacity: int = 4096,
        seed: int = 0,
        *,
        backend: str = "list",
        seq_len: Optional[int] = None,
    ):
        assert backend in ("list", "soa"), backend
        self.backend = backend
        self.capacity = int(capacity)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.total_added = 0
        self.total_pruned = 0
        if backend == "list":
            self._buf: deque = deque(maxlen=capacity)
        else:
            if seq_len is None:
                raise ValueError("backend='soa' requires seq_len")
            self._S = int(seq_len)
            self._planes = {
                name: np.zeros((self.capacity, self._S), dt)
                for name, dt in ARENA_PLANES
            }
            self._version_col = np.zeros(self.capacity, np.int64)
            self._wall_col = np.zeros(self.capacity, np.float64)
            self._length_col = np.zeros(self.capacity, np.int64)
            self._meta: list = [None] * self.capacity
            self._head = 0
            self._n = 0

    # ------------------------------------------------------------ appending
    def add(self, item: Any) -> None:
        with self._lock:
            self._append_items([item])

    def extend(self, items) -> None:
        """Bulk insert: one lock acquisition, one block write per plane."""
        items = list(items)
        if not items:
            return
        with self._lock:
            self._append_items(items)

    def extend_columns(
        self,
        columns: dict,
        lengths: Sequence[int],
        metas: Sequence[Optional[dict]],
    ) -> None:
        """Bulk insert from pre-stacked columns (the micro-batched ingest
        fast path). ``columns`` holds the six ``(k, seq_len)`` planes plus
        1-D ``version`` / ``ingest_wall``; rows must be zero beyond each
        row's length. The list backend slices the columns back into
        per-sample dicts, so either backend observes identical samples."""
        k = len(metas)
        if k == 0:
            return
        lengths = np.asarray(lengths, np.int64)
        with self._lock:
            if self.backend == "soa":
                self._soa_append_columns(columns, lengths, metas, k)
                self.total_added += k
                return
            items = []
            for i in range(k):
                L = int(lengths[i])
                it = dict(metas[i] or {})
                it["version"] = int(columns["version"][i])
                it["ingest_wall"] = float(columns["ingest_wall"][i])
                for name, _ in ARENA_PLANES:
                    # copy: the ingest flush reuses its column buffers
                    it[name] = columns[name][i, :L].copy()
                items.append(it)
            self._buf.extend(items)
            self.total_added += k

    def _append_items(self, items: list) -> None:
        if self.backend == "list":
            self._buf.extend(items)
            self.total_added += len(items)
            return
        k = len(items)
        columns = {name: np.zeros((k, self._S), dt) for name, dt in ARENA_PLANES}
        columns["version"] = np.zeros(k, np.int64)
        columns["ingest_wall"] = np.zeros(k, np.float64)
        lengths = np.zeros(k, np.int64)
        metas: list = [None] * k
        for i, it in enumerate(items):
            if not isinstance(it, dict) or "tokens" not in it:
                raise TypeError(
                    "backend='soa' stores RL sample dicts with a 'tokens' "
                    f"array; got {type(it).__name__}"
                )
            L = len(it["tokens"])
            if L > self._S:
                raise ValueError(f"sample length {L} exceeds arena seq_len {self._S}")
            lengths[i] = L
            for name, _ in ARENA_PLANES:
                row = it.get(name)
                if row is not None:
                    columns[name][i, : len(row)] = row
            columns["version"][i] = int(it.get("version", 0))
            columns["ingest_wall"][i] = float(it.get("ingest_wall", 0.0))
            metas[i] = {
                key: v
                for key, v in it.items()
                if key not in ARENA_PLANE_KEYS and key not in ARENA_SCALAR_KEYS
            }
        self._soa_append_columns(columns, lengths, metas, k)
        self.total_added += k

    def _soa_append_columns(self, columns, lengths, metas, k: int) -> None:
        cap = self.capacity
        if k > cap:  # only the newest ``capacity`` rows can survive
            columns = {name: col[-cap:] for name, col in columns.items()}
            lengths = lengths[-cap:]
            metas = metas[-cap:]
            k = cap
        start = (self._head + self._n) % cap
        slots = (start + np.arange(k)) % cap
        for name, _ in ARENA_PLANES:
            col = np.asarray(columns[name])
            if col.shape[1] != self._S:
                raise ValueError(
                    f"column {name!r} width {col.shape[1]} != arena {self._S}"
                )
            self._planes[name][slots] = col[:k]
        self._version_col[slots] = np.asarray(columns["version"], np.int64)[:k]
        self._wall_col[slots] = np.asarray(columns["ingest_wall"], np.float64)[:k]
        self._length_col[slots] = np.minimum(lengths[:k], self._S)
        for i, slot in enumerate(slots):
            self._meta[slot] = metas[i]
        overflow = max(0, self._n + k - cap)
        self._head = (self._head + overflow) % cap
        self._n = min(self._n + k, cap)

    # ------------------------------------------------------------- sampling
    def sample(self, n: int) -> list:
        with self._lock:
            size = self._size_locked()
            if size == 0:
                return []
            idx = self._rng.integers(0, size, size=n)
            if self.backend == "list":
                return [self._buf[i] for i in idx]
            return [self._soa_item(i) for i in idx]

    def sample_columns(self, n: int, *, seq_len: Optional[int] = None):
        """``n`` uniformly drawn samples as stacked columns: the six
        ``(n, S)`` planes plus 1-D ``version`` / ``ingest_wall`` /
        ``length``. One fancy-index gather per plane on the arena backend;
        the list backend pads dict rows out to ``seq_len`` (required
        there) so both return the same shapes. Returns None when empty.

        Consumes exactly one generator draw of size ``n`` — the same
        stream position ``sample`` would use, so scalar and fused learner
        paths pull identical indices."""
        with self._lock:
            size = self._size_locked()
            if size == 0:
                return None
            idx = self._rng.integers(0, size, size=n)
            if self.backend == "soa":
                slots = (self._head + idx) % self.capacity
                cols = {name: self._planes[name][slots] for name, _ in ARENA_PLANES}
                cols["version"] = self._version_col[slots]
                cols["ingest_wall"] = self._wall_col[slots]
                cols["length"] = self._length_col[slots]
                return cols
            if seq_len is None:
                raise ValueError("list backend needs seq_len for sample_columns")
            items = [self._buf[i] for i in idx]
            cols = {name: np.zeros((n, seq_len), dt) for name, dt in ARENA_PLANES}
            cols["version"] = np.zeros(n, np.int64)
            cols["ingest_wall"] = np.zeros(n, np.float64)
            cols["length"] = np.zeros(n, np.int64)
            for i, it in enumerate(items):
                L = min(len(it["tokens"]), seq_len)
                cols["length"][i] = L
                for name, _ in ARENA_PLANES:
                    row = it.get(name)
                    if row is not None:
                        cols[name][i, :L] = row[:L]
                cols["version"][i] = int(it.get("version", 0))
                cols["ingest_wall"][i] = float(it.get("ingest_wall", 0.0))
            return cols

    def versions(self) -> np.ndarray:
        """Per-sample behavior-policy versions in logical (FIFO) order."""
        with self._lock:
            size = self._size_locked()
            if self.backend == "soa":
                slots = (self._head + np.arange(size)) % self.capacity
                return self._version_col[slots].copy()
            return np.asarray(
                [int(it.get("version", 0)) for it in self._buf], np.int64
            )

    def snapshot(self) -> list:
        """Every sample as a dict, in logical (FIFO) order. Array fields
        may be views into backing storage — treat them as read-only. This
        is the parity-audit accessor (``tests/test_dataplane.py`` diffs
        backends row by row with it), not a hot-path API."""
        with self._lock:
            if self.backend == "list":
                return list(self._buf)
            return [self._soa_item(i) for i in range(self._n)]

    def _soa_item(self, i: int) -> dict:
        slot = (self._head + int(i)) % self.capacity
        L = int(self._length_col[slot])
        item = dict(self._meta[slot] or {})
        for name, _ in ARENA_PLANES:
            item[name] = self._planes[name][slot, :L]
        item["version"] = int(self._version_col[slot])
        item["ingest_wall"] = float(self._wall_col[slot])
        return item

    # -------------------------------------------------------------- pruning
    def prune(self, pred: Callable[[Any], bool]) -> int:
        """Drop every item for which ``pred`` is true; returns the count.

        The online learner uses this to evict samples whose policy version
        fell outside the staleness bound — leaving them in place would
        starve the batch sampler with unusable experience."""
        with self._lock:
            if self.backend == "list":
                kept = [it for it in self._buf if not pred(it)]
                dropped = len(self._buf) - len(kept)
                self._buf = deque(kept, maxlen=self.capacity)
                self.total_pruned += dropped
                return dropped
            drop = np.asarray(
                [bool(pred(self._soa_item(i))) for i in range(self._n)], bool
            )
            return self._soa_compact(drop)

    def prune_where(
        self, drop: Union[np.ndarray, Callable[[np.ndarray], np.ndarray]]
    ) -> int:
        """Vectorized prune: ``drop`` is a boolean mask over logical order,
        or a callable mapping the version column to one — evaluated under
        the lock, so the mask cannot race concurrent appends."""
        with self._lock:
            size = self._size_locked()
            if callable(drop):
                if self.backend == "soa":
                    slots = (self._head + np.arange(size)) % self.capacity
                    vers = self._version_col[slots]
                else:
                    vers = np.asarray(
                        [int(it.get("version", 0)) for it in self._buf], np.int64
                    )
                mask = np.asarray(drop(vers), bool)
            else:
                mask = np.zeros(size, bool)
                mask[: len(drop)] = np.asarray(drop, bool)[:size]
            if self.backend == "soa":
                return self._soa_compact(mask)
            kept = [it for it, d in zip(self._buf, mask) if not d]
            dropped = len(self._buf) - len(kept)
            self._buf = deque(kept, maxlen=self.capacity)
            self.total_pruned += dropped
            return dropped

    def _soa_compact(self, drop: np.ndarray) -> int:
        """Gather kept rows to the arena front (one boolean gather per
        plane); logical order is preserved."""
        dropped = int(drop.sum())
        if dropped == 0:
            return 0
        keep_slots = ((self._head + np.flatnonzero(~drop)) % self.capacity).astype(
            np.int64
        )
        m = len(keep_slots)
        for name, _ in ARENA_PLANES:
            plane = self._planes[name]
            plane[:m] = plane[keep_slots]
        self._version_col[:m] = self._version_col[keep_slots]
        self._wall_col[:m] = self._wall_col[keep_slots]
        self._length_col[:m] = self._length_col[keep_slots]
        kept_meta = [self._meta[s] for s in keep_slots]
        self._meta[:m] = kept_meta
        for i in range(m, self._n):
            self._meta[i] = None
        self._head = 0
        self._n = m
        self.total_pruned += dropped
        return dropped

    # ------------------------------------------------------------------ misc
    def _size_locked(self) -> int:
        return len(self._buf) if self.backend == "list" else self._n

    def __len__(self) -> int:
        with self._lock:
            return self._size_locked()
