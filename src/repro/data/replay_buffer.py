"""Replay buffer for the semi-online asynchronous RL pipeline (§4.2):
rollout workers append experiences while the learner samples batches —
producers and consumers are decoupled exactly as in the paper."""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int = 4096, seed: int = 0):
        self._buf: deque = deque(maxlen=capacity)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.total_added = 0
        self.total_pruned = 0

    def add(self, item: Any) -> None:
        with self._lock:
            self._buf.append(item)
            self.total_added += 1

    def extend(self, items) -> None:
        with self._lock:
            for it in items:
                self._buf.append(it)
                self.total_added += 1

    def sample(self, n: int) -> list:
        with self._lock:
            if not self._buf:
                return []
            idx = self._rng.integers(0, len(self._buf), size=n)
            return [self._buf[i] for i in idx]

    def prune(self, pred: Callable[[Any], bool]) -> int:
        """Drop every item for which ``pred`` is true; returns the count.

        The online learner uses this to evict samples whose policy version
        fell outside the staleness bound — leaving them in place would
        starve the batch sampler with unusable experience."""
        with self._lock:
            kept = [it for it in self._buf if not pred(it)]
            dropped = len(self._buf) - len(kept)
            self._buf = deque(kept, maxlen=self._buf.maxlen)
            self.total_pruned += dropped
            return dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)
