"""Replay buffer for the semi-online asynchronous RL pipeline (§4.2):
rollout workers append experiences while the learner samples batches —
producers and consumers are decoupled exactly as in the paper."""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Optional

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int = 4096, seed: int = 0):
        self._buf: deque = deque(maxlen=capacity)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.total_added = 0

    def add(self, item: Any) -> None:
        with self._lock:
            self._buf.append(item)
            self.total_added += 1

    def extend(self, items) -> None:
        with self._lock:
            for it in items:
                self._buf.append(it)
                self.total_added += 1

    def sample(self, n: int) -> list:
        with self._lock:
            if not self._buf:
                return []
            idx = self._rng.integers(0, len(self._buf), size=n)
            return [self._buf[i] for i in idx]

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)
