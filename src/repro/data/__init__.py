from repro.data.pipeline import (
    PrefetchIterator,
    Trajectory,
    TrajectoryStep,
    encode_trajectory,
    pack_batches,
    pad_stack,
    synthetic_trajectories,
)
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer
