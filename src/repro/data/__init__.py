from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import (Trajectory, TrajectoryStep,
                                 encode_trajectory, pack_batches,
                                 synthetic_trajectories, PrefetchIterator)
from repro.data.replay_buffer import ReplayBuffer
