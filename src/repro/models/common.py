"""Norms, activations, RoPE — shared numerics for every architecture."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Spec


# --------------------------------------------------------------------- norms
def norm_spec(cfg: ModelConfig, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": Spec((d,), (None,), "ones", "float32"),
                "bias": Spec((d,), (None,), "zeros", "float32")}
    return {"scale": Spec((d,), (None,), "ones", "float32")}


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMS over the head_dim of (B, S, H, hd)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------- activations
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ----------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
