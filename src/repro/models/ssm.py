"""Mamba2 (SSD) mixer layer: in_proj -> causal conv -> SSD scan -> gated out.

Full-sequence path uses the chunked SSD scan (Pallas kernel on TPU, jnp
oracle elsewhere); the decode path carries an O(1) recurrent state
(conv tail + SSD state) instead of a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules
from repro.kernels import ops
from repro.models.param import Spec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = cfg.d_inner
    nh = cfg.n_ssm_heads
    conv_dim = di + 2 * s.ngroups * s.d_state
    return s, di, nh, conv_dim


def ssm_spec(cfg: ModelConfig) -> dict:
    s, di, nh, conv_dim = _dims(cfg)
    D = cfg.d_model
    # in_proj emits [z(di), xBC(conv_dim), dt(nh)]
    return {
        "w_in": Spec((D, 2 * di + 2 * s.ngroups * s.d_state + nh),
                     ("embed", "ssm_inner"), "scaled"),
        "conv_w": Spec((s.d_conv, conv_dim), (None, "ssm_inner"), "scaled"),
        "conv_b": Spec((conv_dim,), ("ssm_inner",), "zeros", "float32"),
        "A_log": Spec((nh,), (None,), "zeros", "float32"),
        "dt_bias": Spec((nh,), (None,), "zeros", "float32"),
        "D": Spec((nh,), (None,), "ones", "float32"),
        "norm": Spec((di,), (None,), "ones", "float32"),
        "w_out": Spec((di, D), ("ssm_inner", "embed"), "scaled"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s, di, nh, conv_dim = _dims(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + conv_dim]
    dt = zxbcdt[..., di + conv_dim:]
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC: jax.Array):
    s, di, nh, _ = _dims(cfg)
    x = xBC[..., :di]
    B_in = xBC[..., di:di + s.ngroups * s.d_state]
    C_in = xBC[..., di + s.ngroups * s.d_state:]
    return x, B_in, C_in


_UNBOUND = AxisRules()


def ssm_apply(p: dict, cfg: ModelConfig, u: jax.Array, *,
              initial_state: Optional[dict] = None,
              return_state: bool = False,
              rules: AxisRules = _UNBOUND):
    """Full-sequence SSD. u: (B, S, D)."""
    s, di, nh, conv_dim = _dims(cfg)
    B, S, _ = u.shape
    zxbcdt = u @ p["w_in"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    z = rules.constrain(z, "batch", None, "ssm_inner")
    xBC = rules.constrain(xBC, "batch", None, "ssm_inner")
    xBC = ops.causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xBC = rules.constrain(xBC, "batch", None, "ssm_inner")
    x, B_in, C_in = _split_xbc(cfg, xBC)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    xh = x.reshape(B, S, nh, s.head_dim)
    Bm = B_in.reshape(B, S, s.ngroups, s.d_state)
    Cm = C_in.reshape(B, S, s.ngroups, s.d_state)

    out = ops.ssd_scan(xh, dtf, A, Bm, Cm, p["D"], chunk=s.chunk,
                       initial_state=(initial_state or {}).get("ssd"),
                       return_state=return_state)
    if return_state:
        y, ssd_state = out
    else:
        y = out
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["norm"])
    res = y @ p["w_out"]
    if return_state:
        conv_state = xBC_tail(u, p, cfg)
        return res, {"ssd": ssd_state, "conv": conv_state}
    return res


def xBC_tail(u: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Last (d_conv-1) pre-conv xBC inputs — the decode conv state."""
    s, di, nh, conv_dim = _dims(cfg)
    zxbcdt = u[:, -(s.d_conv - 1):] @ p["w_in"]
    _, xBC, _ = _split_proj(cfg, zxbcdt)
    return xBC  # (B, d_conv-1, conv_dim)


def ssm_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    s, di, nh, conv_dim = _dims(cfg)
    return {
        "ssd": jax.ShapeDtypeStruct((batch, nh, s.d_state, s.head_dim),
                                    jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim),
                                     jnp.bfloat16),
    }


def ssm_state_axes(cfg: ModelConfig) -> dict:
    return {"ssd": ("cache_batch", "heads", None, None),
            "conv": ("cache_batch", None, "ssm_inner")}


def ssm_decode(p: dict, cfg: ModelConfig, u: jax.Array, state: dict):
    """One-token SSD update. u: (B, 1, D); state {"ssd","conv"}."""
    s, di, nh, conv_dim = _dims(cfg)
    B = u.shape[0]
    zxbcdt = u[:, 0] @ p["w_in"]
    z, xBC_new, dt = _split_proj(cfg, zxbcdt)

    # causal conv over [conv_state, new]
    window = jnp.concatenate([state["conv"],
                              xBC_new[:, None, :].astype(state["conv"].dtype)],
                             axis=1)                            # (B, d_conv, C)
    conv_out = (jnp.sum(window.astype(jnp.float32)
                        * p["conv_w"].astype(jnp.float32)[None], axis=1)
                + p["conv_b"])
    xBC = jax.nn.silu(conv_out).astype(u.dtype)
    x, B_in, C_in = _split_xbc(cfg, xBC)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, nh, s.head_dim)
    Bm = B_in.reshape(B, s.ngroups, s.d_state)
    Cm = C_in.reshape(B, s.ngroups, s.d_state)
    y, ssd_state = ops.ssd_decode(xh, dtf, A, Bm, Cm, p["D"], state["ssd"])
    y = y.reshape(B, di) * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["norm"])
    res = (y @ p["w_out"])[:, None, :]
    new_conv = window[:, 1:]
    return res, {"ssd": ssd_state, "conv": new_conv}
