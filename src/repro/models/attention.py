"""GQA attention layer (RoPE, qk-norm, sliding window, cross-attention)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import apply_rope, rms_head_norm
from repro.models.param import Spec


def attn_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    spec = {
        "wq": Spec((D, H * hd), ("embed", "q_dim"), "scaled"),
        "wk": Spec((D, KVH * hd), ("embed", "kv_dim"), "scaled"),
        "wv": Spec((D, KVH * hd), ("embed", "kv_dim"), "scaled"),
        "wo": Spec((H * hd, D), ("q_dim", "embed"), "scaled"),
    }
    if cfg.qk_norm:
        spec["q_norm"] = Spec((hd,), (None,), "ones", "float32")
        spec["k_norm"] = Spec((hd,), (None,), "ones", "float32")
    return spec


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array,
                 kv_x: Optional[jax.Array] = None):
    """Returns q (B,S,H,hd), k/v (B,Skv,KVH,hd)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_src = x if kv_x is None else kv_x
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    return q, k, v


def attn_apply(p: dict, cfg: ModelConfig, x: jax.Array, *,
               positions: Optional[jax.Array] = None, causal: bool = True,
               use_rope: bool = True, return_kv: bool = False):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = ops.flash_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window)
    out = o.reshape(B, S, -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def cross_attn_apply(p: dict, cfg: ModelConfig, x: jax.Array,
                     enc_kv: tuple[jax.Array, jax.Array]):
    """Encoder-decoder cross attention; enc_kv precomputed (k, v)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
    k, v = enc_kv
    o = ops.flash_attention(q, k, v, causal=False, window=0)
    return o.reshape(B, S, -1) @ p["wo"]


def cross_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute cross-attention k/v from encoder output."""
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_head_norm(p["k_norm"], k)
    return k, v


def attn_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                pos: jax.Array, *, use_rope: bool = True,
                cross: bool = False):
    """One-token decode. x: (B, 1, D). cache: {"k","v"} (B, Sc, KVH, hd).

    Self-attention writes the new k/v at `pos` (rolling for sliding window);
    cross-attention reads a static cache. Returns (out, new_cache).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    if cross:
        q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
        if cfg.qk_norm:
            q = rms_head_norm(p["q_norm"], q)
        o = ops.decode_attention(q, cache["k"], cache["v"],
                                 cache["k"].shape[1])
        return (o.reshape(B, 1, -1) @ p["wo"]), cache

    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        q = apply_rope(q, pos[None] if pos.ndim == 0 else pos,
                       cfg.rope_theta)
        k = apply_rope(k, pos[None] if pos.ndim == 0 else pos,
                       cfg.rope_theta)
    Sc = cache["k"].shape[1]
    slot = jnp.mod(pos, Sc) if cfg.sliding_window else jnp.minimum(pos, Sc - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cache_len = jnp.minimum(pos + 1, Sc)
    o = ops.decode_attention(q, k_cache, v_cache, cache_len)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}
