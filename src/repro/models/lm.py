"""The language model: embedding, scanned block stack, head, loss, prefill,
decode. One class serves all ten assigned architectures (dense / MoE / SSM /
hybrid / encoder-decoder / multimodal-stub)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules
from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models.common import norm_spec, apply_norm
from repro.models.param import (Spec, init_params, param_shapes, param_axes,
                                stack_specs)

UNBOUND = AxisRules()


def _maybe_remat(fn, policy: Optional[str]):
    if policy is None:
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {policy}")


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.prefix_len, self.period, self.n_blocks = blk.layout(cfg)
        self.is_encdec = cfg.family == "encdec"
        # scan-over-layers unroll factor. 1 = rolled (fast compile; XLA's
        # cost_analysis counts the body once). The dry-run sets this to
        # n_blocks so HLO FLOPs/bytes/collectives reflect the whole stack.
        self.unroll = 1

    def _unroll(self) -> int:
        return max(1, min(self.unroll, self.n_blocks))

    # ------------------------------------------------------------- params
    @property
    def padded_vocab(self) -> int:
        # round up so the vocab dim divides the model axis (TP sharding);
        # pad logits are masked to -inf in _logits
        return -(-self.cfg.vocab_size // 128) * 128

    def param_spec(self) -> dict:
        cfg = self.cfg
        D, V = cfg.d_model, self.padded_vocab
        spec: dict = {
            "embed": Spec((V, D), ("vocab", "lm_embed"), "normal"),
            "final_norm": norm_spec(cfg, D),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = Spec((D, V), ("lm_embed", "vocab"), "scaled")
        if cfg.frontend != "none":
            spec["frontend_proj"] = Spec((cfg.frontend_dim, D),
                                         ("frontend", "embed"), "scaled")
        if self.prefix_len:
            spec["prefix"] = [blk.block_spec(cfg, i)
                              for i in range(self.prefix_len)]
        period_spec = {
            f"l{j}": blk.block_spec(cfg, self.prefix_len + j,
                                    cross=self.is_encdec)
            for j in range(self.period)
        }
        spec["blocks"] = stack_specs(period_spec, self.n_blocks, "layers")
        if self.is_encdec:
            enc_spec = {"l0": blk.block_spec(cfg, 0)}
            spec["enc_blocks"] = stack_specs(enc_spec, cfg.enc_layers, "layers")
            spec["enc_norm"] = norm_spec(cfg, D)
        return spec

    def init(self, key: jax.Array):
        return init_params(key, self.param_spec(), self.cfg.dtype)

    def param_shapes(self):
        return param_shapes(self.param_spec(), self.cfg.dtype)

    def param_logical_axes(self):
        return param_axes(self.param_spec())

    # -------------------------------------------------------------- embed
    def _embed_inputs(self, params, tokens, frames, rules: AxisRules):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.frontend != "none" and frames is not None and not self.is_encdec:
            fx = frames.astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([fx, x], axis=1)
        x = rules.constrain(x, "batch", "seq", "act_embed")
        return x

    def _encode(self, params, frames, rules: AxisRules):
        """Encoder stack over projected frontend frames (encdec only)."""
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16) @ params["frontend_proj"]
        x = rules.constrain(x, "batch", "seq", "act_embed")

        def body(carry, layer_params):
            h, _ = blk.block_apply(layer_params["l0"], cfg, 0, carry,
                                   causal=False)
            h = rules.constrain(h, "batch", "seq", "act_embed")
            return h, None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                            unroll=min(self._unroll(), self.cfg.enc_layers) or 1)
        return apply_norm(params["enc_norm"], x)

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head
        if self.padded_vocab != cfg.vocab_size:
            pad_id = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1)
            logits = jnp.where(pad_id < cfg.vocab_size, logits, -1e30)
        return logits

    # ------------------------------------------------------------ forward
    def forward(self, params, tokens, frames=None, *,
                rules: AxisRules = UNBOUND,
                remat: Optional[str] = None,
                return_hidden: bool = False):
        """Full-sequence logits (training / prefill-without-cache).

        Returns (logits, aux_loss) or (logits, aux_loss, hidden)."""
        cfg = self.cfg
        enc_out = None
        if self.is_encdec:
            enc_out = self._encode(params, frames, rules)
        x = self._embed_inputs(params, tokens, frames, rules)
        aux = jnp.zeros((), jnp.float32)

        for i, p in enumerate(params.get("prefix", [])):
            x, a = blk.block_apply(p, cfg, i, x, rules=rules)
            aux = aux + a

        def body(carry, layer_params):
            h, acc = carry
            a_total = jnp.zeros((), jnp.float32)
            for j in range(self.period):
                i = self.prefix_len + j
                enc_kv = None
                if self.is_encdec:
                    enc_kv = attn.cross_kv(layer_params[f"l{j}"]["cross"],
                                           cfg, enc_out)
                h, a = blk.block_apply(layer_params[f"l{j}"], cfg, i, h,
                                       rules=rules, enc_kv=enc_kv)
                a_total = a_total + a
            h = rules.constrain(h, "batch", "seq", "act_embed")
            return (h, acc + a_total), None

        body = _maybe_remat(body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"],
                                   unroll=self._unroll())
        x = apply_norm(params["final_norm"], x)
        logits = self._logits(params, x)
        logits = rules.constrain(logits, "batch", "seq", "vocab")
        if return_hidden:
            return logits, aux, x
        return logits, aux

    def loss(self, params, batch: dict, *, rules: AxisRules = UNBOUND,
             remat: Optional[str] = None):
        """Masked softmax cross-entropy (+ MoE aux)."""
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("frames"), rules=rules,
                                   remat=remat)
        logits = logits.astype(jnp.float32)
        targets, mask = batch["targets"], batch["mask"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        # target logit via masked reduction (NOT take_along_axis: gathering
        # along the vocab-sharded dim makes GSPMD replicate the logits)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                      axis=-1)
        nll = (logz - tgt) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll) / denom + aux

    # ------------------------------------------------------------ serving
    def cache_shapes(self, batch: int, cache_size: int, enc_len: int = 0):
        cfg = self.cfg
        if cfg.sliding_window:
            cache_size = min(cache_size, cfg.sliding_window)
        shapes: dict = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if self.prefix_len:
            shapes["prefix"] = [
                blk.block_cache_shapes(cfg, i, batch, cache_size)
                for i in range(self.prefix_len)]
        period = {
            f"l{j}": blk.block_cache_shapes(cfg, self.prefix_len + j, batch,
                                            cache_size)
            for j in range(self.period)
        }
        shapes["blocks"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n_blocks,) + s.shape, s.dtype),
            period)
        if self.is_encdec:
            hd = cfg.resolved_head_dim
            kv = jax.ShapeDtypeStruct(
                (self.n_blocks, batch, enc_len, cfg.n_kv_heads, hd),
                jnp.bfloat16)
            shapes["enc_kv"] = {"k": kv, "v": kv}
        return shapes

    def cache_logical_axes(self):
        cfg = self.cfg
        axes: dict = {"pos": ()}
        if self.prefix_len:
            axes["prefix"] = [blk.block_cache_axes(cfg, i)
                              for i in range(self.prefix_len)]
        period = {f"l{j}": blk.block_cache_axes(cfg, self.prefix_len + j)
                  for j in range(self.period)}

        def add_layer_dim(t):
            return jax.tree.map(lambda ax: (None,) + ax, t,
                                is_leaf=lambda x: isinstance(x, tuple))

        axes["blocks"] = add_layer_dim(period)
        if self.is_encdec:
            ax = (None, "cache_batch", "cache_seq", "cache_kv", "cache_kv")
            axes["enc_kv"] = {"k": ax, "v": ax}
        return axes

    def prefill(self, params, tokens, frames=None, *, cache_size: int,
                rules: AxisRules = UNBOUND):
        """Run the full prompt, return (last_logits, cache)."""
        cfg = self.cfg
        if cfg.sliding_window:
            # SWA caches are rolling buffers of exactly `window` positions
            cache_size = min(cache_size, cfg.sliding_window)
        enc_out = None
        if self.is_encdec:
            enc_out = self._encode(params, frames, rules)
        x = self._embed_inputs(params, tokens, frames, rules)
        S = x.shape[1]

        cache: dict = {"pos": jnp.asarray(S, jnp.int32)}
        if self.prefix_len:
            cache["prefix"] = []
            for i, p in enumerate(params.get("prefix", [])):
                x, c, _ = blk.block_prefill(p, cfg, i, x,
                                            cache_size, rules=rules)
                cache["prefix"].append(c)

        def body(h, layer_params):
            caches = {}
            for j in range(self.period):
                i = self.prefix_len + j
                enc_kv = None
                if self.is_encdec:
                    enc_kv = attn.cross_kv(layer_params[f"l{j}"]["cross"],
                                           cfg, enc_out)
                    caches[f"enc_l{j}"] = enc_kv
                h, c, _ = blk.block_prefill(layer_params[f"l{j}"], cfg, i, h,
                                            cache_size, rules=rules,
                                            enc_kv=enc_kv)
                caches[f"l{j}"] = c
            h = rules.constrain(h, "batch", "seq", "act_embed")
            return h, caches

        x, layer_caches = jax.lax.scan(body, x, params["blocks"],
                                       unroll=self._unroll())
        if self.is_encdec:
            # all periods share the same enc_kv stacking layout
            ekv = layer_caches.pop("enc_l0")
            cache["enc_kv"] = {"k": ekv[0], "v": ekv[1]}
        cache["blocks"] = {k: v for k, v in layer_caches.items()
                           if not k.startswith("enc_")}
        x = apply_norm(params["final_norm"], x)
        logits = self._logits(params, x[:, -1:])
        return logits, cache

    def decode_step(self, params, cache, token, *,
                    rules: AxisRules = UNBOUND):
        """One decode step. token: (B, 1) int32. Returns (logits, cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = jnp.take(params["embed"], token, axis=0)
        x = rules.constrain(x, "batch", "seq", "act_embed")

        new_cache: dict = {"pos": pos + 1}
        if self.prefix_len:
            new_cache["prefix"] = []
            for i, p in enumerate(params.get("prefix", [])):
                x, c = blk.block_decode(p, cfg, i, x, cache["prefix"][i],
                                        pos, rules=rules)
                new_cache["prefix"].append(c)

        def body(h, xs):
            layer_params, layer_cache, enc_kv = xs
            new_layer_cache = {}
            for j in range(self.period):
                i = self.prefix_len + j
                ekv = (enc_kv["k"], enc_kv["v"]) if enc_kv is not None else None
                h, c = blk.block_decode(layer_params[f"l{j}"], cfg, i, h,
                                        layer_cache[f"l{j}"], pos,
                                        rules=rules, enc_kv=ekv)
                new_layer_cache[f"l{j}"] = c
            h = rules.constrain(h, "batch", "seq", "act_embed")
            return h, new_layer_cache

        enc_kv_stack = cache.get("enc_kv")
        xs = (params["blocks"], cache["blocks"], enc_kv_stack)
        if enc_kv_stack is None:
            xs = (params["blocks"], cache["blocks"],
                  jax.tree.map(lambda _: None, params["blocks"]))
            x, blocks_cache = jax.lax.scan(
                lambda h, z: body(h, (z[0], z[1], None)),
                x, (params["blocks"], cache["blocks"]),
                unroll=self._unroll())
        else:
            x, blocks_cache = jax.lax.scan(body, x, xs,
                                           unroll=self._unroll())
            new_cache["enc_kv"] = enc_kv_stack
        new_cache["blocks"] = blocks_cache

        x = apply_norm(params["final_norm"], x)
        logits = self._logits(params, x)
        logits = rules.constrain(logits, "batch", "seq", "vocab")
        return logits, new_cache


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
