"""Dense MLP block (gated SwiGLU-style or plain, configurable activation)."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.common import activation
from repro.models.param import Spec


def mlp_spec(cfg: ModelConfig, d_ff: int) -> dict:
    D = cfg.d_model
    spec = {
        "w_in": Spec((D, d_ff), ("embed", "mlp"), "scaled"),
        "w_out": Spec((d_ff, D), ("mlp", "embed"), "scaled"),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = Spec((D, d_ff), ("embed", "mlp"), "scaled")
    return spec


def mlp_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = activation(cfg.act)
    h = act(x @ p["w_in"])
    if cfg.gated_mlp:
        h = h * (x @ p["w_gate"])
    return h @ p["w_out"]
