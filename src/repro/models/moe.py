"""Mixture-of-Experts with GShard-style grouped one-hot dispatch.

Tokens are split into small groups (default 256); per group a capacity-bounded
one-hot dispatch tensor routes tokens to experts via einsums — no scatters, so
GSPMD partitions everything cleanly at 512 devices. The (token, expert,
capacity) dispatch/combine tensors are built by contracting over the k routing
choices, so nothing 5-D is ever materialized. Supports top-k routing, shared
experts (DeepSeekMoE) and the Switch load-balance auxiliary loss.

A shard_map all-to-all expert-parallel variant lives in
``repro.distributed.ep_moe`` (used by the perf hillclimb).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules
from repro.models.common import activation
from repro.models.mlp import mlp_spec, mlp_apply
from repro.models.param import Spec

GROUP_SIZE = 256


def moe_spec(cfg: ModelConfig) -> dict:
    m = cfg.moe
    D = cfg.d_model
    F = m.expert_d_ff or cfg.d_ff
    E = m.n_experts
    fs = m.ep_fsplit
    # EP layout splits each expert's hidden dim across fs storage rows so
    # the (expert, slice) dim divides the data axis (grok: 8e -> 16 rows)
    spec = {
        "router": Spec((D, E), ("embed", None), "small", "float32"),
        "w_in": Spec((E * fs, D, F // fs),
                     ("expert", "embed", "expert_mlp"), "scaled"),
        "w_out": Spec((E * fs, F // fs, D),
                      ("expert", "expert_mlp", "embed"), "scaled"),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = Spec((E * fs, D, F // fs),
                              ("expert", "embed", "expert_mlp"), "scaled")
    if m.n_shared:
        spec["shared"] = mlp_spec(cfg, m.n_shared * F)
    return spec


def capacity(group_size: int, top_k: int, n_experts: int, factor: float) -> int:
    return max(int(math.ceil(factor * top_k * group_size / n_experts)), top_k)


def route(logits: jax.Array, E: int, k: int, C: int):
    """Top-k capacity routing within a group.

    logits: (..., g, E) float32. Returns (gate_vals (...,g,k), dispatch
    one-hots de (...,g,k,E) and dc (...,g,k,C) with capacity-overflow dropped).
    """
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (...,g,k)
    if k > 1:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    oh = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)          # (...,g,k,E)
    g = oh.shape[-3]
    lead = oh.shape[:-3]
    # capacity slots assigned choice-major (all 1st choices first)
    ohf = jnp.swapaxes(oh, -2, -3).reshape(lead + (k * g, E))
    pos = jnp.cumsum(ohf, axis=-2) - ohf                       # 0-based slot
    keep = (pos < C) & (ohf > 0)
    pos = jnp.swapaxes(pos.reshape(lead + (k, g, E)), -2, -3)
    keep = jnp.swapaxes(keep.reshape(lead + (k, g, E)), -2, -3)

    slot = jnp.sum(pos * oh, axis=-1)                          # (...,g,k)
    kept = jnp.any(keep & (oh > 0), axis=-1)                   # (...,g,k)
    dc = jax.nn.one_hot(slot, C) * kept[..., None]             # (...,g,k,C)
    return probs, gate_vals, oh, dc


_UNBOUND = AxisRules()


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array, *,
              group_size: int = GROUP_SIZE,
              rules: AxisRules = _UNBOUND):
    """x: (B, S, D) -> (y, aux_loss).

    Sharding intent (GShard): dispatch/combine tensors ride the token (data)
    sharding; expert_in/out are expert-sharded over data (the dp<->ep
    transition lowers to all-to-all) with the expert FFN hidden dim on the
    tensor-parallel axis."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    B, S, D = x.shape
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    G = T // g
    C = capacity(g, k, E, m.capacity_factor)

    if rules.mapping.get("moe_impl") == ("ep",) and rules.mesh is not None:
        from repro.distributed.ep_moe import moe_apply_ep
        return moe_apply_ep(p, cfg, x, rules)

    fs = m.ep_fsplit
    if fs > 1:   # reconstruct (E, D, F) from the EP storage layout
        F = p["w_in"].shape[2] * fs
        D_ = p["w_in"].shape[1]
        def unsplit_in(w):
            return (w.reshape(E, fs, D_, F // fs)
                    .transpose(0, 2, 1, 3).reshape(E, D_, F))
        p = dict(p, w_in=unsplit_in(p["w_in"]),
                 w_out=p["w_out"].reshape(E, F, D_),
                 **({"w_gate": unsplit_in(p["w_gate"])}
                    if "w_gate" in p else {}))

    xt = x.reshape(G, g, D)
    xt = rules.constrain(xt, "groups", None, "act_embed")
    logits = (xt.astype(jnp.float32) @ p["router"])            # (G,g,E)
    logits = rules.constrain(logits, "groups", None, None)
    probs, gate_vals, de, dc = route(logits, E, k, C)
    de = rules.constrain(de.astype(x.dtype), "groups", None, None, None)
    dc = rules.constrain(dc.astype(x.dtype), "groups", None, None, None)

    # 4-D dispatch/combine built by contracting over k (no 5-D tensor)
    disp = jnp.einsum("gtke,gtkc->gtec", de, dc)               # (G,g,E,C)
    comb = jnp.einsum("gtke,gtkc->gtec", de * gate_vals.astype(x.dtype)[..., None], dc)
    disp = rules.constrain(disp, "groups", None, None, None)
    comb = rules.constrain(comb, "groups", None, None, None)

    # Activations keep the group(data) sharding; the expert dim rides the
    # same axis as the expert weights (configure_moe) so the expert FFN is
    # fully local. The shard_map all-to-all EP variant (tokens move instead)
    # is the §Perf alternative.
    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xt)         # (G,E,C,D)
    expert_in = rules.constrain(expert_in, "groups", "expert", None,
                                "act_embed")
    act = activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", expert_in, p["w_in"]))
    h = rules.constrain(h, "groups", "expert", None, None)
    if cfg.gated_mlp:
        h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_out"])   # (G,E,C,D)
    expert_out = rules.constrain(expert_out, "groups", "expert", None,
                                 "act_embed")
    y = jnp.einsum("gtec,gecd->gtd", comb, expert_out)
    y = y.reshape(B, S, D)
    y = rules.constrain(y, "batch", None, "act_embed")

    if m.n_shared:
        y = y + mlp_apply(p["shared"], cfg, x)

    # Switch load-balance aux: E * sum_e frac_tokens_e * frac_prob_e
    frac_tokens = jnp.mean(jnp.sum(disp, axis=-1), axis=(0, 1))    # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens.astype(jnp.float32) * frac_probs)
    return y, (aux * m.router_aux_weight).astype(jnp.float32)
