"""Parameter-spec machinery.

A model's parameters are declared as a pytree of ``Spec`` leaves (shape +
logical axis names + init kind). From one spec tree we derive: real params
(smoke tests / examples), ShapeDtypeStructs (dry-run lowering), and the
logical-axes tree consumed by the sharding rule engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple                 # logical axis name (or None) per dim
    init: str = "normal"        # normal | zeros | ones | scaled | small
    dtype: Optional[str] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_params(key: jax.Array, spec_tree, default_dtype: str = "bfloat16"):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, s in zip(keys, leaves):
        dt = jnp.dtype(s.dtype or default_dtype)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, dt)
        elif s.init == "ones":
            v = jnp.ones(s.shape, dt)
        elif s.init == "scaled":
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            v = (jax.random.normal(k, s.shape, jnp.float32)
                 / np.sqrt(fan_in)).astype(dt)
        elif s.init == "small":
            v = (0.02 * jax.random.normal(k, s.shape, jnp.float32)).astype(dt)
        else:  # normal
            v = (0.02 * jax.random.normal(k, s.shape, jnp.float32)).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def param_shapes(spec_tree, default_dtype: str = "bfloat16"):
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype)),
        spec_tree, is_leaf=_is_spec)


def param_axes(spec_tree):
    """Logical-axes tree (tuple leaves) for the sharding rule engine."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def param_bytes(spec_tree, default_dtype: str = "bfloat16") -> int:
    total = 0
    for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype or default_dtype).itemsize
    return total


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = None):
    """Add a leading stacking dim (for scan-over-layers parameter stacks)."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.dtype),
        spec_tree, is_leaf=_is_spec)
