"""Residual block assembly: norm -> mixer (attn | ssd) -> norm -> ffn
(dense | moe | none), signature chosen per layer index. Hybrid archs scan over
a repeating period of heterogeneous blocks."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import norm_spec, apply_norm
from repro.models.mlp import mlp_spec, mlp_apply
from repro.models.moe import moe_spec, moe_apply


def layer_signature(cfg: ModelConfig, i: int) -> tuple[str, str]:
    """(mixer, ffn) for absolute layer index i."""
    mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
    if cfg.is_moe_layer(i):
        ffn = "moe"
    elif mixer == "ssm" and cfg.family == "ssm":
        ffn = "none"                       # pure Mamba blocks: mixer only
    elif cfg.d_ff or (cfg.moe and cfg.moe.first_k_dense and i < cfg.moe.first_k_dense):
        ffn = "dense"
    else:
        ffn = "none"
    return mixer, ffn


def layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(prefix_len, period, n_blocks) for scan-over-layers."""
    prefix = cfg.moe.first_k_dense if cfg.moe else 0
    p = 1
    if cfg.family == "hybrid" and cfg.attn_every:
        p = cfg.attn_every
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    n = cfg.n_layers - prefix
    assert n % p == 0, (cfg.name, n, p)
    return prefix, p, n // p


def block_spec(cfg: ModelConfig, i: int, *, cross: bool = False) -> dict:
    mixer, ffn = layer_signature(cfg, i)
    spec: dict = {"norm1": norm_spec(cfg, cfg.d_model)}
    if mixer == "attn":
        spec["attn"] = attn.attn_spec(cfg)
    else:
        spec["ssm"] = ssm_mod.ssm_spec(cfg)
    if cross:
        spec["norm_x"] = norm_spec(cfg, cfg.d_model)
        spec["cross"] = attn.attn_spec(cfg)
    if ffn != "none":
        spec["norm2"] = norm_spec(cfg, cfg.d_model)
    if ffn == "moe":
        spec["moe"] = moe_spec(cfg)
    elif ffn == "dense":
        d_ff = cfg.d_ff
        if cfg.moe and cfg.moe.first_k_dense and i < cfg.moe.first_k_dense:
            d_ff = cfg.moe.dense_d_ff or cfg.d_ff
        spec["mlp"] = mlp_spec(cfg, d_ff)
    return spec


_UNBOUND = AxisRules()


def block_apply(p: dict, cfg: ModelConfig, i: int, x: jax.Array, *,
                positions: Optional[jax.Array] = None, causal: bool = True,
                use_rope: bool = True, rules: AxisRules = _UNBOUND,
                enc_kv: Optional[tuple] = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, aux_loss)."""
    mixer, ffn = layer_signature(cfg, i)
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x)
    if mixer == "attn":
        h = attn.attn_apply(p["attn"], cfg, h, positions=positions,
                            causal=causal, use_rope=use_rope)
    else:
        h = ssm_mod.ssm_apply(p["ssm"], cfg, h, rules=rules)
    x = x + h
    if enc_kv is not None:
        h = apply_norm(p["norm_x"], x)
        x = x + attn.cross_attn_apply(p["cross"], cfg, h, enc_kv)
    if ffn != "none":
        h = apply_norm(p["norm2"], x)
        if ffn == "moe":
            h, aux = moe_apply(p["moe"], cfg, h, rules=rules)
        else:
            h = mlp_apply(p["mlp"], cfg, h)
        x = x + h
    return x, aux


def block_prefill(p: dict, cfg: ModelConfig, i: int, x: jax.Array,
                  cache_size: int, *, positions=None,
                  rules: AxisRules = _UNBOUND,
                  enc_kv: Optional[tuple] = None):
    """Full-sequence pass that also returns this layer's decode cache."""
    mixer, ffn = layer_signature(cfg, i)
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x)
    if mixer == "attn":
        S = x.shape[1]
        h, (k, v) = attn.attn_apply(p["attn"], cfg, h, positions=positions,
                                    causal=True, return_kv=True)
        if cache_size <= S:
            k, v = k[:, S - cache_size:], v[:, S - cache_size:]
            if cfg.sliding_window:
                # rolling buffer: absolute position p lives at slot p % size
                shift = (S - cache_size) % cache_size
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
        else:
            pad = cache_size - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": k, "v": v}
    else:
        h, cache = ssm_mod.ssm_apply(p["ssm"], cfg, h, return_state=True,
                                     rules=rules)
    x = x + h
    if enc_kv is not None:
        hh = apply_norm(p["norm_x"], x)
        x = x + attn.cross_attn_apply(p["cross"], cfg, hh, enc_kv)
    if ffn != "none":
        hh = apply_norm(p["norm2"], x)
        if ffn == "moe":
            hh, aux = moe_apply(p["moe"], cfg, hh, rules=rules)
        else:
            hh = mlp_apply(p["mlp"], cfg, hh)
        x = x + hh
    return x, cache, aux


def block_decode(p: dict, cfg: ModelConfig, i: int, x: jax.Array, cache,
                 pos: jax.Array, *, rules: AxisRules = _UNBOUND,
                 enc_kv: Optional[tuple] = None):
    """One-token decode. x: (B, 1, D). Returns (x, new_cache)."""
    mixer, ffn = layer_signature(cfg, i)
    h = apply_norm(p["norm1"], x)
    if mixer == "attn":
        h, cache = attn.attn_decode(p["attn"], cfg, h, cache, pos)
    else:
        h, cache = ssm_mod.ssm_decode(p["ssm"], cfg, h, cache)
    x = x + h
    if enc_kv is not None:
        hh = apply_norm(p["norm_x"], x)
        out, _ = attn.attn_decode(p["cross"], cfg, hh,
                                  {"k": enc_kv[0], "v": enc_kv[1]}, pos,
                                  cross=True)
        x = x + out
    if ffn != "none":
        hh = apply_norm(p["norm2"], x)
        if ffn == "moe":
            hh, _ = moe_apply(p["moe"], cfg, hh, rules=rules)
        else:
            hh = mlp_apply(p["mlp"], cfg, hh)
        x = x + hh
    return x, cache


def block_cache_shapes(cfg: ModelConfig, i: int, batch: int, cache_size: int):
    mixer, _ = layer_signature(cfg, i)
    if mixer == "attn":
        hd = cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct((batch, cache_size, cfg.n_kv_heads, hd),
                                  jnp.bfloat16)
        return {"k": kv, "v": kv}
    return ssm_mod.ssm_state_shapes(cfg, batch)


def block_cache_axes(cfg: ModelConfig, i: int):
    mixer, _ = layer_signature(cfg, i)
    if mixer == "attn":
        # both kv-head and head-dim carry the "cache_kv" name: the rule
        # engine assigns the model axis to whichever dim divides first
        # (kv_heads < axis size falls through to head_dim)
        ax = ("cache_batch", "cache_seq", "cache_kv", "cache_kv")
        return {"k": ax, "v": ax}
    return ssm_mod.ssm_state_axes(cfg)
