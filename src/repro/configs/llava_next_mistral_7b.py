"""LLaVA-NeXT (Mistral-7B backbone) with anyres patch tiling.
Backbone only; the vision tower is a stub providing precomputed patch
embeddings per the assignment. [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1000000.0,
    frontend="vision",
    frontend_dim=1024,         # CLIP-ViT-L patch embedding dim (stub)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
