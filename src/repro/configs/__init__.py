"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    shape_applicable, reduced,
)

# arch-id -> module basename
ARCHS = {
    "grok-1-314b": "grok_1_314b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "nemotron-4-15b": "nemotron_4_15b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen3-1.7b": "qwen3_1_7b",
    "starcoder2-15b": "starcoder2_15b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}


def list_arch_ids() -> list[str]:
    return list(ARCHS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str, **over) -> ModelConfig:
    return reduced(get_config(arch_id), **over)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "ARCHS", "list_arch_ids", "get_config", "get_reduced", "get_shape",
    "shape_applicable", "reduced",
]
