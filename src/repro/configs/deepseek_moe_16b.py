"""DeepSeekMoE 16B: 2 shared + 64 routed top-6, fine-grained experts, first
layer dense. [arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # per-expert fine-grained hidden
    vocab_size=102400,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
        first_k_dense=1, dense_d_ff=10944),
    source="arXiv:2401.06066",
)
