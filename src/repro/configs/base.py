"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four assigned
input shapes are ``ShapeConfig``s. ``param_count()`` / ``active_param_count()``
feed the roofline's MODEL_FLOPS = 6*N*D term.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # always-on shared experts (DeepSeekMoE)
    expert_d_ff: int = 0           # per-expert hidden size (0 -> use model d_ff)
    every: int = 1                 # MoE every k-th layer (Jamba: 2)
    first_k_dense: int = 0         # first k layers use a dense MLP (DeepSeekMoE: 1)
    dense_d_ff: int = 0            # hidden size of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    ep_fsplit: int = 1     # expert-parallel hidden-dim split (E < data axis)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    ngroups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "silu"              # silu | relu2 | gelu
    gated_mlp: bool = True         # SwiGLU-style (2 input mats) vs plain
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0            # hybrid: one attention layer per `attn_every`
    frontend: str = "none"         # none | audio | vision
    frontend_dim: int = 0          # embedding dim delivered by the (stub) frontend
    enc_layers: int = 0            # encoder-decoder: encoder depth
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid interleave: layer i uses attention (else SSM)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        k = self.attn_every
        # Jamba places the attention layer in the middle of each period.
        return (i % k) == (k // 2)

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_k_dense:
            return False
        return ((i - self.moe.first_k_dense) % self.moe.every) == 0

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only arch in the assignment

    # -------------------------------------------------------------- param math
    def _mlp_params(self, d_ff: int) -> int:
        n_in = 2 if self.gated_mlp else 1
        return (n_in + 1) * self.d_model * d_ff

    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        return q + kv + o

    def _ssm_params(self) -> int:
        s = self.ssm
        di = self.d_inner
        nh = self.n_ssm_heads
        # in_proj -> [z, x, B, C, dt] ; out_proj
        in_proj = self.d_model * (2 * di + 2 * s.ngroups * s.d_state + nh)
        conv = s.d_conv * (di + 2 * s.ngroups * s.d_state)
        out_proj = di * self.d_model
        extras = 3 * nh  # A_log, dt_bias, D
        return in_proj + conv + out_proj + extras

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        n = 2 * self.d_model  # two norms
        if self.family == "ssm" or (self.family == "hybrid" and not self.is_attn_layer(i)):
            n += self._ssm_params()
        else:
            n += self._attn_params()
        if self.is_moe_layer(i):
            m = self.moe
            e_ff = m.expert_d_ff or self.d_ff
            n_routed = m.top_k if active_only else m.n_experts
            n += n_routed * self._mlp_params(e_ff)
            n += m.n_shared * self._mlp_params(e_ff)
            n += self.d_model * m.n_experts  # router
        elif self.family != "ssm":  # pure-SSM blocks have no MLP
            d_ff = self.d_ff
            if self.moe is not None and self.moe.first_k_dense and i < self.moe.first_k_dense:
                d_ff = self.moe.dense_d_ff or self.d_ff
            if d_ff:
                n += self._mlp_params(d_ff)
        return n

    def param_count(self, active_only: bool = False) -> int:
        n = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # lm head
        for i in range(self.n_layers):
            n += self._layer_params(i, active_only)
        if self.family == "encdec":
            for i in range(self.enc_layers):
                n += self._layer_params(i, active_only)
                n += self._attn_params() + self.d_model  # decoder cross-attn + norm
        if self.frontend != "none" and self.frontend_dim:
            n += self.frontend_dim * self.d_model  # projector
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not model.is_subquadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % model.name
    return True, ""


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        enc_layers=min(cfg.enc_layers, 2),
        frontend_dim=32 if cfg.frontend != "none" else 0,
    )
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), expert_d_ff=64,
            dense_d_ff=128 if cfg.moe.first_k_dense else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.family == "hybrid":
        changes["n_layers"] = max(cfg.attn_every, 4)
    changes.update(over)
    return dataclasses.replace(cfg, **changes)
