"""SeamlessM4T-medium: encoder-decoder multimodal (audio) transformer.
Backbone only; the speech frontend is a stub providing precomputed frame
embeddings per the assignment. [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    act="relu2",               # conformer-ish FFN; squared-relu stand-in
    gated_mlp=False,
    norm="layernorm",
    rope_theta=10000.0,
    frontend="audio",
    frontend_dim=1024,         # w2v-BERT frame embedding dim (stub)
    source="arXiv:2308.11596",
)
