"""Input-shape stand-ins (ShapeDtypeStruct) for every (arch x shape) cell.

``input_specs`` mirrors the pattern used by the multi-pod dry-run: weak-type
correct, shardable, zero device allocation. Data inputs only — parameter and
KV-cache ShapeDtypeStructs come from the model builders.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

# Vision anyres tiling: base 576 patches + one high-res tile (LLaVA-NeXT).
VISION_PATCHES = 1152


def frontend_len(model: ModelConfig, shape: ShapeConfig) -> int:
    """Frames/patches delivered by the (stub) modality frontend."""
    if model.frontend == "audio":
        return max(shape.seq_len // 4, 8)
    if model.frontend == "vision":
        return min(VISION_PATCHES, shape.seq_len // 2)
    return 0


def text_len(model: ModelConfig, shape: ShapeConfig) -> int:
    """Decoder token length such that the backbone sees `seq_len` positions."""
    if model.family == "encdec":
        return shape.seq_len           # decoder length; encoder is separate
    return shape.seq_len - (frontend_len(model, shape) if model.frontend != "none" else 0)


def input_specs(model: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every data input of the lowered step."""
    B = shape.global_batch
    f32 = jnp.float32
    bf16 = jnp.bfloat16
    i32 = jnp.int32

    if shape.kind == "decode":
        specs = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
        return specs

    S_txt = text_len(model, shape)
    specs = {"tokens": jax.ShapeDtypeStruct((B, S_txt), i32)}
    if model.frontend != "none":
        S_f = frontend_len(model, shape)
        specs["frames"] = jax.ShapeDtypeStruct((B, S_f, model.frontend_dim), bf16)
    if shape.kind == "train":
        S_total = shape.seq_len
        specs["targets"] = jax.ShapeDtypeStruct((B, S_total), i32)
        specs["mask"] = jax.ShapeDtypeStruct((B, S_total), f32)
    return specs


def cache_len(model: ModelConfig, shape: ShapeConfig) -> int:
    """KV-cache length for decode cells (window-clamped for SWA archs)."""
    assert shape.kind == "decode"
    if model.sliding_window:
        return min(shape.seq_len, model.sliding_window)
    return shape.seq_len
