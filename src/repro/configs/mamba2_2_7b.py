"""Mamba2 2.7B: attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # no MLP block; SSD mixer only
    vocab_size=50280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
