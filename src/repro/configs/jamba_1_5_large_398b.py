"""Jamba-1.5-large 398B: Mamba+attention 1:7 interleave, MoE 16e top-2 on
every other layer. [arXiv:2403.19887; hf]

Unspecified-by-assignment SSM constants follow the Jamba paper (d_state=16,
d_conv=4, expand=2); the mixer is run through our SSD layer with head_dim 128.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    attn_every=8,              # 1 attention layer per 8 (1:7 interleave)
    moe=MoEConfig(n_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=128, chunk=256),
    source="arXiv:2403.19887",
)
