"""Multi-tenant serving plane: tenant descriptors, admission control,
and fair-share scheduling between job submission and the gateway.

The control plane (this package: who may run how much, when) is split
from the data plane (``repro.core.gateway`` + ``repro.rollout``: leases
and episode traffic). See ``docs/MULTITENANCY.md`` for the operator
guide and ``benchmarks/multitenant.py`` for the CI-gated fairness and
isolation benchmark.
"""

from repro.tenancy.scheduler import FairShareScheduler
from repro.tenancy.tenant import (
    ADMITTED,
    REJECTED,
    THROTTLED,
    AdmissionDecision,
    Tenant,
    TenantStats,
    jain_index,
)

__all__ = [
    "ADMITTED",
    "REJECTED",
    "THROTTLED",
    "AdmissionDecision",
    "FairShareScheduler",
    "Tenant",
    "TenantStats",
    "jain_index",
]
