"""Tenant descriptors and admission-control vocabulary for the serving plane.

A :class:`Tenant` is the control-plane contract one job stream signs with
the fleet: how much of it the stream may use at once (``max_inflight``),
how much backlog it may park (``max_queued``), how fast it may submit
(the ``burst_tokens`` / ``refill_per_vs`` token bucket, measured on the
**virtual** clock), what share of contended capacity it earns
(``weight``), and which strict ``priority`` tier it dispatches from.

Every admission verdict is an explicit :class:`AdmissionDecision` —
clients see ``throttled`` or ``rejected`` with a reason instead of
silent queue growth. Decisions are pure functions of submission order
and virtual time, so a seeded multi-tenant run replays bit-identically
in any process (the determinism contract shared by the whole event-time
stack).

>>> t = Tenant("acme", weight=2.0, max_inflight=8)
>>> t.weight, t.priority
(2.0, 1)
>>> jain_index([1.0, 1.0, 1.0, 1.0])
1.0
>>> round(jain_index([1.0, 0.0, 0.0, 0.0]), 3)
0.25
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

# AdmissionDecision.status values. ``THROTTLED`` is transient (quota or
# burst budget — retry later); ``REJECTED`` is permanent for this
# submission (unknown tenant / malformed task).
ADMITTED = "admitted"
THROTTLED = "throttled"
REJECTED = "rejected"


@dataclass(frozen=True)
class Tenant:
    """One tenant's scheduling contract.

    ``weight`` sets the deficit-round-robin share under contention (a
    weight-2 tenant earns twice the dispatch credit of a weight-1 tenant
    per round). ``max_inflight`` caps concurrently *running* episodes;
    ``max_queued`` caps the admitted-but-undispatched backlog — a
    submission past it is throttled, never silently parked.
    ``burst_tokens`` / ``refill_per_vs`` form a token bucket on the
    virtual clock: a submission costs one token, the bucket refills
    continuously and never exceeds ``burst_tokens``, so a Poisson spike
    is absorbed up to the budget and throttled beyond it. ``priority``
    is a strict tier: lower numbers dispatch first; DRR shares apply
    *within* a tier only. ``slo_wait_p95_vs`` optionally overrides the
    autoscaler's default per-tenant acquire-wait SLO target.
    """

    tenant_id: str
    weight: float = 1.0
    max_inflight: int = 32
    max_queued: int = 256
    burst_tokens: float = 64.0
    refill_per_vs: float = 2.0
    priority: int = 1
    slo_wait_p95_vs: float | None = None

    def __post_init__(self) -> None:
        if not self.tenant_id:
            raise ValueError("tenant_id must be non-empty")
        if not (self.weight > 0.0 and math.isfinite(self.weight)):
            raise ValueError(f"weight must be finite and > 0, got {self.weight}")
        if self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {self.max_inflight}")
        if self.max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {self.max_queued}")
        if self.burst_tokens < 1.0:
            raise ValueError(f"burst_tokens must be >= 1, got {self.burst_tokens}")
        if self.refill_per_vs < 0.0:
            raise ValueError(f"refill_per_vs must be >= 0, got {self.refill_per_vs}")


@dataclass(frozen=True)
class AdmissionDecision:
    """The explicit verdict on one submission.

    ``status`` is one of :data:`ADMITTED` / :data:`THROTTLED` /
    :data:`REJECTED`; ``reason`` names the binding constraint
    (``"queue full"``, ``"burst budget exhausted"``, ``"unknown
    tenant"``). ``queue_depth`` is the tenant's backlog *after* the
    decision and ``vt`` the virtual submission time, so a decision log
    doubles as an audit trail of the admission plane.
    """

    tenant_id: str
    task_id: str
    status: str
    reason: str = ""
    queue_depth: int = 0
    vt: float = 0.0

    @property
    def admitted(self) -> bool:
        return self.status == ADMITTED


@dataclass
class TenantStats:
    """Mutable per-tenant accounting kept by the scheduler (one instance
    per tenant per run; all counters are updated on the event loop, so
    they are deterministic per seed)."""

    submitted: int = 0
    admitted: int = 0
    throttled: int = 0
    rejected: int = 0
    dispatched: int = 0
    completed: int = 0
    failed: int = 0
    queued_at_stop: int = 0
    service_vs: float = 0.0  # summed virtual seconds of served episodes
    wait_vs: list[float] = field(default_factory=list)  # submit -> runner

    def as_dict(self) -> dict:
        out = {
            k: getattr(self, k)
            for k in (
                "submitted",
                "admitted",
                "throttled",
                "rejected",
                "dispatched",
                "completed",
                "failed",
                "queued_at_stop",
            )
        }
        out["service_vs"] = round(self.service_vs, 6)
        return out


def jain_index(xs: list[float]) -> float:
    """Jain's fairness index over per-tenant allocations.

    ``(sum x)^2 / (n * sum x^2)`` — 1.0 when every tenant gets the same
    allocation, ``1/n`` when one tenant gets everything. Returns 1.0 for
    an empty or all-zero series (nothing was allocated, nothing was
    unfair).
    """
    if not xs:
        return 1.0
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    s = sum(xs)
    return (s * s) / (len(xs) * sq)
