"""Fair-share scheduler: the control plane between submission and the fleet.

:class:`FairShareScheduler` sits between a multi-tenant job stream and
the ``Gateway``'s data plane (leases, episode traffic). It does three
things, all on the deterministic virtual clock:

- **admission control** — every submission gets an explicit
  :class:`~repro.tenancy.tenant.AdmissionDecision`; past-quota or
  past-burst-budget traffic is *throttled* at the door (the client sees
  it) instead of growing an unbounded queue;
- **weighted deficit-round-robin dispatch** — admitted jobs wait in
  strictly per-tenant queues; under contention each backlogged tenant
  earns ``quantum * weight`` dispatch credit per round and serves one
  queued job per unit of credit, so long-run service is proportional to
  weight regardless of how deep any one tenant's backlog is;
- **burst isolation** — one tenant's Poisson spike is bounded twice:
  the token bucket throttles the spike at admission, and DRR caps the
  admitted backlog's share of dispatch at the tenant's weight, so a
  quiet tenant's acquire-wait tail cannot be moved by a noisy neighbor.

Priority classes are strict tiers: all dispatchable backlog in tier 0
is served before tier 1 is considered (DRR applies within a tier). A
tenant at its ``max_inflight`` quota is skipped without earning credit,
so quota-blocked tenants cannot bank deficit while blocked.

Determinism contract: the scheduler holds no wall-clock state and draws
no randomness. Admission verdicts and dispatch order are pure functions
of (submission order, virtual time, tenant descriptors), so a seeded
multi-tenant run — including every throttle and every DRR interleaving
— replays bit-identically in any process, on either event kernel.

Typical wiring (the engine does this internally; see
``RolloutEngine.run_event_driven(scheduler=...)``)::

    sched = FairShareScheduler([Tenant("a"), Tenant("b", weight=2.0)])
    decision = sched.submit(task, now=loop.now)   # explicit verdict
    for job in sched.dispatch(loop.now, budget=free_slots):
        launch(job)                                # DRR-picked order
    ...
    sched.task_done(tenant_id, ok=True, service_vs=episode_vs)
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.telemetry import Telemetry
from repro.tenancy.tenant import (
    ADMITTED,
    REJECTED,
    THROTTLED,
    AdmissionDecision,
    Tenant,
    TenantStats,
)


@dataclass
class _TenantState:
    """Runtime scheduling state for one tenant (queue, bucket, deficit)."""

    tenant: Tenant
    queue: deque = field(default_factory=deque)
    deficit: float = 0.0
    turn_credited: bool = False  # this DRR turn already earned its quantum
    tokens: float = 0.0
    last_refill_vt: float = 0.0
    inflight: int = 0
    in_ring: bool = False
    stats: TenantStats = field(default_factory=TenantStats)


class FairShareScheduler:
    """Admission control + weighted DRR dispatch over per-tenant queues."""

    def __init__(
        self,
        tenants: list[Tenant],
        *,
        quantum: float = 1.0,
        default_tenant: Optional[str] = None,
        telemetry: Optional[Telemetry] = None,
    ):
        if quantum <= 0.0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = quantum
        self.telemetry = telemetry or Telemetry()
        self._t: dict[str, _TenantState] = {}
        # priority tier -> rotation ring of backlogged tenant ids. Tenants
        # enter in submission order and leave when their queue drains, so
        # the rotation order is a pure function of the arrival stream.
        self._rings: dict[int, deque[str]] = {}
        self.decisions: list[AdmissionDecision] = []
        self._now_vt = 0.0
        for t in tenants:
            self.register(t)
        if default_tenant is not None and default_tenant not in self._t:
            raise ValueError(f"default tenant {default_tenant!r} not registered")
        self.default_tenant = default_tenant

    # -------------------------------------------------------------- tenants
    def register(self, tenant: Tenant) -> Tenant:
        """Add a tenant; its token bucket starts full at the current
        virtual time (a fresh tenant may burst up to its budget at once)."""
        if tenant.tenant_id in self._t:
            raise ValueError(f"tenant {tenant.tenant_id!r} already registered")
        st = _TenantState(tenant, tokens=tenant.burst_tokens, last_refill_vt=self._now_vt)
        self._t[tenant.tenant_id] = st
        return tenant

    def tenant_ids(self) -> list[str]:
        return list(self._t)

    def tenant_of(self, task: dict) -> Optional[str]:
        """The tenant a task dict belongs to (``task["tenant"]``, else the
        scheduler's default tenant, else None)."""
        return task.get("tenant", self.default_tenant)

    def slo_map(self) -> dict[str, float]:
        """Per-tenant acquire-wait SLO targets for the autoscaler
        (tenants without an explicit ``slo_wait_p95_vs`` are omitted and
        fall back to the autoscaler's default)."""
        return {
            tid: st.tenant.slo_wait_p95_vs
            for tid, st in self._t.items()
            if st.tenant.slo_wait_p95_vs is not None
        }

    # ------------------------------------------------------------ admission
    def submit(self, task: dict, *, now: float) -> AdmissionDecision:
        """Admit, throttle, or reject one submission at virtual time
        ``now``; admitted tasks are stamped (``tenant``, ``_submit_vt``)
        and enqueued on their tenant's queue. Never blocks."""
        self._now_vt = max(self._now_vt, now)
        tid = self.tenant_of(task)
        task_id = str(task.get("task_id", ""))
        st = self._t.get(tid) if tid is not None else None
        if st is None:
            return self._decide(
                AdmissionDecision(tid or "<none>", task_id, REJECTED, "unknown tenant", 0, now)
            )
        t = st.tenant
        st.stats.submitted += 1
        self._refill(st, now)
        if len(st.queue) >= t.max_queued:
            d = AdmissionDecision(
                tid, task_id, THROTTLED, "queue full", len(st.queue), now
            )
        elif st.tokens < 1.0:
            d = AdmissionDecision(
                tid, task_id, THROTTLED, "burst budget exhausted", len(st.queue), now
            )
        else:
            st.tokens -= 1.0
            task["tenant"] = tid
            task["_submit_vt"] = now
            st.queue.append(task)
            if not st.in_ring:
                st.in_ring = True
                self._rings.setdefault(t.priority, deque()).append(tid)
            d = AdmissionDecision(tid, task_id, ADMITTED, "", len(st.queue), now)
        return self._decide(d, st)

    def _refill(self, st: _TenantState, now: float) -> None:
        """Continuous token-bucket refill on the virtual clock."""
        dt = now - st.last_refill_vt
        if dt > 0:
            st.tokens = min(
                st.tenant.burst_tokens, st.tokens + dt * st.tenant.refill_per_vs
            )
        st.last_refill_vt = max(st.last_refill_vt, now)

    def _decide(
        self, d: AdmissionDecision, st: Optional[_TenantState] = None
    ) -> AdmissionDecision:
        self.decisions.append(d)
        self.telemetry.count(f"tenant_{d.status}:{d.tenant_id}")
        if st is not None:
            if d.status == ADMITTED:
                st.stats.admitted += 1
            elif d.status == THROTTLED:
                st.stats.throttled += 1
            else:
                st.stats.rejected += 1
            self.telemetry.gauge(f"tenant_queue_depth:{d.tenant_id}", float(len(st.queue)))
        return d

    # ------------------------------------------------------------- dispatch
    def dispatch(self, now: float, budget: int) -> list[dict]:
        """Pick up to ``budget`` queued jobs by strict-priority weighted
        DRR and mark their tenants in flight. The caller launches them in
        the returned order (which IS the fairness contract)."""
        out: list[dict] = []
        if budget <= 0:
            return out
        self._now_vt = max(self._now_vt, now)
        for prio in sorted(self._rings):
            ring = self._rings[prio]
            if not ring:
                continue
            budget = self._dispatch_tier(ring, budget, out)
            if budget <= 0:
                break
        return out

    def _dispatch_tier(self, ring: deque, budget: int, out: list[dict]) -> int:
        """One tier's DRR sweep; returns the remaining budget.

        Termination: ``quota_streak`` breaks once a full rotation served
        nothing because every backlogged tenant is at its inflight quota,
        and ``max_idle`` bounds *consecutive non-serving visits* — the
        credit-building passes a sub-unit weight may legitimately need
        before it can afford one job. Serving visits reset the bound, so
        a large dispatch budget sweeps as many full rotations as it can
        pay for.
        """
        min_w = min(self._t[tid].tenant.weight for tid in ring)
        max_idle = (len(ring) + 1) * (1 + int(math.ceil(1.0 / (self.quantum * min_w))))
        idle = 0
        quota_streak = 0
        while budget > 0 and ring and idle < max_idle:
            tid = ring[0]
            st = self._t[tid]
            t = st.tenant
            if st.inflight >= t.max_inflight:
                # skip without credit: a quota-blocked tenant must not
                # bank deficit while its own episodes hold the quota
                st.turn_credited = False
                ring.rotate(-1)
                idle += 1
                quota_streak += 1
                if quota_streak >= len(ring):
                    break
                continue
            quota_streak = 0
            if not st.turn_credited:
                # credit exactly once per turn; cap so carry from a
                # mid-turn quota block cannot compound into a burst
                st.deficit = min(
                    st.deficit + self.quantum * t.weight,
                    2.0 * max(1.0, self.quantum * t.weight),
                )
                st.turn_credited = True
            served = 0
            while (
                budget > 0
                and st.queue
                and st.deficit >= 1.0
                and st.inflight < t.max_inflight
            ):
                job = st.queue.popleft()
                st.deficit -= 1.0
                st.inflight += 1
                st.stats.dispatched += 1
                out.append(job)
                budget -= 1
                served += 1
                self.telemetry.count(f"tenant_dispatched:{tid}")
                self.telemetry.gauge(f"tenant_queue_depth:{tid}", float(len(st.queue)))
            if (
                budget <= 0
                and st.queue
                and st.deficit >= 1.0
                and st.inflight < t.max_inflight
            ):
                # the budget interrupted this turn mid-credit: resume it
                # on the next dispatch call without re-crediting
                break
            # turn over: out of credit, out of backlog, or quota hit mid-turn
            st.turn_credited = False
            idle = 0 if served else idle + 1
            if not st.queue:
                st.deficit = 0.0  # classic DRR: empty queue forfeits credit
                st.in_ring = False
                ring.popleft()
            else:
                ring.rotate(-1)
        return budget

    # ------------------------------------------------------------- feedback
    def task_done(self, tenant_id: str, *, ok: bool, service_vs: float = 0.0) -> None:
        """Episode settled: free the tenant's inflight slot and account
        the service it received (virtual seconds of fleet time)."""
        st = self._t.get(tenant_id)
        if st is None:
            return
        st.inflight = max(st.inflight - 1, 0)
        if ok:
            st.stats.completed += 1
        else:
            st.stats.failed += 1
        st.stats.service_vs += service_vs
        self.telemetry.count(f"tenant_{'completed' if ok else 'failed'}:{tenant_id}")

    def observe_wait(self, tenant_id: str, wait_vs: float) -> None:
        """Record one submit->runner-acquired wait (the tenant-facing
        latency the SLO is written against)."""
        st = self._t.get(tenant_id)
        if st is not None:
            st.stats.wait_vs.append(wait_vs)
        self.telemetry.observe(f"tenant_wait_vs:{tenant_id}", wait_vs)

    def mark_stopped(self, now: float) -> int:
        """A deadline or stop cut the run: drop all queued jobs, counting
        them per tenant (``queued_at_stop``). Returns how many were
        dropped. In-flight episodes are untouched — they settle through
        ``task_done`` as usual."""
        dropped = 0
        for st in self._t.values():
            n = len(st.queue)
            if n:
                st.stats.queued_at_stop += n
                dropped += n
                st.queue.clear()
            st.in_ring = False
            st.deficit = 0.0
            st.turn_credited = False
        for ring in self._rings.values():
            ring.clear()
        if dropped:
            self.telemetry.count("tenant_jobs_dropped_at_stop", dropped)
        self._now_vt = max(self._now_vt, now)
        return dropped

    # -------------------------------------------------------------- queries
    @property
    def n_queued(self) -> int:
        return sum(len(st.queue) for st in self._t.values())

    @property
    def n_inflight(self) -> int:
        return sum(st.inflight for st in self._t.values())

    def queue_depth(self, tenant_id: str) -> int:
        return len(self._t[tenant_id].queue)

    def tokens(self, tenant_id: str) -> float:
        return self._t[tenant_id].tokens

    def stats(self) -> dict[str, TenantStats]:
        """Per-tenant accounting, keyed by tenant id (sorted)."""
        return {tid: self._t[tid].stats for tid in sorted(self._t)}

    def share_of_fleet(self) -> dict[str, float]:
        """Each tenant's fraction of total served virtual seconds."""
        total = sum(st.stats.service_vs for st in self._t.values())
        if total <= 0.0:
            return {tid: 0.0 for tid in sorted(self._t)}
        return {tid: self._t[tid].stats.service_vs / total for tid in sorted(self._t)}
