"""Deterministic-randomness utilities shared by every simulation layer.

Two fixes live here:

1. **Stable seeds.** The seed repo derived per-stream seeds with
   ``(seed, n, design).__hash__()`` — but ``str.__hash__`` is randomized
   per process (PYTHONHASHSEED), so two runs of the same benchmark in
   different processes drew *different* random streams: "deterministic per
   seed" only held within one interpreter. Every RNG construction now goes
   through :func:`stable_seed`, a blake2b digest of the key parts, which
   is identical across processes, platforms, and Python versions.

2. **Mean-preserving jitter.** Latency samplers drew
   ``mean * lognormvariate(0, sigma)`` — but ``E[lognorm(0, s)] =
   exp(s^2/2)`` (≈1.063 at the default sigma 0.35), silently inflating
   every configured mean by 6%. :func:`lognorm_jitter` centers the draw so
   the expected value is exactly 1.0 and the configured means are the
   means that calibration against the paper's numbers assumes.

3. **Bulk draws.** The batched event kernel processes thousands of replica
   ops per tick; one Python ``random.Random.lognormvariate`` call per op
   (~0.7 µs) dominates at fleet scale. :class:`LatencyStream` draws
   mean-preserving lognormal multipliers in numpy blocks from a
   counter-based Philox generator — ~3× cheaper per draw, and the stream
   is a pure function of its ``stable_seed`` key, so it is identical
   across processes, platforms, and consumption patterns (a replica's
   n-th draw never depends on how other replicas interleave).
"""

from __future__ import annotations

import hashlib
import random

import numpy as np

_SEP = b"\x1f"  # unit separator: ("ab", "c") never collides with ("a", "bc")


def stable_seed(*parts) -> int:
    """Derive a 63-bit RNG seed from ``parts``, stably across processes.

    Parts are stringified, so any mix of ints/strings/floats works:
    ``stable_seed(seed, n_replicas, "centralized")``."""
    h = hashlib.blake2b(_SEP.join(str(p).encode() for p in parts), digest_size=8)
    return int.from_bytes(h.digest(), "little") & 0x7FFFFFFFFFFFFFFF


def lognorm_jitter(rng: random.Random, sigma: float) -> float:
    """A lognormal multiplier with mean exactly 1.0 (median < 1).

    ``lognormvariate(-sigma^2/2, sigma)`` — the mu offset cancels the
    lognormal's ``exp(sigma^2/2)`` mean inflation, so
    ``mean * lognorm_jitter(rng, s)`` has expectation ``mean``."""
    return rng.lognormvariate(-0.5 * sigma * sigma, sigma)


class LatencyStream:
    """Block-buffered, mean-preserving lognormal multiplier stream.

    The bulk-draw counterpart of :func:`lognorm_jitter`: draws ``BLOCK``
    multipliers at a time with one vectorized numpy call instead of one
    Python RNG call per event. Built on counter-based Philox keyed by a
    :func:`stable_seed` value, so the n-th draw of a stream is a pure
    function of ``(seed, n)`` — identical across processes (any
    ``PYTHONHASHSEED``), platforms, and regardless of how draws from
    *other* streams interleave with it. Each replica owns one stream, so
    batched and scalar kernels consume identical per-replica latency
    sequences whenever they run ops in the same per-replica order (the
    bit-exact parity contract).
    """

    BLOCK = 64

    __slots__ = ("sigma", "_gen", "_buf", "_i")

    def __init__(self, seed: int, sigma: float):
        self.sigma = float(sigma)
        self._gen = np.random.Generator(np.random.Philox(key=seed))
        self._buf: np.ndarray = np.empty(0)
        self._i = 0

    def jitter(self) -> float:
        """Next multiplier (mean exactly 1.0, like :func:`lognorm_jitter`)."""
        if self._i >= len(self._buf):
            z = self._gen.standard_normal(self.BLOCK)
            self._buf = np.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)
            self._i = 0
        v = self._buf[self._i]
        self._i += 1
        return float(v)

    def jitter_block(self, n: int) -> np.ndarray:
        """``n`` multipliers as one array (same stream as :meth:`jitter` —
        ``jitter_block(n)`` equals n successive ``jitter()`` calls)."""
        out = np.empty(n)
        filled = 0
        while filled < n:
            if self._i >= len(self._buf):
                z = self._gen.standard_normal(self.BLOCK)
                self._buf = np.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)
                self._i = 0
            take = min(n - filled, len(self._buf) - self._i)
            out[filled : filled + take] = self._buf[self._i : self._i + take]
            self._i += take
            filled += take
        return out

    def sample(self, mean: float) -> float:
        return mean * self.jitter()
