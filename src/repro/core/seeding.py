"""Deterministic-randomness utilities shared by every simulation layer.

Two fixes live here:

1. **Stable seeds.** The seed repo derived per-stream seeds with
   ``(seed, n, design).__hash__()`` — but ``str.__hash__`` is randomized
   per process (PYTHONHASHSEED), so two runs of the same benchmark in
   different processes drew *different* random streams: "deterministic per
   seed" only held within one interpreter. Every RNG construction now goes
   through :func:`stable_seed`, a blake2b digest of the key parts, which
   is identical across processes, platforms, and Python versions.

2. **Mean-preserving jitter.** Latency samplers drew
   ``mean * lognormvariate(0, sigma)`` — but ``E[lognorm(0, s)] =
   exp(s^2/2)`` (≈1.063 at the default sigma 0.35), silently inflating
   every configured mean by 6%. :func:`lognorm_jitter` centers the draw so
   the expected value is exactly 1.0 and the configured means are the
   means that calibration against the paper's numbers assumes.
"""
from __future__ import annotations

import hashlib
import random

_SEP = b"\x1f"  # unit separator: ("ab", "c") never collides with ("a", "bc")


def stable_seed(*parts) -> int:
    """Derive a 63-bit RNG seed from ``parts``, stably across processes.

    Parts are stringified, so any mix of ints/strings/floats works:
    ``stable_seed(seed, n_replicas, "centralized")``."""
    h = hashlib.blake2b(_SEP.join(str(p).encode() for p in parts),
                        digest_size=8)
    return int.from_bytes(h.digest(), "little") & 0x7FFFFFFFFFFFFFFF


def lognorm_jitter(rng: random.Random, sigma: float) -> float:
    """A lognormal multiplier with mean exactly 1.0 (median < 1).

    ``lognormvariate(-sigma^2/2, sigma)`` — the mu offset cancels the
    lognormal's ``exp(sigma^2/2)`` mean inflation, so
    ``mean * lognorm_jitter(rng, s)`` has expectation ``mean``."""
    return rng.lognormvariate(-0.5 * sigma * sigma, sigma)
