"""Universally diverse tasks with a unified flow (§3.5, Table 3).

Every task follows the four-phase flow the paper defines — configure, reset,
operate, evaluate — regardless of domain. The suite mirrors Table 3's ten
application domains with the paper's trajectory statistics (10-25 steps per
trajectory), so the datagen benchmark can reproduce the table.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Optional

# (task_type, domain, description, trajectories, steps) — Table 3 rows
TABLE3_ROWS = [
    ("Office", "LibreOffice Writer", "Document Editing", 493, 5028),
    ("Office", "LibreOffice Calc", "Spreadsheet Editing", 222, 4240),
    ("Office", "LibreOffice Impress", "Presentation Editing", 314, 4898),
    ("Daily", "Chrome", "Web Browsing", 291, 4285),
    ("Daily", "ThunderBird", "Email", 189, 3627),
    ("Daily", "VLC", "Media Control", 107, 1701),
    ("Professional", "VS Code", "Programming", 309, 4604),
    ("Professional", "GIMP", "Image Editing", 203, 3410),
    ("Professional", "OS", "System Configuration", 491, 5333),
    ("Workflow", "Multi-Apps", "Combined Above", 244, 5709),
]


@dataclass(frozen=True)
class TaskSpec:
    task_id: str
    task_type: str
    domain: str
    description: str
    horizon: int                      # steps per trajectory (10-25)
    setup_software: tuple = ()

    def to_dict(self) -> dict:
        return {"task_id": self.task_id, "task_type": self.task_type,
                "domain": self.domain, "description": self.description,
                "horizon": self.horizon}


class TaskSuite:
    """Generates task specs matching Table 3's domain mix."""

    def __init__(self, seed: int = 0):
        self._rng = random.Random(seed)

    def sample(self, n: int) -> list[TaskSpec]:
        weights = [r[3] for r in TABLE3_ROWS]   # trajectory counts
        rows = self._rng.choices(TABLE3_ROWS, weights=weights, k=n)
        out = []
        for i, (ttype, domain, desc, _t, _s) in enumerate(rows):
            horizon = self._rng.randint(10, 25)
            out.append(TaskSpec(
                task_id=f"{domain.replace(' ', '_').lower()}-{i}",
                task_type=ttype, domain=domain, description=desc,
                horizon=horizon, setup_software=(domain,)))
        return out

    def by_domain(self, domain: str, n: int) -> list[TaskSpec]:
        row = next(r for r in TABLE3_ROWS if r[1] == domain)
        return [TaskSpec(
            task_id=f"{domain.replace(' ', '_').lower()}-{i}",
            task_type=row[0], domain=domain, description=row[2],
            horizon=self._rng.randint(10, 25), setup_software=(domain,))
            for i in range(n)]

    @staticmethod
    def domains() -> list[str]:
        return [r[1] for r in TABLE3_ROWS]
