"""Task specs with a unified four-phase flow (§3.5, Table 3).

Every task follows the flow the paper defines — configure, reset, operate,
evaluate — regardless of domain. This module holds the low-level
``TaskSpec`` record and the Table-3 statistics; the scenario *families*
that generate specs (with per-family latency profiles and scripted
policies) live in ``repro.rollout.scenarios.ScenarioRegistry``.
``TaskSuite`` is kept as a thin compatibility shim over the default
registry so existing callers and the Table-3 datagen benchmark keep
working unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.seeding import stable_seed

# (task_type, domain, description, trajectories, steps) — Table 3 rows
TABLE3_ROWS = [
    ("Office", "LibreOffice Writer", "Document Editing", 493, 5028),
    ("Office", "LibreOffice Calc", "Spreadsheet Editing", 222, 4240),
    ("Office", "LibreOffice Impress", "Presentation Editing", 314, 4898),
    ("Daily", "Chrome", "Web Browsing", 291, 4285),
    ("Daily", "ThunderBird", "Email", 189, 3627),
    ("Daily", "VLC", "Media Control", 107, 1701),
    ("Professional", "VS Code", "Programming", 309, 4604),
    ("Professional", "GIMP", "Image Editing", 203, 3410),
    ("Professional", "OS", "System Configuration", 491, 5333),
    ("Workflow", "Multi-Apps", "Combined Above", 244, 5709),
]


@dataclass(frozen=True)
class TaskSpec:
    task_id: str
    task_type: str
    domain: str
    description: str
    horizon: int                      # steps per trajectory (10-25)
    setup_software: tuple = ()
    scenario: str = ""                # registry name; "" for legacy tasks
    backend: str = "simos"            # EnvBackend the episode must run on

    def to_dict(self) -> dict:
        return {"task_id": self.task_id, "task_type": self.task_type,
                "domain": self.domain, "description": self.description,
                "horizon": self.horizon, "scenario": self.scenario,
                "backend": self.backend}


class TaskSuite:
    """Generates task specs matching Table 3's domain mix.

    Compatibility shim: sampling is delegated to the default
    ``ScenarioRegistry`` (imported lazily — ``repro.rollout`` depends on
    this module at import time, not vice versa)."""

    def __init__(self, seed: int = 0):
        self._seed = seed
        self._calls = 0

    @staticmethod
    def _registry():
        from repro.rollout.scenarios import get_default_registry
        return get_default_registry()

    def sample(self, n: int) -> list[TaskSpec]:
        self._calls += 1
        return self._registry().sample(
            n, seed=stable_seed(self._seed, self._calls))

    def by_domain(self, domain: str, n: int) -> list[TaskSpec]:
        reg = self._registry()
        scenario = next(s for s in reg if s.domain == domain)
        self._calls += 1
        return reg.tasks_for(
            scenario.name, n,
            seed=stable_seed(self._seed, self._calls))

    @staticmethod
    def domains() -> list[str]:
        return [r[1] for r in TABLE3_ROWS]
