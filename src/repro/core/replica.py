"""Simulated OS replica (the data-plane stand-in for a KVM VM).

The control plane above this class (state managers, pools, gateway, data
server) is the paper's contribution and is real; the VM itself is simulated:
deterministic screenshot observations, a calibrated latency model (boot /
reset / step / evaluate in *virtual seconds*), CoW-backed disk writes, and
seeded stochastic faults. Default latencies are calibrated so the Table-3
datagen benchmark reproduces ~1420 trajectories/min at 1024 replicas.
"""

from __future__ import annotations

import enum
import hashlib
import random
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.core.cow_store import DiskImage
from repro.core.faults import FaultInjector, FaultType, ReplicaError
from repro.core.seeding import LatencyStream, lognorm_jitter, stable_seed

SCREEN = (48, 64, 3)  # tiny deterministic "screenshot"


@dataclass
class LatencyModel:
    """Virtual-second costs (mean-preserving lognormal jitter).

    Calibrated so the *live* engine — faults, failover, and recovery all
    active — reproduces the paper's ~1420 trajectories/min at 1024
    replicas (Table 3). The hang timeout is two gateway health intervals:
    a hung replica is detected by the 10 s sweep, not by an arbitrary
    60 s client deadline."""

    boot_s: float = 12.0
    configure_s: float = 3.0
    reset_s: float = 4.0
    step_s: float = 2.15
    evaluate_s: float = 1.0
    sigma: float = 0.35
    hang_timeout_s: float = 20.0
    # known-answer canary check (§3.4 silent-failure detection): a
    # lightweight scripted reset/step against a precomputed observation
    # checksum — much cheaper than a full reset, deterministic (no
    # jitter) so probing never perturbs the replica's latency stream
    canary_s: float = 0.25

    def sample(self, rng: random.Random, mean: float) -> float:
        return mean * lognorm_jitter(rng, self.sigma)

    def stream(self, seed: int) -> LatencyStream:
        """Bulk-draw latency stream for one replica (see
        :class:`~repro.core.seeding.LatencyStream`): multipliers come from
        block numpy draws instead of per-event Python RNG calls, and the
        stream is stable across processes and event-kernel choice."""
        return LatencyStream(seed, self.sigma)


class ReplicaState(enum.Enum):
    COLD = "cold"
    BOOTING = "booting"
    READY = "ready"
    RUNNING = "running"
    CRASHED = "crashed"
    CLOSED = "closed"


@dataclass
class ReplicaResources:
    ram_gb: float = 5.0  # steady RAM (limit 6 GB per container)
    ram_limit_gb: float = 6.0
    cpu_peak_cores: float = 2.0  # burst demand
    cpu_duty: float = 0.2  # fraction of time at peak
    cpu_idle_cores: float = 0.1


class SimOSReplica:
    """A full-featured (simulated) OS sandbox with GUI.

    Also the reference implementation of the ``EnvBackend`` replica
    protocol (``repro.envs``): the lifecycle methods below (boot /
    configure / reset / step / evaluate / close, plus ``canary_probe``)
    and the ``alive`` / ``state`` / ``silent_broken`` attributes are the
    contract every backend's replica satisfies. Backend replicas
    subclass this and override ``_expected`` (their own known-answer
    canary) and, where episode semantics differ, ``evaluate``."""

    #: which EnvBackend family this replica implements (see repro.envs)
    backend_name = "simos"

    def __init__(
        self,
        replica_id: str,
        base_image: DiskImage,
        *,
        faults: Optional[FaultInjector] = None,
        seed: int = 0,
        latency: Optional[LatencyModel] = None,
        use_reflink: bool = True,
        resources: Optional[ReplicaResources] = None,
    ):
        self.replica_id = replica_id
        self.base_image = base_image
        self.faults = faults or FaultInjector(enabled=False)
        self.latency = latency or LatencyModel()
        self.resources = resources or ReplicaResources()
        self.use_reflink = use_reflink
        # two independent deterministic streams: latency jitter comes from
        # a bulk numpy LatencyStream (the batched kernel draws blocks, not
        # per-event Python RNG calls); disk-write placement keeps the
        # cheap stdlib RNG. Separate keys so neither perturbs the other.
        self._lat = self.latency.stream(stable_seed(seed, replica_id, "lat"))
        self._rng = random.Random(stable_seed(seed, replica_id))
        self.state = ReplicaState.COLD
        self.disk: Optional[DiskImage] = None
        self.task: Optional[dict] = None
        self.step_count = 0
        self.obs_nonce = 0
        # the paper's silent failure mode: exhausted host kernel limits
        # leave the VM "working" but corrupting every observation. A
        # property of the VM's host allocation, so a reboot (fresh CoW
        # overlay, same allocation) does NOT clear it — only recreation
        # on a host with headroom does (recovery ladder L3).
        self.silent_broken = False

    # ------------------------------------------------------------ lifecycle
    def boot(self) -> float:
        if self.disk is not None:
            self.disk.close()
        if self.use_reflink:
            self.disk, prov = self.base_image.clone(self.replica_id)
        else:
            self.disk, prov = self.base_image.full_copy(self.replica_id)
        self.state = ReplicaState.READY
        self.step_count = 0
        return prov + self._lat.sample(self.latency.boot_s)

    def crash(self) -> None:
        self.state = ReplicaState.CRASHED

    def close(self) -> float:
        if self.disk is not None:
            self.disk.close()
            self.disk = None
        self.state = ReplicaState.CLOSED
        return 0.1

    @property
    def alive(self) -> bool:
        return self.state in (ReplicaState.READY, ReplicaState.RUNNING)

    # ------------------------------------------------------------- task API
    def configure(self, task: dict) -> float:
        self._require_alive()
        self.task = dict(task)
        # configuration installs software -> dirties disk blocks
        self._dirty_blocks(n=8, tag="configure")
        return self._lat.sample(self.latency.configure_s)

    def reset(self) -> tuple[np.ndarray, float]:
        self._require_alive()
        assert self.task is not None, "configure before reset"
        self.step_count = 0
        self.obs_nonce += 1
        self.state = ReplicaState.RUNNING
        return (self._observation(), self._lat.sample(self.latency.reset_s))

    def step(self, action: Any) -> tuple[np.ndarray, float, bool, dict, float]:
        """Returns (obs, reward, done, info, virtual_seconds)."""
        self._require_alive()
        fault = self.faults.sample()
        dur = self._lat.sample(self.latency.step_s)
        if fault is not None:
            if fault == FaultType.CRASH:
                self.crash()
                raise ReplicaError(fault, self.replica_id)
            if fault == FaultType.HANG:
                self.crash()
                raise ReplicaError(
                    fault, f"{self.replica_id} (>{self.latency.hang_timeout_s}s)"
                )
            if fault == FaultType.PREEMPT:
                # spot reclaim: the allocation is revoked under the VM —
                # same crash state, but the manager recovers it at L2
                # (fresh respawn), never in place
                self.crash()
                raise ReplicaError(fault, f"{self.replica_id} (spot reclaim)")
            if fault == FaultType.SILENT:
                # succeeds but corrupts the observation (untuned kernel limits)
                self.step_count += 1
                return (
                    np.zeros(SCREEN, np.uint8),
                    0.0,
                    False,
                    {"silent_corruption": True},
                    dur,
                )
            raise ReplicaError(fault, self.replica_id)
        self.step_count += 1
        self._dirty_blocks(n=1, tag=f"step{self.step_count}")
        horizon = self.task.get("horizon", 15) if self.task else 15
        done = self.step_count >= horizon
        obs = self._observation()
        info: dict = {"step": self.step_count}
        if self.silent_broken:
            # persistent silent failure: the step "succeeds" but the
            # observation is garbage — flagged in info only so the
            # canary/benchmark layers can audit; the agent sees nothing
            info["silent_corruption"] = True
        return obs, 0.0, done, info, dur

    def evaluate(self) -> tuple[float, float]:
        self._require_alive()
        # deterministic outcome from (task, trajectory length)
        h = hashlib.blake2b(
            f"{self.task.get('task_id')}/{self.step_count}".encode(), digest_size=4
        ).digest()
        score = h[0] / 255.0
        return score, self._lat.sample(self.latency.evaluate_s)

    # ------------------------------------------------------------ internals
    def _require_alive(self) -> None:
        if not self.alive:
            raise ReplicaError(
                FaultType.CRASH, f"{self.replica_id} is {self.state.value}"
            )

    def _dirty_blocks(self, n: int, tag: str) -> None:
        if self.disk is None:
            return
        for _ in range(n):
            idx = self._rng.randrange(len(self.disk.blocks))
            self.disk.write_block(idx, tag)

    def canary_probe(self) -> tuple[bool, float]:
        """Known-answer health check (§3.4 silent-failure detection).

        Runs a scripted no-op reset/step whose observation is exactly
        predictable from ``(replica_id, obs_nonce, step_count)`` and
        checksums it against :func:`expected_observation`. A healthy
        replica reproduces the known answer bit-for-bit; a silently
        broken one (kernel-limit corruption) cannot. Returns
        ``(healthy, virtual_seconds)``; the cost is deterministic (no
        jitter) so probing never advances the replica's RNG stream."""
        cost = self.latency.canary_s
        if not self.alive:
            return False, cost
        got = self._observation()
        want = self._expected()
        got_sum = hashlib.blake2b(got.tobytes(), digest_size=8).digest()
        want_sum = hashlib.blake2b(want.tobytes(), digest_size=8).digest()
        return got_sum == want_sum, cost

    def _expected(self) -> np.ndarray:
        """The known-answer observation for this replica's visible state.

        Backend replicas (``repro.envs``) override this with their own
        backend-salted reference so each backend has a distinct canary."""
        return expected_observation(self.replica_id, self.obs_nonce, self.step_count)

    def _observation(self) -> np.ndarray:
        if self.silent_broken:
            # kernel-limit exhaustion: frames come back blank, silently
            return np.zeros(SCREEN, np.uint8)
        return self._expected()


_OBS_WORDS = (SCREEN[0] * SCREEN[1] * SCREEN[2]) // 8  # uint64 per frame


def expected_observation(
    replica_id: str, obs_nonce: int, step_count: int
) -> np.ndarray:
    """The known-answer observation a *healthy* replica must produce.

    Pure function of the replica's visible state — the canary probe's
    reference value. Kept module-level so detection code never needs a
    healthy twin replica to compare against.

    Frame synthesis is the single hottest call at fleet scale (once per
    reset/step plus every canary probe), so it goes straight from a
    blake2b digest of the state to raw Philox counter output — no
    ``default_rng`` construction, no bounded-integers path — about half
    the cost of the ``integers(0, 256)`` formulation it replaces."""
    d = hashlib.blake2b(
        f"{replica_id}/{obs_nonce}/{step_count}".encode(), digest_size=32
    ).digest()
    bits = np.random.Philox(
        counter=int.from_bytes(d[:16], "little"), key=int.from_bytes(d[16:], "little")
    )
    words = bits.random_raw(_OBS_WORDS).astype("<u8", copy=False)
    return words.view(np.uint8).reshape(SCREEN)
