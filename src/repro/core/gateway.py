"""Gateway layer (§3.4): task-affinity routing across executor nodes,
periodic background health checks, automatic failover when a node becomes
unreachable."""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.runner_pool import Runner, RunnerPool


@dataclass
class NodeStatus:
    healthy: bool = True
    consecutive_failures: int = 0
    last_check: float = 0.0


class Gateway:
    """Routes task executions to runner pools with affinity + failover."""

    def __init__(self, pools: list[RunnerPool], *,
                 health_interval_s: float = 10.0,
                 unhealthy_threshold: int = 3,
                 start_background: bool = False):
        assert pools, "need at least one executor node"
        self.pools = {p.node_id: p for p in pools}
        self.status = {p.node_id: NodeStatus() for p in pools}
        self.health_interval_s = health_interval_s
        self.unhealthy_threshold = unhealthy_threshold
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.failovers = 0
        if start_background:
            self.start()

    # ------------------------------------------------------------ routing
    def _affinity_order(self, task_id: str) -> list[str]:
        """Stable hash ring: preferred node first, failover order after."""
        nodes = sorted(self.pools)
        h = int.from_bytes(
            hashlib.blake2b(task_id.encode(), digest_size=8).digest(),
            "little")
        start = h % len(nodes)
        return nodes[start:] + nodes[:start]

    def acquire(self, task_id: str, timeout: Optional[float] = 1.0
                ) -> Optional[tuple[str, Runner]]:
        """Acquire a runner, honoring affinity and skipping unhealthy nodes."""
        order = self._affinity_order(task_id)
        for attempt, node in enumerate(order):
            with self._lock:
                healthy = self.status[node].healthy
            if not healthy:
                continue
            r = self.pools[node].acquire(task_id, timeout=timeout)
            if r is not None:
                if attempt > 0:
                    self.failovers += 1
                return node, r
        return None

    def release(self, node: str, runner: Runner, **kw) -> float:
        return self.pools[node].release(runner, **kw)

    # ------------------------------------------------------- health checks
    def check_now(self) -> dict:
        """One health sweep (the background loop calls this every 10 s)."""
        report = {}
        for node, pool in self.pools.items():
            h = pool.health()
            ok = h["alive"] > 0
            st = self.status[node]
            with self._lock:
                st.last_check = time.time()
                if ok:
                    st.consecutive_failures = 0
                    st.healthy = True
                else:
                    st.consecutive_failures += 1
                    if st.consecutive_failures >= self.unhealthy_threshold:
                        st.healthy = False
            report[node] = {**h, "healthy": st.healthy}
            pool.reclaim_leaked()
        return report

    def mark_unreachable(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = False

    def mark_recovered(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = True
            self.status[node].consecutive_failures = 0

    # ---------------------------------------------------------- background
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.health_interval_s):
                self.check_now()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="gateway-health")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def healthy_nodes(self) -> list[str]:
        with self._lock:
            return [n for n, s in self.status.items() if s.healthy]
