"""Gateway layer (§3.4): task routing across executor nodes, periodic
background health checks, automatic failover when a node becomes
unreachable, and a non-blocking submit API for asynchronous rollout.

Routing modes:

- ``affinity`` (default) — stable blake2b hash ring per task id; the
  failover order is the ring order. Deterministic and sticky, but blind
  to load: a node can queue while its neighbor idles.
- ``least_loaded`` — the cluster control plane's mode: nodes are ordered
  by a live load score (busy fraction + CPU-contention penalty from the
  host tracker), with the hash-ring position as a deterministic
  tie-break. Under skewed or bursty arrivals this routes around hot and
  overcommitted nodes instead of piling onto them.

Pools are **dynamically attachable**: ``add_pool`` / ``remove_pool``
work on a live event loop (the elastic autoscaler grows and drains the
fleet at runtime), and in-flight virtual acquires recompute their
candidate order on every wakeup so they see pools added after they
parked. A removed pool that still has leased runners is retired rather
than dropped: its leases release through the gateway as usual and the
pool detaches once the last one comes back.

Acquire-wait samples are **tenant-tagged**: event-mode acquires may carry
a ``tenant=`` id, and every wait sample is recorded as
``(tenant, waited_vs)`` so the autoscaler can burn per-tenant SLOs
instead of one global p95. The untagged path (``tenant=None``) is just
the single-tenant special case — same window, same series, bit-identical
behavior for existing single-job fleets.

Determinism contract: in event mode every method reads fleet state on
the single-threaded virtual clock — routing scores, health sweeps, wait
samples, and failover counts are pure functions of (fleet, seed, task
stream) and replay identically in any process.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Collection, Optional

from repro.core.event_loop import Condition as VirtualCondition
from repro.core.event_loop import EventLoop, Timer
from repro.core.runner_pool import Runner, RunnerPool
from repro.core.telemetry import Telemetry

# A thread pool sized to the fleet would spawn thousands of OS threads at
# paper-scale (1024+ runners); the executor is for modest external async
# use — the scale route is the event-driven path (attach_loop +
# RolloutEngine.run_event_driven), which needs no threads at all.
MAX_EXECUTOR_WORKERS = 64


class NoRunnerAvailable(RuntimeError):
    """No healthy node could supply a free runner within the timeout."""


@dataclass
class NodeStatus:
    healthy: bool = True
    consecutive_failures: int = 0
    last_check: float = 0.0


class Gateway:
    """Routes task executions to runner pools with affinity + failover."""

    def __init__(self, pools: list[RunnerPool], *,
                 health_interval_s: float = 10.0,
                 unhealthy_threshold: int = 3,
                 routing: str = "affinity",
                 canary_interval_s: float = 15.0,
                 telemetry: Optional[Telemetry] = None,
                 start_background: bool = False):
        assert pools, "need at least one executor node"
        assert routing in ("affinity", "least_loaded"), routing
        self.pools = {p.node_id: p for p in pools}
        self.status = {p.node_id: NodeStatus() for p in pools}
        # hash-ring node order, re-sorted only when the pool set changes:
        # sorting per acquire is O(n log n) per event and dominates routing
        # at 1000+ nodes (65k-replica fleets sweep this on every wakeup)
        self._node_ring = sorted(self.pools)
        # backend-constrained sub-rings (repro.envs): a task tagged with a
        # backend only ever routes to pools of that backend, so a SWE
        # episode cannot land on a browser pool. Cached per backend and
        # rebuilt with the node ring; key None is the unconstrained ring,
        # which on a single-backend fleet is the same list — identical
        # hash start index, bit-identical routing to the pre-backend stack
        self._backend_rings: dict[Optional[str], list[str]] = {}
        self.health_interval_s = health_interval_s
        self.unhealthy_threshold = unhealthy_threshold
        self.routing = routing
        # §3.4 silent-failure detection: the periodic known-answer sweep
        # each pool's recovery ladder runs over its free runners (virtual
        # seconds, event mode only; 0 disables)
        self.canary_interval_s = canary_interval_s
        self.telemetry = telemetry or Telemetry()
        # L4 sink installed by the cluster control plane (eviction with
        # replacement); without one, eviction just stops routing
        self.on_evict: Optional[Callable[[str], None]] = None
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._pool_executor: Optional[ThreadPoolExecutor] = None
        self._stopped = False
        self.failovers = 0
        self._retired: dict[str, RunnerPool] = {}
        # recent virtual acquire-wait samples as (tenant, waited_vs) —
        # the autoscaler's SLO-burn signal; tenant is None for untagged
        # (single-tenant) acquires
        self._wait_window: deque[tuple[Optional[str], float]] = \
            deque(maxlen=1024)
        self._loop: Optional[EventLoop] = None
        self._release_cv: Optional[VirtualCondition] = None
        self._health_timer: Optional[Timer] = None
        self._canary_timer: Optional[Timer] = None
        for p in pools:
            self._ensure_recovery(p)
        if start_background:
            self.start()

    def _ensure_recovery(self, pool: RunnerPool) -> None:
        """Install a recovery ladder on a pool that lacks one.

        Imported lazily: ``repro.recovery`` sits above the core layer
        (it composes pool + replica + telemetry), so the gateway only
        pulls it in when it actually builds a ladder."""
        if pool.recovery is None:
            from repro.recovery.ladder import RecoveryLadder, RecoveryPolicy
            policy = RecoveryPolicy()
            if self.canary_interval_s > 0:
                # one cadence knob: the per-runner probe throttle follows
                # the sweep interval
                policy.probe_interval_vs = self.canary_interval_s
            RecoveryLadder(pool, telemetry=self.telemetry, policy=policy,
                           on_evict=self._evict_node)

    def _evict_node(self, node_id: str) -> None:
        """L4 sink: with a cluster attached, evict + replace the node;
        a bare gateway just stops routing to it."""
        if self.on_evict is not None:
            self.on_evict(node_id)
        elif node_id in self.status:
            self.mark_unreachable(node_id)

    # ---------------------------------------------------------- event mode
    def attach_loop(self, loop: EventLoop, *,
                    health_checks: bool = True) -> None:
        """Make the gateway (and its pools) event-loop citizens.

        All pools share one virtual release-condition so a gateway-level
        acquire can wait for *any* node to free a runner; the periodic
        health sweep becomes a recurring daemon timer on the virtual clock
        instead of a background thread. Idempotent per loop; attaching a
        *different* loop (a fresh engine run) re-arms everything there."""
        if self._loop is loop:
            return
        if self._health_timer is not None:
            # the old timer belongs to the previous loop; drop it so the
            # sweep is re-armed on the new clock below
            self._health_timer.cancel()
            self._health_timer = None
        if self._canary_timer is not None:
            self._canary_timer.cancel()
            self._canary_timer = None
        self._loop = loop
        self._release_cv = VirtualCondition(loop)
        for p in self.pools.values():
            p.attach_loop(loop, release_cv=self._release_cv)
        if health_checks and self._health_timer is None:
            self._health_timer = loop.call_later(
                self.health_interval_s, self._health_tick, daemon=True)
        if health_checks and self.canary_interval_s > 0:
            self._canary_timer = loop.call_later(
                self.canary_interval_s, self._canary_tick, daemon=True)

    def detach_loop(self) -> None:
        """Unbind the gateway and its pools from the event loop, restoring
        thread-mode behavior (wall-clock health stamps, pool-local virtual
        time). The engine calls this when an event-driven run finishes."""
        if self._health_timer is not None:
            self._health_timer.cancel()
            self._health_timer = None
        if self._canary_timer is not None:
            self._canary_timer.cancel()
            self._canary_timer = None
        for p in self.pools.values():
            p.detach_loop()
        with self._lock:
            retired = list(self._retired.values())
            self._retired.clear()
        for p in retired:
            p.detach_loop()
        self._loop = None
        self._release_cv = None

    def _health_tick(self) -> None:
        self.check_now()
        self._health_timer = self._loop.call_later(
            self.health_interval_s, self._health_tick, daemon=True)

    def _canary_tick(self) -> None:
        """Periodic canary sweep (§3.4): each pool's recovery ladder runs
        the known-answer probe over its free runners, escalating silent
        failures through quarantine/recreation up to node eviction."""
        for _node, pool in list(self.pools.items()):
            if pool.recovery is not None:
                pool.recovery.canary_sweep()
        self._canary_timer = self._loop.call_later(
            self.canary_interval_s, self._canary_tick, daemon=True)

    # ------------------------------------------------------- dynamic pools
    def add_pool(self, pool: RunnerPool) -> None:
        """Attach a new executor node at runtime.

        Works mid-run: if the gateway is bound to an event loop, the pool
        joins the shared release-condition immediately and every parked
        acquire re-checks the (now larger) candidate set on its next
        wakeup — which this call triggers, so waiters stranded on an
        exhausted fleet see the new capacity at once."""
        if pool.node_id in self.pools or pool.node_id in self._retired:
            raise ValueError(f"node {pool.node_id!r} already attached")
        self._ensure_recovery(pool)
        with self._lock:
            self.pools[pool.node_id] = pool
            self.status[pool.node_id] = NodeStatus()
            self._node_ring = sorted(self.pools)
            self._backend_rings.clear()
        if self._loop is not None:
            pool.attach_loop(self._loop, release_cv=self._release_cv)
            self._release_cv.notify_all()

    def remove_pool(self, node_id: str) -> RunnerPool:
        """Detach an executor node at runtime; returns the pool.

        The node leaves the routing tables immediately — no new leases.
        If runners are still leased the pool is *retired*, not dropped:
        in-flight episodes keep their runners and release them through
        the gateway as usual; the pool unbinds from the loop once the
        last lease returns. Free-only pools detach right away."""
        with self._lock:
            pool = self.pools.pop(node_id)
            self.status.pop(node_id)
            self._node_ring = sorted(self.pools)
            self._backend_rings.clear()
            if pool.n_busy > 0:
                self._retired[node_id] = pool
                return pool
        pool.detach_loop()
        return pool

    @property
    def n_waiting(self) -> int:
        """Virtual acquires currently parked for a runner (queue depth)."""
        if self._release_cv is None:
            return 0
        return self._release_cv.n_waiters

    def drain_wait_samples(self) -> list[float]:
        """Hand the recent acquire-wait samples to the caller and reset
        the window (tenant tags stripped — the aggregate view)."""
        return [w for _t, w in self.drain_wait_samples_tagged()]

    def drain_wait_samples_tagged(self) -> list[tuple[Optional[str], float]]:
        """Hand the recent ``(tenant, waited_vs)`` samples to the caller
        (the autoscaler's SLO-burn tick) and reset the window. Untagged
        samples carry tenant ``None``; a stream with only ``None`` tags
        is the single-tenant special case."""
        out = list(self._wait_window)
        self._wait_window.clear()
        return out

    def _record_wait(self, waited_vs: float,
                     tenant: Optional[str] = None) -> None:
        self._wait_window.append((tenant, waited_vs))
        # telemetry is always present: __init__ defaults to a private
        # sink so the recovery ladders have somewhere to record MTTR
        self.telemetry.observe("acquire_wait_vs", waited_vs)
        if tenant is not None:
            self.telemetry.observe(f"acquire_wait_vs:{tenant}", waited_vs)

    # ------------------------------------------------------------ routing
    def _ring_for(self, backend: Optional[str]) -> list[str]:
        """The hash ring restricted to one backend's pools (None = all).

        On a heterogeneous fleet this is what keeps a SWE episode off a
        browser pool; on a single-backend fleet the restricted ring *is*
        the full ring, so routing is bit-identical to the unconstrained
        path. Cached until the pool set changes."""
        ring = self._backend_rings.get(backend)
        if ring is None:
            if backend is None:
                ring = self._node_ring
            else:
                ring = [n for n in self._node_ring
                        if self.pools[n].backend_name == backend]
            self._backend_rings[backend] = ring
        return ring

    def _affinity_order(self, task_id: str,
                        backend: Optional[str] = None) -> list[str]:
        """Stable hash ring: preferred node first, failover order after."""
        nodes = self._ring_for(backend)
        if not nodes:
            return []
        h = int.from_bytes(
            hashlib.blake2b(task_id.encode(), digest_size=8).digest(),
            "little")
        start = h % len(nodes)
        return nodes[start:] + nodes[:start]

    def _load_score(self, node: str) -> float:
        """Live load: busy fraction plus the host's CPU-contention excess.

        Both terms are deterministic functions of fleet state on the
        event loop, so least-loaded routing stays reproducible."""
        p = self.pools[node]
        busy = 1.0 - (p.n_free / p.size) if p.size else 1.0
        return busy + max(p.latency_scale() - 1.0, 0.0)

    def _route_order(self, task_id: str,
                     backend: Optional[str] = None) -> list[str]:
        """Candidate order for one acquire attempt, per routing mode.

        ``least_loaded`` sorts by the live load score and uses the hash
        ring's order as a deterministic tie-break, so an idle fleet
        routes exactly like affinity mode."""
        order = self._affinity_order(task_id, backend)
        if self.routing == "affinity" or len(order) <= 1:
            return order
        rank = {n: i for i, n in enumerate(order)}
        return sorted(order,
                      key=lambda n: (round(self._load_score(n), 9), rank[n]))

    def acquire(self, task_id: str, timeout: Optional[float] = 1.0,
                exclude: Collection[str] = (),
                backend: Optional[str] = None
                ) -> Optional[tuple[str, Runner]]:
        """Acquire a runner, honoring affinity and skipping unhealthy nodes.

        ``exclude`` removes specific nodes from consideration — used by the
        rollout engine to fail an aborted episode over to a *different* node
        even when the faulty one still reports healthy. ``backend``
        restricts candidates to pools of that EnvBackend (None = any)."""
        order = self._route_order(task_id, backend)
        for attempt, node in enumerate(order):
            if node in exclude:
                continue
            with self._lock:
                healthy = self.status[node].healthy
            if not healthy:
                continue
            r = self.pools[node].acquire(task_id, timeout=timeout)
            if r is not None:
                if attempt > 0:
                    with self._lock:
                        self.failovers += 1
                return node, r
        return None

    def try_acquire(self, task_id: str, exclude: Collection[str] = (),
                    backend: Optional[str] = None
                    ) -> Optional[tuple[str, Runner]]:
        """Non-blocking acquire: returns immediately, None if nothing free."""
        return self.acquire(task_id, timeout=0.0, exclude=exclude,
                            backend=backend)

    def acquire_ev(self, task_id: str, timeout: Optional[float] = 1.0,
                   exclude: Collection[str] = (),
                   tenant: Optional[str] = None,
                   backend: Optional[str] = None):
        """Event-loop acquire: ``got = yield from gw.acquire_ev(...)``.

        Same affinity/health/exclusion semantics as ``acquire``, but the
        calling task parks on the shared virtual release-condition until
        any pool frees a runner or ``timeout`` virtual seconds elapse —
        no thread ever blocks. Returns ``(node, runner)`` or ``None``.

        ``tenant`` tags this acquire's wait sample (window + telemetry
        series ``acquire_wait_vs:<tenant>``) so per-tenant latency SLOs
        can be tracked; ``None`` keeps the untagged single-tenant path.
        ``backend`` restricts candidates to pools of that EnvBackend.

        The candidate order is recomputed on every wakeup: pools added or
        removed while this task was parked (elastic scaling) are seen on
        the next pass, and least-loaded routing re-ranks against current
        load rather than the load at park time."""
        assert self._loop is not None, "attach_loop() before acquire_ev()"
        t0 = self._loop.now
        deadline = (None if timeout is None
                    else self._loop.now + timeout)
        while True:
            candidates = 0
            ring = self._ring_for(backend)
            if not any(self.pools[n].n_free for n in ring):
                # saturation fast path: release() wakes *every* parked
                # waiter (exclusion-aware, see runner_pool), so under a
                # deep backlog most wakeups find the one freed runner
                # already consumed. With zero free runners no acquire can
                # succeed and routing order is moot — just count healthy
                # candidates (for the nothing-can-help early return) and
                # skip the load-score sort. Bit-identical to the full
                # scan, which skips every empty pool anyway.
                for node in ring:
                    if node not in exclude and self.status[node].healthy:
                        candidates += 1
            else:
                for attempt, node in enumerate(
                        self._route_order(task_id, backend)):
                    if node in exclude or not self.status[node].healthy:
                        continue
                    candidates += 1
                    pool = self.pools[node]
                    if pool.n_free == 0:
                        # lock-free skip: the event loop is single-
                        # threaded, so an empty free list cannot refill
                        # under us — no need to pay the pool lock just to
                        # learn it is empty (the all-busy sweep is
                        # O(nodes) on every wakeup)
                        continue
                    r = pool.acquire_nowait(task_id)
                    if r is not None:
                        if attempt > 0:
                            self.failovers += 1
                        self._record_wait(self._loop.now - t0, tenant)
                        return node, r
            if candidates == 0:
                # nothing a release could fix: every node is excluded or
                # unhealthy — report immediately so the caller can clear
                # its exclusions instead of parking for the full timeout
                return None
            remaining = (None if deadline is None
                         else deadline - self._loop.now)
            if remaining is not None and remaining <= 0:
                self._record_wait(self._loop.now - t0, tenant)
                return None
            yield from self._release_cv.wait(remaining)

    def release(self, node: str, runner: Runner, **kw) -> float:
        """Return a lease; routes to retired pools too (see remove_pool).

        A retired pool whose last lease just came back is fully detached
        here — its freed runners are unreachable by routing, so there is
        nothing left for it to do on the loop. A node in neither table is
        a stale handle (the lease was already reclaimed and its drained
        pool dropped): ignore it, as ``RunnerPool.release`` does."""
        pool = self.pools.get(node)
        if pool is None:
            with self._lock:
                pool = self._retired.get(node)
            if pool is None:
                return 0.0
        dur = pool.release(runner, **kw)
        with self._lock:
            retired = self._retired.get(node)
            if retired is not None and retired.n_busy == 0:
                del self._retired[node]
            else:
                retired = None
        if retired is not None:
            retired.detach_loop()
        return dur

    # ----------------------------------------------------- async submission
    def _executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._stopped:
                raise RuntimeError("gateway stopped; no new submissions")
            if self._pool_executor is None:
                # bounded: sizing to the fleet spawned thousands of threads
                # at 1024+ replicas (see MAX_EXECUTOR_WORKERS above)
                workers = min(
                    max(sum(p.size for p in self.pools.values()), 1),
                    MAX_EXECUTOR_WORKERS)
                self._pool_executor = ThreadPoolExecutor(
                    max_workers=workers, thread_name_prefix="gateway")
            return self._pool_executor

    def submit(self, task_id: str,
               fn: Callable[[str, Runner], object], *,
               acquire_timeout: Optional[float] = 5.0,
               exclude: Collection[str] = (),
               backend: Optional[str] = None) -> Future:
        """Non-blocking task submission.

        Acquires a runner asynchronously (affinity + failover as in
        ``acquire``) and runs ``fn(node, runner)`` on it, releasing the
        runner afterwards regardless of outcome. Returns a ``Future`` that
        resolves to ``fn``'s result, or raises ``NoRunnerAvailable`` if no
        node could supply a runner within ``acquire_timeout``. The caller
        never blocks on submission. This is the general-purpose async entry
        point for external callers; ``RolloutEngine`` manages runner
        lifetimes itself via ``acquire(exclude=...)``/``release`` because
        its failover retries and release-before-write ordering need finer
        control than the acquire-run-release wrapper offers."""

        def job():
            got = self.acquire(task_id, timeout=acquire_timeout,
                               exclude=exclude, backend=backend)
            if got is None:
                raise NoRunnerAvailable(task_id)
            node, runner = got
            try:
                return fn(node, runner)
            finally:
                self.release(node, runner, task_id=task_id)

        return self._executor().submit(job)

    # ------------------------------------------------------- health checks
    def check_now(self) -> dict:
        """One health sweep (the background loop calls this every 10 s)."""
        report = {}
        for node, pool in list(self.pools.items()):
            if node not in self.status:
                continue            # removed between snapshot and sweep
            h = pool.health()
            ok = h["alive"] > 0
            st = self.status[node]
            with self._lock:
                st.last_check = (self._loop.now if self._loop is not None
                                 else time.time())
                if ok:
                    st.consecutive_failures = 0
                    st.healthy = True
                else:
                    st.consecutive_failures += 1
                    if st.consecutive_failures >= self.unhealthy_threshold:
                        st.healthy = False
            report[node] = {**h, "healthy": st.healthy}
            pool.reclaim_leaked()
            if pool.recovery is not None:
                # proactive L1/L2: dead free runners are repaired by the
                # sweep instead of waiting for an acquire to find them
                pool.recovery.heal_free_dead()
        return report

    def mark_unreachable(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = False

    def mark_recovered(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = True
            self.status[node].consecutive_failures = 0

    # ---------------------------------------------------------- background
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        with self._lock:
            self._stopped = False

        def loop():
            while not self._stop.wait(self.health_interval_s):
                self.check_now()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="gateway-health")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        with self._lock:
            self._stopped = True
            ex, self._pool_executor = self._pool_executor, None
        if ex is not None:
            ex.shutdown(wait=True)

    def healthy_nodes(self) -> list[str]:
        with self._lock:
            return [n for n, s in self.status.items() if s.healthy]
