"""Gateway layer (§3.4): task-affinity routing across executor nodes,
periodic background health checks, automatic failover when a node becomes
unreachable, and a non-blocking submit API for asynchronous rollout.
"""
from __future__ import annotations

import hashlib
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Collection, Optional

from repro.core.event_loop import Condition as VirtualCondition
from repro.core.event_loop import EventLoop, Timer
from repro.core.runner_pool import Runner, RunnerPool

# A thread pool sized to the fleet would spawn thousands of OS threads at
# paper-scale (1024+ runners); the executor is for modest external async
# use — the scale route is the event-driven path (attach_loop +
# RolloutEngine.run_event_driven), which needs no threads at all.
MAX_EXECUTOR_WORKERS = 64


class NoRunnerAvailable(RuntimeError):
    """No healthy node could supply a free runner within the timeout."""


@dataclass
class NodeStatus:
    healthy: bool = True
    consecutive_failures: int = 0
    last_check: float = 0.0


class Gateway:
    """Routes task executions to runner pools with affinity + failover."""

    def __init__(self, pools: list[RunnerPool], *,
                 health_interval_s: float = 10.0,
                 unhealthy_threshold: int = 3,
                 start_background: bool = False):
        assert pools, "need at least one executor node"
        self.pools = {p.node_id: p for p in pools}
        self.status = {p.node_id: NodeStatus() for p in pools}
        self.health_interval_s = health_interval_s
        self.unhealthy_threshold = unhealthy_threshold
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._pool_executor: Optional[ThreadPoolExecutor] = None
        self._stopped = False
        self.failovers = 0
        self._loop: Optional[EventLoop] = None
        self._release_cv: Optional[VirtualCondition] = None
        self._health_timer: Optional[Timer] = None
        if start_background:
            self.start()

    # ---------------------------------------------------------- event mode
    def attach_loop(self, loop: EventLoop, *,
                    health_checks: bool = True) -> None:
        """Make the gateway (and its pools) event-loop citizens.

        All pools share one virtual release-condition so a gateway-level
        acquire can wait for *any* node to free a runner; the periodic
        health sweep becomes a recurring daemon timer on the virtual clock
        instead of a background thread. Idempotent per loop; attaching a
        *different* loop (a fresh engine run) re-arms everything there."""
        if self._loop is loop:
            return
        if self._health_timer is not None:
            # the old timer belongs to the previous loop; drop it so the
            # sweep is re-armed on the new clock below
            self._health_timer.cancel()
            self._health_timer = None
        self._loop = loop
        self._release_cv = VirtualCondition(loop)
        for p in self.pools.values():
            p.attach_loop(loop, release_cv=self._release_cv)
        if health_checks and self._health_timer is None:
            self._health_timer = loop.call_later(
                self.health_interval_s, self._health_tick, daemon=True)

    def detach_loop(self) -> None:
        """Unbind the gateway and its pools from the event loop, restoring
        thread-mode behavior (wall-clock health stamps, pool-local virtual
        time). The engine calls this when an event-driven run finishes."""
        if self._health_timer is not None:
            self._health_timer.cancel()
            self._health_timer = None
        for p in self.pools.values():
            p.detach_loop()
        self._loop = None
        self._release_cv = None

    def _health_tick(self) -> None:
        self.check_now()
        self._health_timer = self._loop.call_later(
            self.health_interval_s, self._health_tick, daemon=True)

    # ------------------------------------------------------------ routing
    def _affinity_order(self, task_id: str) -> list[str]:
        """Stable hash ring: preferred node first, failover order after."""
        nodes = sorted(self.pools)
        h = int.from_bytes(
            hashlib.blake2b(task_id.encode(), digest_size=8).digest(),
            "little")
        start = h % len(nodes)
        return nodes[start:] + nodes[:start]

    def acquire(self, task_id: str, timeout: Optional[float] = 1.0,
                exclude: Collection[str] = ()
                ) -> Optional[tuple[str, Runner]]:
        """Acquire a runner, honoring affinity and skipping unhealthy nodes.

        ``exclude`` removes specific nodes from consideration — used by the
        rollout engine to fail an aborted episode over to a *different* node
        even when the faulty one still reports healthy."""
        order = self._affinity_order(task_id)
        for attempt, node in enumerate(order):
            if node in exclude:
                continue
            with self._lock:
                healthy = self.status[node].healthy
            if not healthy:
                continue
            r = self.pools[node].acquire(task_id, timeout=timeout)
            if r is not None:
                if attempt > 0:
                    with self._lock:
                        self.failovers += 1
                return node, r
        return None

    def try_acquire(self, task_id: str, exclude: Collection[str] = ()
                    ) -> Optional[tuple[str, Runner]]:
        """Non-blocking acquire: returns immediately, None if nothing free."""
        return self.acquire(task_id, timeout=0.0, exclude=exclude)

    def acquire_ev(self, task_id: str, timeout: Optional[float] = 1.0,
                   exclude: Collection[str] = ()):
        """Event-loop acquire: ``got = yield from gw.acquire_ev(...)``.

        Same affinity/health/exclusion semantics as ``acquire``, but the
        calling task parks on the shared virtual release-condition until
        any pool frees a runner or ``timeout`` virtual seconds elapse —
        no thread ever blocks. Returns ``(node, runner)`` or ``None``."""
        assert self._loop is not None, "attach_loop() before acquire_ev()"
        deadline = (None if timeout is None
                    else self._loop.now + timeout)
        order = self._affinity_order(task_id)
        while True:
            candidates = 0
            for attempt, node in enumerate(order):
                if node in exclude or not self.status[node].healthy:
                    continue
                candidates += 1
                r = self.pools[node].acquire_nowait(task_id)
                if r is not None:
                    if attempt > 0:
                        self.failovers += 1
                    return node, r
            if candidates == 0:
                # nothing a release could fix: every node is excluded or
                # unhealthy — report immediately so the caller can clear
                # its exclusions instead of parking for the full timeout
                return None
            remaining = (None if deadline is None
                         else deadline - self._loop.now)
            if remaining is not None and remaining <= 0:
                return None
            yield from self._release_cv.wait(remaining)

    def release(self, node: str, runner: Runner, **kw) -> float:
        return self.pools[node].release(runner, **kw)

    # ----------------------------------------------------- async submission
    def _executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._stopped:
                raise RuntimeError("gateway stopped; no new submissions")
            if self._pool_executor is None:
                # bounded: sizing to the fleet spawned thousands of threads
                # at 1024+ replicas (see MAX_EXECUTOR_WORKERS above)
                workers = min(
                    max(sum(p.size for p in self.pools.values()), 1),
                    MAX_EXECUTOR_WORKERS)
                self._pool_executor = ThreadPoolExecutor(
                    max_workers=workers, thread_name_prefix="gateway")
            return self._pool_executor

    def submit(self, task_id: str,
               fn: Callable[[str, Runner], object], *,
               acquire_timeout: Optional[float] = 5.0,
               exclude: Collection[str] = ()) -> Future:
        """Non-blocking task submission.

        Acquires a runner asynchronously (affinity + failover as in
        ``acquire``) and runs ``fn(node, runner)`` on it, releasing the
        runner afterwards regardless of outcome. Returns a ``Future`` that
        resolves to ``fn``'s result, or raises ``NoRunnerAvailable`` if no
        node could supply a runner within ``acquire_timeout``. The caller
        never blocks on submission. This is the general-purpose async entry
        point for external callers; ``RolloutEngine`` manages runner
        lifetimes itself via ``acquire(exclude=...)``/``release`` because
        its failover retries and release-before-write ordering need finer
        control than the acquire-run-release wrapper offers."""

        def job():
            got = self.acquire(task_id, timeout=acquire_timeout,
                               exclude=exclude)
            if got is None:
                raise NoRunnerAvailable(task_id)
            node, runner = got
            try:
                return fn(node, runner)
            finally:
                self.release(node, runner, task_id=task_id)

        return self._executor().submit(job)

    # ------------------------------------------------------- health checks
    def check_now(self) -> dict:
        """One health sweep (the background loop calls this every 10 s)."""
        report = {}
        for node, pool in self.pools.items():
            h = pool.health()
            ok = h["alive"] > 0
            st = self.status[node]
            with self._lock:
                st.last_check = (self._loop.now if self._loop is not None
                                 else time.time())
                if ok:
                    st.consecutive_failures = 0
                    st.healthy = True
                else:
                    st.consecutive_failures += 1
                    if st.consecutive_failures >= self.unhealthy_threshold:
                        st.healthy = False
            report[node] = {**h, "healthy": st.healthy}
            pool.reclaim_leaked()
        return report

    def mark_unreachable(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = False

    def mark_recovered(self, node: str) -> None:
        with self._lock:
            self.status[node].healthy = True
            self.status[node].consecutive_failures = 0

    # ---------------------------------------------------------- background
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        with self._lock:
            self._stopped = False

        def loop():
            while not self._stop.wait(self.health_interval_s):
                self.check_now()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="gateway-health")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        with self._lock:
            self._stopped = True
            ex, self._pool_executor = self._pool_executor, None
        if ex is not None:
            ex.shutdown(wait=True)

    def healthy_nodes(self) -> list[str]:
        with self._lock:
            return [n for n, s in self.status.items() if s.healthy]
