"""Robust container pool (§3.4): pre-warmed fixed-size runner pool, resource
guard, kernel-limits tuning, leaked-task reclamation.

A *runner* is (replica + its decentralized state manager). The pool
pre-creates every runner before training begins and recycles them between
tasks. Creation is gated by the resource guard (simulated /proc/meminfo and
/proc/loadavg): blocked if available memory < 10% or < 8 GB absolute,
accounting in-flight creations at their 6 GB container limit. Kernel limits
(fd / inotify / AIO / conntrack) are enforced: exceeding an untuned limit
produces *silent* replica failures, reproducing the paper's failure mode.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.cow_store import DiskImage
from repro.core.event_loop import Condition as VirtualCondition
from repro.core.event_loop import EventLoop, Timer
from repro.core.faults import FaultInjector
from repro.core.replica import LatencyModel
from repro.core.state_manager import ReplicaStateManager


# ------------------------------------------------------------- host model
@dataclass
class HostSpec:
    cores: int = 88
    ram_gb: float = 768.0
    # untuned kernel defaults (the paper's §3.4 examples)
    limits: dict = field(default_factory=lambda: {
        "fs.aio-max-nr": 65536,
        "fs.inotify.max_user_instances": 128,
        "fs.file-max": 65536,
        "net.netfilter.nf_conntrack_max": 65536,
    })


TUNED_LIMITS = {
    "fs.aio-max-nr": 1048576,
    "fs.inotify.max_user_instances": 8192,
    "fs.file-max": 4194304,
    "net.netfilter.nf_conntrack_max": 1048576,
}

# per-VM kernel resource consumption (qemu + docker + GUI stack)
PER_VM_USAGE = {
    "fs.aio-max-nr": 1024,
    "fs.inotify.max_user_instances": 4,
    "fs.file-max": 512,
    "net.netfilter.nf_conntrack_max": 600,
}


HOST_OS_BASELINE_GB = 4.0


class SimHost:
    """Simulated executor node: RAM accounting + kernel limit registry.

    RAM is accounted as baseline + sum of live VM allocations, with
    ``free_vm`` clamped to what was actually allocated: a double-free (or
    a free of a VM that was never allocated) cannot drag the gauge below
    the host-OS baseline or leak negative kernel-resource counts."""

    def __init__(self, spec: Optional[HostSpec] = None):
        self.spec = spec or HostSpec()
        self.limits = dict(self.spec.limits)
        self.used: dict[str, int] = {k: 0 for k in self.limits}
        self._vm_ram_gb = 0.0           # sum of live VM allocations
        self._vm_count = 0
        self._lock = threading.Lock()

    @property
    def ram_used_gb(self) -> float:
        return HOST_OS_BASELINE_GB + self._vm_ram_gb

    @property
    def vm_count(self) -> int:
        return self._vm_count

    def tune_limits(self) -> None:
        self.limits.update(TUNED_LIMITS)

    def meminfo(self) -> dict:
        """Simulated /proc/meminfo (GB)."""
        total = self.spec.ram_gb
        avail = max(total - self.ram_used_gb, 0.0)
        return {"MemTotal": total, "MemAvailable": avail}

    def loadavg(self) -> float:
        return min(self.used.get("fs.file-max", 0) / 512 * 0.5,
                   self.spec.cores * 1.5)

    def allocate_vm(self, ram_gb: float) -> bool:
        """Consume kernel resources for one VM. Returns False on silent
        exhaustion (untuned limits)."""
        with self._lock:
            self._vm_ram_gb += ram_gb
            self._vm_count += 1
            ok = True
            for k, v in PER_VM_USAGE.items():
                self.used[k] += v
                if self.used[k] > self.limits.get(k, 1 << 62):
                    ok = False   # silent failure — no exception raised
            return ok

    def free_vm(self, ram_gb: float) -> None:
        """Release one VM's resources; over-frees are clamped, not applied.

        Freeing with no live VM allocation is a no-op, and the RAM release
        never exceeds the outstanding allocated total — the gauge cannot
        drift below the host-OS baseline however unbalanced the calls."""
        with self._lock:
            if self._vm_count <= 0:
                return
            self._vm_count -= 1
            self._vm_ram_gb -= min(ram_gb, self._vm_ram_gb)
            for k, v in PER_VM_USAGE.items():
                self.used[k] = max(self.used[k] - v, 0)


@dataclass
class ResourceGuard:
    """Paper §3.4: block VM creation when headroom is too small."""

    host: SimHost
    min_fraction: float = 0.10
    min_absolute_gb: float = 8.0
    inflight_vm_gb: float = 6.0

    def __post_init__(self):
        self._inflight = 0
        self._lock = threading.Lock()

    def try_begin_creation(self) -> bool:
        with self._lock:
            mem = self.host.meminfo()
            headroom = (mem["MemAvailable"]
                        - self._inflight * self.inflight_vm_gb)
            if headroom < self.min_absolute_gb:
                return False
            if headroom / mem["MemTotal"] < self.min_fraction:
                return False
            self._inflight += 1
            return True

    def end_creation(self) -> None:
        with self._lock:
            self._inflight = max(self._inflight - 1, 0)

    @property
    def inflight(self) -> int:
        return self._inflight


# sentinel task id for runners the canary probe (or an in-place repair)
# is holding out of circulation; release() ignores it like any stale lease
PROBE_TASK_ID = "__probe__"


@dataclass
class Runner:
    runner_id: str
    manager: ReplicaStateManager
    busy: bool = False
    task_id: Optional[str] = None
    deadline_vt: float = float("inf")   # leaked-task reclamation
    silent_broken: bool = False
    broken_since_vt: Optional[float] = None   # detection-latency anchor
    boot_vs: float = 0.0                # provisioning cost of last boot
    last_probe_vt: float = float("-inf")      # canary cadence bookkeeping
    reclaim_timer: Optional[Timer] = field(default=None, repr=False)

    def mark_silent_broken(self, vt: float = 0.0) -> None:
        """Silently corrupt this runner (kernel-limit exhaustion): every
        observation from here on is garbage, nothing raises. ``vt``
        anchors the canary's detection-latency measurement."""
        self.silent_broken = True
        self.manager.replica.silent_broken = True
        if self.broken_since_vt is None:
            self.broken_since_vt = vt


class RunnerPool:
    """Fixed-size pre-warmed pool with recycle + reclamation (§3.4)."""

    def __init__(self, node_id: str, base_image: DiskImage, *,
                 size: int = 128, host: Optional[SimHost] = None,
                 faults: Optional[FaultInjector] = None,
                 tune_limits: bool = True, seed: int = 0,
                 latency: Optional[LatencyModel] = None,
                 task_timeout_vs: float = 600.0,
                 backend=None):
        self.node_id = node_id
        self.base_image = base_image
        if backend is None:
            # lazy: repro.envs sits above the replica layer (it subclasses
            # SimOSReplica), so the default backend is resolved at pool
            # construction, never at module import
            from repro.envs.simos import SimOSBackend
            backend = SimOSBackend()
        # which EnvBackend this pool's runners implement: every runner is
        # built by the backend's factory, and the gateway routes tasks
        # only to pools whose backend matches the task's
        self.backend = backend
        self.host = host or SimHost()
        if tune_limits:
            self.host.tune_limits()
        self.guard = ResourceGuard(self.host)
        self.task_timeout_vs = task_timeout_vs
        self._faults = faults or FaultInjector(enabled=False)
        self._latency = latency
        self._seed = seed
        self._free: deque[Runner] = deque()
        self._all: dict[str, Runner] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.prewarm_seconds = 0.0
        self.blocked_creations = 0
        self._next_idx = 0               # monotone runner-id counter
        self._vt = 0.0                   # pool-local virtual clock
        self._loop: Optional[EventLoop] = None
        self._ev_cv: Optional[VirtualCondition] = None
        # multi-layer fault recovery (§3.4): installed via
        # attach_recovery() — typically by the Gateway, which builds a
        # repro.recovery.RecoveryLadder per pool. Without one, release
        # falls back to bare in-place recovery as before.
        self.recovery = None
        self.evicted = False             # L4: node removed from routing
        self.quarantined: list[Runner] = []
        self._quarantined_ids: set[str] = set()
        # cluster hook: a live per-host CPU-contention factor (>= 1.0)
        # multiplying every replica operation's virtual latency — see
        # repro.cluster.host.Host.contention_factor
        self.latency_scale_fn: Optional[Callable[[], float]] = None
        self._prewarm(size)

    # ------------------------------------------------------------ prewarm
    def _make_runner(self, i: int) -> Optional[Runner]:
        if not self.guard.try_begin_creation():
            self.blocked_creations += 1
            return None
        try:
            rid = f"{self.node_id}/r{i}"
            # delegated to the pool's EnvBackend; the SimOS backend
            # forwards these arguments to SimOSReplica verbatim, so the
            # default path is bit-identical to direct construction
            rep = self.backend.make_replica(
                rid, self.base_image,
                faults=self._faults.scaled(1.0),
                seed=self._seed + i, latency=self._latency)
            ok = self.host.allocate_vm(rep.resources.ram_limit_gb)
            boot_s = rep.boot()
            runner = Runner(rid, ReplicaStateManager(rep))
            runner.boot_vs = boot_s
            if not ok:
                runner.mark_silent_broken(self.vt)
            self.prewarm_seconds += boot_s
            if self.recovery is not None:
                self.recovery.watch(runner)
            return runner
        finally:
            self.guard.end_creation()

    def _prewarm(self, size: int) -> None:
        for _ in range(size):
            r = self._make_runner(self._next_idx)
            if r is None:
                break
            self._next_idx += 1
            self._all[r.runner_id] = r
            self._free.append(r)

    # -------------------------------------------------------------- elasticity
    def grow(self, n: int) -> int:
        """Add up to ``n`` freshly-booted runners; returns how many were
        actually created (the resource guard may refuse some). Runner ids
        continue the pool's monotone counter, so grown runners draw fresh,
        stable per-replica random streams."""
        created = 0
        for _ in range(n):
            r = self._make_runner(self._next_idx)
            if r is None:
                break
            self._next_idx += 1
            with self._cv:
                self._all[r.runner_id] = r
                self._free.append(r)
                self._cv.notify()
            created += 1
        if created and self._ev_cv is not None:
            self._ev_cv.notify_all()
        return created

    def shrink(self, n: int) -> int:
        """Retire up to ``n`` *free* runners; returns how many were retired.

        A leased (busy) runner is never reclaimed — shrink only ever takes
        from the free deque, so an in-flight episode cannot lose its
        replica out from under it. Retired runners release their VM's RAM
        and kernel resources back to the host."""
        retired: list[Runner] = []
        with self._cv:
            for _ in range(min(n, len(self._free))):
                r = self._free.pop()    # back of the deque: farthest
                #                         from being issued next
                del self._all[r.runner_id]
                retired.append(r)
        for r in retired:
            self.host.free_vm(r.manager.replica.resources.ram_limit_gb)
            r.manager.close()
        return len(retired)

    # ----------------------------------------------------------- recovery
    def attach_recovery(self, ladder) -> None:
        """Install a ``repro.recovery.RecoveryLadder`` on this pool.

        The ladder takes over release-path healing (L1→L2 escalation),
        reboots reclaimed runners from the CoW base, and is the target
        of the gateway's periodic canary sweep (silent-failure
        detection, L3 quarantine/recreation, L4 eviction)."""
        self.recovery = ladder

    # --------------------------------------------------------- event mode
    def attach_loop(self, loop: EventLoop,
                    release_cv: Optional[VirtualCondition] = None) -> None:
        """Make the pool an event-loop citizen.

        The pool's virtual clock becomes the loop's clock, acquisition
        waits park on a virtual condition variable instead of a real
        thread, and every acquire arms a daemon timer that reclaims the
        runner if its task leaks past ``task_timeout_vs`` — reclamation
        fires from virtual-time advancement, no polling sweep required.
        ``release_cv`` lets the gateway share one wakeup channel across
        its pools. Event mode is single-threaded by construction: do not
        mix it with the blocking ``acquire`` path on other threads."""
        self._loop = loop
        self._ev_cv = release_cv or VirtualCondition(loop)

    def detach_loop(self) -> None:
        """Unbind from the event loop so threaded mode works again.

        The loop's final time folds into the pool-local clock (virtual
        time is monotone), so a later ``advance_time`` + ``reclaim_leaked``
        sweep sees a moving clock instead of the dead loop's frozen one."""
        if self._loop is not None:
            self._vt = max(self._vt, self._loop.now)
        self._loop = None
        self._ev_cv = None

    @property
    def vt(self) -> float:
        """Pool virtual time: the event loop's clock when attached."""
        return self._loop.now if self._loop is not None else self._vt

    # ------------------------------------------------------------ acquire
    def _take_locked(self, task_id: str) -> Runner:
        r = self._free.popleft()
        r.busy = True
        r.task_id = task_id
        r.deadline_vt = self.vt + self.task_timeout_vs
        if self._loop is not None:
            # leak guard: fires only if the task never releases the
            # runner. Scheduled at *exactly* the deadline — no epsilon
            # fudge: reclaim_leaked treats vt == deadline as leaked, and
            # the event loop's (time, sequence) ordering is the
            # deterministic tie-break. A timer armed here at acquire
            # time always carries a lower sequence number than a release
            # event scheduled later for the same timestamp, so a release
            # landing exactly at the deadline loses to reclamation and
            # degrades to a stale no-op — never a double-issue race.
            r.reclaim_timer = self._loop.call_later(
                self.task_timeout_vs, self.reclaim_leaked, daemon=True)
        return r

    def acquire(self, task_id: str, timeout: Optional[float] = None
                ) -> Optional[Runner]:
        """Blocking acquire (thread mode) with a deadline loop.

        A single ``Condition.wait`` is not enough: a spurious wakeup, or a
        competing waiter stealing the runner freed between ``notify`` and
        re-acquiring the lock, would return ``None`` long before the
        timeout elapsed. Loop until a runner is actually free or the
        deadline passes."""
        with self._cv:
            if timeout is None:
                while not self._free:
                    self._cv.wait()
            else:
                deadline = time.monotonic() + timeout
                while not self._free:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cv.wait(timeout=remaining)
            return self._take_locked(task_id)

    def acquire_nowait(self, task_id: str) -> Optional[Runner]:
        """Non-blocking take — the event-driven acquire primitive."""
        with self._lock:
            if not self._free:
                return None
            return self._take_locked(task_id)

    def acquire_ev(self, task_id: str, timeout: Optional[float] = None):
        """Event-loop acquire: ``runner = yield from pool.acquire_ev(...)``.

        Parks the calling task on the virtual condition until a runner
        frees (release or reclamation) or ``timeout`` virtual seconds
        elapse; returns ``None`` on timeout, like ``acquire``."""
        assert self._loop is not None, "attach_loop() before acquire_ev()"
        deadline = (None if timeout is None
                    else self._loop.now + timeout)
        while True:
            r = self.acquire_nowait(task_id)
            if r is not None:
                return r
            remaining = (None if deadline is None
                         else deadline - self._loop.now)
            if remaining is not None and remaining <= 0:
                return None
            yield from self._ev_cv.wait(remaining)
            # re-check: another waiter may have taken the freed runner

    def release(self, runner: Runner, *, recycle: bool = True,
                task_id: Optional[str] = None) -> float:
        """Return a runner to the pool; recycle = reset to a clean state.

        Stale handles are ignored: if the runner leaked past its timeout,
        reclamation already freed it (and may have re-issued it to another
        task), so the original holder's late release must not append it a
        second time — that would hand one replica to two episodes. Pass
        ``task_id`` to make the staleness check exact; without it, a
        runner that is no longer busy is treated as stale."""
        quarantine_after = False
        with self._cv:
            if not runner.busy or (task_id is not None
                                   and runner.task_id != task_id):
                return 0.0
            dur = 0.0
            quarantine_after = (self.recovery is not None
                                and self.evicted and runner.silent_broken)
            if recycle and not quarantine_after:
                # under the pool lock so reclamation cannot observe the
                # runner mid-recovery; the ladder escalates L1 -> L2 when
                # in-place recovery does not bring the replica back
                if self.recovery is not None:
                    dur += self.recovery.heal(runner)
                elif not runner.manager.replica.alive:
                    dur += runner.manager.recover_if_needed()
            runner.busy = False
            runner.task_id = None
            runner.deadline_vt = float("inf")
            if runner.reclaim_timer is not None:
                runner.reclaim_timer.cancel()
                runner.reclaim_timer = None
            if not quarantine_after:
                self._free.append(runner)
                self._cv.notify()
        if quarantine_after:
            # the node was evicted (L4) while this lease was in flight:
            # a silently-broken runner returning to a dead node is
            # quarantined on the spot instead of going back to free
            self.quarantine(runner)
            self.recovery.note_quarantined(runner)
            return dur
        if self._ev_cv is not None:
            # wake every virtual waiter: waiters carry per-episode node
            # exclusions, so the frontmost one may refuse this runner and a
            # single notify would strand it (lost wakeup); refused waiters
            # just re-check and re-park, which is cheap on the loop
            self._ev_cv.notify_all()
        if recycle and self.recovery is not None:
            # release-path canary (throttled to the probe interval): a
            # saturated fleet re-leases runners instantly, so this is the
            # only point where a busy silently-broken runner is ever seen
            dur += self.recovery.maybe_probe_released(runner)
        return dur

    def advance_time(self, dt: float) -> None:
        with self._lock:
            self._vt += dt

    def reclaim_leaked(self) -> list[str]:
        """Reclaim runners whose task reached the timeout (leaked).

        ``vt >= deadline`` (not strict ``>``): the reclaim timer fires at
        exactly the deadline, and at-deadline ties resolve by the event
        loop's sequence order — see ``_take_locked``. With a recovery
        ladder attached, a leaked task marks the VM suspect: the runner
        is rebooted from the CoW base (L2) and, on the event loop, only
        returns to service once the reboot's virtual latency has
        elapsed. In thread mode the reboot completes synchronously and
        the runner frees immediately: the pool-local clock has no
        scheduler to defer availability on, and nudging it forward would
        prematurely expire every other lease's deadline — the repair
        still lands in MTTR telemetry, like every thread-mode duration
        that has no caller to charge. The event-driven path is the
        faithful one, as everywhere else at scale."""
        reclaimed = []
        rebooting: list[tuple[Runner, float]] = []
        with self._cv:
            for r in self._all.values():
                if r.busy and r.task_id != PROBE_TASK_ID \
                        and self.vt >= r.deadline_vt:
                    tid, r.task_id = r.task_id, None
                    r.busy = False
                    r.deadline_vt = float("inf")
                    if r.reclaim_timer is not None:
                        r.reclaim_timer.cancel()
                        r.reclaim_timer = None
                    dur = 0.0
                    if self.recovery is not None:
                        dur = self.recovery.on_reclaimed(r)
                    if dur > 0 and self._loop is not None:
                        # hold the runner out of service while it reboots
                        r.busy = True
                        r.task_id = PROBE_TASK_ID
                        rebooting.append((r, dur))
                    else:
                        self._free.append(r)
                    reclaimed.append(tid)
            if reclaimed:
                self._cv.notify_all()
        for r, dur in rebooting:
            self._loop.call_later(dur, self._finish_probe, r)
        if reclaimed and self._ev_cv is not None:
            self._ev_cv.notify_all()    # see release(): exclusion-aware wake
        return reclaimed

    # ----------------------------------------- canary / quarantine plumbing
    def free_runners(self) -> list[Runner]:
        """Snapshot of the free deque (canary sweep iteration order)."""
        with self._lock:
            return list(self._free)

    def hold_for_probe(self, runner: Runner) -> bool:
        """Take one specific *free* runner out of circulation for a canary
        probe or an in-place repair. Returns False if it is no longer
        free (a concurrent acquire won the race)."""
        with self._cv:
            try:
                self._free.remove(runner)
            except ValueError:
                return False
            runner.busy = True
            runner.task_id = PROBE_TASK_ID
            return True

    def end_probe(self, runner: Runner, after_vs: float = 0.0) -> None:
        """Return a held runner to service after ``after_vs`` virtual
        seconds (probe + repair latency) on the event loop; immediately
        in thread mode, where callers account durations themselves."""
        if self._loop is not None and after_vs > 0:
            self._loop.call_later(after_vs, self._finish_probe, runner)
        else:
            self._finish_probe(runner)

    def _finish_probe(self, runner: Runner) -> None:
        with self._cv:
            if runner.runner_id not in self._all \
                    or runner.task_id != PROBE_TASK_ID:
                return    # quarantined (or re-issued) while held
            runner.busy = False
            runner.task_id = None
            self._free.append(runner)
            self._cv.notify()
        if self._ev_cv is not None:
            self._ev_cv.notify_all()

    def quarantine(self, runner: Runner) -> None:
        """Permanently remove a broken runner from service (ladder L3/L4).

        The runner leaves the issue tables, its VM's RAM and kernel
        resources return to the host (so a replacement allocation can
        succeed where this one silently failed), and its manager closes.
        Works on runners that were never registered too — a ``recreate``
        replacement born broken still holds a VM allocation that must be
        freed. Quarantined runners never serve a trajectory again."""
        with self._cv:
            if runner.runner_id in self._quarantined_ids:
                return
            self._quarantined_ids.add(runner.runner_id)
            self._all.pop(runner.runner_id, None)
            try:
                self._free.remove(runner)
            except ValueError:
                pass
            runner.busy = False
            runner.task_id = None
            runner.deadline_vt = float("inf")
            if runner.reclaim_timer is not None:
                runner.reclaim_timer.cancel()
                runner.reclaim_timer = None
            self.quarantined.append(runner)
        self.host.free_vm(runner.manager.replica.resources.ram_limit_gb)
        runner.manager.close()

    def recreate(self, runner: Runner) -> tuple[Optional[Runner], float]:
        """Quarantine ``runner`` and build a replacement on a fresh VM
        allocation (ladder L3). The replacement is *not* yet in service:
        the caller charges its boot latency on the virtual clock and then
        calls ``put_in_service``. Returns ``(replacement, boot_vs)`` —
        ``(None, 0.0)`` when the resource guard refuses the creation."""
        self.quarantine(runner)
        r = self._make_runner(self._next_idx)
        if r is None:
            return None, 0.0
        self._next_idx += 1
        return r, r.boot_vs

    def put_in_service(self, runner: Runner) -> None:
        """Register a ``recreate``d runner once its boot has been charged;
        it becomes acquirable immediately."""
        with self._cv:
            self._all[runner.runner_id] = runner
            self._free.append(runner)
            self._cv.notify()
        if self._ev_cv is not None:
            self._ev_cv.notify_all()

    # ------------------------------------------------------------ metrics
    @property
    def backend_name(self) -> str:
        """Routing key: the gateway's backend-constrained rings match a
        task's ``backend`` tag against this."""
        return self.backend.name

    @property
    def size(self) -> int:
        return len(self._all)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_busy(self) -> int:
        return len(self._all) - len(self._free)

    def latency_scale(self) -> float:
        """Live CPU-contention multiplier for this pool's operations
        (1.0 when no cluster contention tracker is installed)."""
        if self.latency_scale_fn is None:
            return 1.0
        return max(self.latency_scale_fn(), 1.0)

    def health(self) -> dict:
        alive = 0
        broken = 0
        healthy = 0
        with self._lock:
            for r in self._all.values():
                if r.manager.replica.alive:
                    alive += 1
                    if not r.silent_broken:
                        healthy += 1
                if r.silent_broken:
                    broken += 1
            n_quarantined = len(self.quarantined)
        return {"node": self.node_id, "backend": self.backend_name,
                "size": self.size, "alive": alive,
                "free": self.n_free,
                "healthy": healthy,
                "silent_broken": broken,
                "quarantined": n_quarantined,
                "ram_used_gb": self.host.ram_used_gb,
                "blocked_creations": self.blocked_creations}

    def close(self) -> None:
        for r in self._all.values():
            r.manager.close()
