"""Robust container pool (§3.4): pre-warmed fixed-size runner pool, resource
guard, kernel-limits tuning, leaked-task reclamation.

A *runner* is (replica + its decentralized state manager). The pool
pre-creates every runner before training begins and recycles them between
tasks. Creation is gated by the resource guard (simulated /proc/meminfo and
/proc/loadavg): blocked if available memory < 10% or < 8 GB absolute,
accounting in-flight creations at their 6 GB container limit. Kernel limits
(fd / inotify / AIO / conntrack) are enforced: exceeding an untuned limit
produces *silent* replica failures, reproducing the paper's failure mode.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.cow_store import CowStore, DiskImage
from repro.core.faults import FaultInjector, FaultType
from repro.core.replica import SimOSReplica, ReplicaResources, LatencyModel
from repro.core.state_manager import ReplicaStateManager, TaskAborted


# ------------------------------------------------------------- host model
@dataclass
class HostSpec:
    cores: int = 88
    ram_gb: float = 768.0
    # untuned kernel defaults (the paper's §3.4 examples)
    limits: dict = field(default_factory=lambda: {
        "fs.aio-max-nr": 65536,
        "fs.inotify.max_user_instances": 128,
        "fs.file-max": 65536,
        "net.netfilter.nf_conntrack_max": 65536,
    })


TUNED_LIMITS = {
    "fs.aio-max-nr": 1048576,
    "fs.inotify.max_user_instances": 8192,
    "fs.file-max": 4194304,
    "net.netfilter.nf_conntrack_max": 1048576,
}

# per-VM kernel resource consumption (qemu + docker + GUI stack)
PER_VM_USAGE = {
    "fs.aio-max-nr": 1024,
    "fs.inotify.max_user_instances": 4,
    "fs.file-max": 512,
    "net.netfilter.nf_conntrack_max": 600,
}


class SimHost:
    """Simulated executor node: RAM accounting + kernel limit registry."""

    def __init__(self, spec: Optional[HostSpec] = None):
        self.spec = spec or HostSpec()
        self.limits = dict(self.spec.limits)
        self.used: dict[str, int] = {k: 0 for k in self.limits}
        self.ram_used_gb = 4.0          # host OS baseline
        self._lock = threading.Lock()

    def tune_limits(self) -> None:
        self.limits.update(TUNED_LIMITS)

    def meminfo(self) -> dict:
        """Simulated /proc/meminfo (GB)."""
        total = self.spec.ram_gb
        avail = max(total - self.ram_used_gb, 0.0)
        return {"MemTotal": total, "MemAvailable": avail}

    def loadavg(self) -> float:
        return min(self.used.get("fs.file-max", 0) / 512 * 0.5,
                   self.spec.cores * 1.5)

    def allocate_vm(self, ram_gb: float) -> bool:
        """Consume kernel resources for one VM. Returns False on silent
        exhaustion (untuned limits)."""
        with self._lock:
            self.ram_used_gb += ram_gb
            ok = True
            for k, v in PER_VM_USAGE.items():
                self.used[k] += v
                if self.used[k] > self.limits.get(k, 1 << 62):
                    ok = False   # silent failure — no exception raised
            return ok

    def free_vm(self, ram_gb: float) -> None:
        with self._lock:
            self.ram_used_gb = max(self.ram_used_gb - ram_gb, 0.0)
            for k, v in PER_VM_USAGE.items():
                self.used[k] = max(self.used[k] - v, 0)


@dataclass
class ResourceGuard:
    """Paper §3.4: block VM creation when headroom is too small."""

    host: SimHost
    min_fraction: float = 0.10
    min_absolute_gb: float = 8.0
    inflight_vm_gb: float = 6.0

    def __post_init__(self):
        self._inflight = 0
        self._lock = threading.Lock()

    def try_begin_creation(self) -> bool:
        with self._lock:
            mem = self.host.meminfo()
            headroom = (mem["MemAvailable"]
                        - self._inflight * self.inflight_vm_gb)
            if headroom < self.min_absolute_gb:
                return False
            if headroom / mem["MemTotal"] < self.min_fraction:
                return False
            self._inflight += 1
            return True

    def end_creation(self) -> None:
        with self._lock:
            self._inflight = max(self._inflight - 1, 0)

    @property
    def inflight(self) -> int:
        return self._inflight


@dataclass
class Runner:
    runner_id: str
    manager: ReplicaStateManager
    busy: bool = False
    task_id: Optional[str] = None
    deadline_vt: float = float("inf")   # leaked-task reclamation
    silent_broken: bool = False


class RunnerPool:
    """Fixed-size pre-warmed pool with recycle + reclamation (§3.4)."""

    def __init__(self, node_id: str, base_image: DiskImage, *,
                 size: int = 128, host: Optional[SimHost] = None,
                 faults: Optional[FaultInjector] = None,
                 tune_limits: bool = True, seed: int = 0,
                 latency: Optional[LatencyModel] = None,
                 task_timeout_vs: float = 600.0):
        self.node_id = node_id
        self.base_image = base_image
        self.host = host or SimHost()
        if tune_limits:
            self.host.tune_limits()
        self.guard = ResourceGuard(self.host)
        self.task_timeout_vs = task_timeout_vs
        self._faults = faults or FaultInjector(enabled=False)
        self._latency = latency
        self._seed = seed
        self._free: deque[Runner] = deque()
        self._all: dict[str, Runner] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.prewarm_seconds = 0.0
        self.blocked_creations = 0
        self._vt = 0.0                   # pool-local virtual clock
        self._prewarm(size)

    # ------------------------------------------------------------ prewarm
    def _make_runner(self, i: int) -> Optional[Runner]:
        if not self.guard.try_begin_creation():
            self.blocked_creations += 1
            return None
        try:
            rid = f"{self.node_id}/r{i}"
            rep = SimOSReplica(
                rid, self.base_image,
                faults=self._faults.scaled(1.0),
                seed=self._seed + i, latency=self._latency)
            ok = self.host.allocate_vm(rep.resources.ram_limit_gb)
            boot_s = rep.boot()
            runner = Runner(rid, ReplicaStateManager(rep))
            runner.silent_broken = not ok
            self.prewarm_seconds += boot_s
            return runner
        finally:
            self.guard.end_creation()

    def _prewarm(self, size: int) -> None:
        for i in range(size):
            r = self._make_runner(i)
            if r is None:
                break
            self._all[r.runner_id] = r
            self._free.append(r)

    # ------------------------------------------------------------ acquire
    def acquire(self, task_id: str, timeout: Optional[float] = None
                ) -> Optional[Runner]:
        with self._cv:
            if not self._free:
                self._cv.wait(timeout=timeout)
            if not self._free:
                return None
            r = self._free.popleft()
            r.busy = True
            r.task_id = task_id
            r.deadline_vt = self._vt + self.task_timeout_vs
            return r

    def release(self, runner: Runner, *, recycle: bool = True) -> float:
        """Return a runner to the pool; recycle = reset to a clean state."""
        dur = 0.0
        if recycle and not runner.manager.replica.alive:
            dur += runner.manager.recover_if_needed()
        with self._cv:
            runner.busy = False
            runner.task_id = None
            runner.deadline_vt = float("inf")
            self._free.append(runner)
            self._cv.notify()
        return dur

    def advance_time(self, dt: float) -> None:
        with self._lock:
            self._vt += dt

    def reclaim_leaked(self) -> list[str]:
        """Reclaim runners whose task exceeded the timeout (leaked)."""
        reclaimed = []
        with self._cv:
            for r in self._all.values():
                if r.busy and self._vt > r.deadline_vt:
                    r.busy = False
                    tid, r.task_id = r.task_id, None
                    r.deadline_vt = float("inf")
                    self._free.append(r)
                    reclaimed.append(tid)
            if reclaimed:
                self._cv.notify_all()
        return reclaimed

    # ------------------------------------------------------------ metrics
    @property
    def size(self) -> int:
        return len(self._all)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def health(self) -> dict:
        alive = sum(1 for r in self._all.values()
                    if r.manager.replica.alive)
        return {"node": self.node_id, "size": self.size, "alive": alive,
                "free": self.n_free,
                "ram_used_gb": self.host.ram_used_gb,
                "blocked_creations": self.blocked_creations}

    def close(self) -> None:
        for r in self._all.values():
            r.manager.close()
