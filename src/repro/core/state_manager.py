"""OS state management: decentralized (the paper's design) plus centralized /
semi-decentralized baselines (§3.1, Figure 2).

Each ``ReplicaStateManager`` owns exactly one replica and exposes OpenAI-Gym-
style public methods (configure / reset / step / evaluate / close) plus
private low-level health & recovery methods. Faults are handled where they
occur: step-retryable errors are retried per policy; crashes trigger an
autonomous local recovery (re-clone disk from base, reboot, re-configure) —
failures never propagate beyond the replica. The manager is
backend-agnostic: it drives any replica honoring the ``EnvBackend``
lifecycle protocol (``repro.envs``), not just the SimOS oracle.

The baselines model the coordination cost the paper argues against: every
operation through a centralized manager serializes behind one dispatcher
whose per-op overhead grows with the number of managed replicas; the
semi-decentralized variant pays it per group plus an inter-group sync term.
These constants drive the Figure-6 scalability benchmark.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.faults import FaultType, ReplicaError, RetryPolicy
from repro.core.replica import SimOSReplica


class ManagerState(enum.Enum):
    COLD = "cold"
    CONFIGURED = "configured"
    READY = "ready"
    RUNNING = "running"
    EVALUATING = "evaluating"
    DONE = "done"
    FAILED = "failed"
    RECOVERING = "recovering"
    CLOSED = "closed"


@dataclass
class ManagerStats:
    steps: int = 0
    retries: int = 0
    recoveries: int = 0
    failures: int = 0
    virtual_seconds: float = 0.0


class ReplicaStateManager:
    """Decentralized per-replica state manager (one per OS replica)."""

    def __init__(self, replica: SimOSReplica,
                 retry: Optional[RetryPolicy] = None):
        self.replica = replica
        self.retry = retry or RetryPolicy()
        self.state = ManagerState.COLD
        self.stats = ManagerStats()
        self._lock = threading.Lock()  # per-replica only — no global locks
        # recovery-ladder hook: (layer, virtual_seconds) per recovery
        # action — "l0" step retries, "l1" autonomous in-place recovery,
        # "l2" forced reboots. Installed by repro.recovery.RecoveryLadder
        # so per-layer MTTR lands in telemetry; None costs nothing.
        self.recovery_observer: Optional[Callable[[str, float], None]] = None

    def _note_recovery(self, layer: str, dur: float) -> None:
        if self.recovery_observer is not None:
            self.recovery_observer(layer, dur)

    # ------------------------------------------------------------- public
    def configure(self, task: dict) -> float:
        with self._lock:
            dur = self._ensure_booted()
            dur += self.replica.configure(task)
            self.state = ManagerState.CONFIGURED
            self.stats.virtual_seconds += dur
            return dur

    def reset(self) -> tuple[Any, float]:
        with self._lock:
            obs, dur = self.replica.reset()
            self.state = ManagerState.RUNNING
            self.stats.virtual_seconds += dur
            return obs, dur

    def step(self, action: Any) -> tuple[Any, float, bool, dict, float]:
        """Step with the paper's step-level retry policy."""
        with self._lock:
            total = 0.0
            attempt = 0
            while True:
                try:
                    obs, rew, done, info, dur = self.replica.step(action)
                    total += dur
                    self.stats.steps += 1
                    self.stats.virtual_seconds += total
                    if done:
                        self.state = ManagerState.EVALUATING
                    return obs, rew, done, info, total
                except ReplicaError as e:
                    if e.fault in (FaultType.CRASH, FaultType.HANG,
                                   FaultType.PREEMPT):
                        # charge the hang timeout before detection
                        if e.fault == FaultType.HANG:
                            total += self.replica.latency.hang_timeout_s
                        if e.fault == FaultType.PREEMPT:
                            # the allocation is gone with the VM: recovery
                            # is an L2 respawn from base, not an in-place
                            # L1 repair (the cloud's reclaim notice makes
                            # detection immediate — no hang timeout)
                            total += self._recover(layer="l2")
                        else:
                            total += self._recover()
                        self.stats.virtual_seconds += total
                        self.state = ManagerState.FAILED
                        self.stats.failures += 1
                        raise TaskAborted(self.replica.replica_id,
                                          total, fault=e.fault) from e
                    if not self.retry.should_retry(e.fault, attempt):
                        self.state = ManagerState.FAILED
                        self.stats.failures += 1
                        self.stats.virtual_seconds += total
                        raise TaskAborted(self.replica.replica_id,
                                          total, fault=e.fault) from e
                    backoff = self.retry.backoff(attempt)
                    total += backoff
                    attempt += 1
                    self.stats.retries += 1
                    self._note_recovery("l0", backoff)

    def evaluate(self) -> tuple[float, float]:
        with self._lock:
            score, dur = self.replica.evaluate()
            self.state = ManagerState.DONE
            self.stats.virtual_seconds += dur
            return score, dur

    def close(self) -> float:
        with self._lock:
            dur = self.replica.close()
            self.state = ManagerState.CLOSED
            return dur

    def status(self) -> dict:
        return {"state": self.state.value,
                "replica": self.replica.state.value,
                "steps": self.stats.steps,
                "retries": self.stats.retries,
                "recoveries": self.stats.recoveries}

    # ------------------------------------------------------------ private
    def _ensure_booted(self) -> float:
        if self.replica.alive:
            return 0.0
        return self.replica.boot()

    def _health_check(self) -> bool:
        return self.replica.alive

    def _recover(self, layer: str = "l1") -> float:
        """Autonomous local recovery: re-clone disk, reboot, reconfigure."""
        self.state = ManagerState.RECOVERING
        dur = self.replica.boot()             # reflink clone + boot
        if self.replica.task is not None:
            dur += self.replica.configure(self.replica.task)
        self.stats.recoveries += 1
        self.state = ManagerState.READY
        self._note_recovery(layer, dur)
        return dur

    def recover_if_needed(self) -> float:
        with self._lock:
            if self._health_check():
                return 0.0
            return self._recover()

    def force_reboot(self) -> float:
        """L2: unconditional reboot from the shared CoW base image.

        Unlike ``recover_if_needed`` this runs even when the replica
        reports alive — the recovery ladder uses it for wedged or
        suspect VMs (leaked tasks, checksum mismatches): the current
        overlay is dropped and a fresh reflink clone of the base is
        booted and reconfigured, charging the provisioning latency."""
        with self._lock:
            self.replica.crash()              # drop the suspect state
            return self._recover(layer="l2")


class TaskAborted(RuntimeError):
    """Raised when a runner fails permanently; the pool reassigns the task.

    ``fault`` carries the terminal fault class (when known) so upper
    layers can attribute the abort — e.g. the rollout engine counts
    spot preemptions separately from crash/hang aborts."""

    def __init__(self, replica_id: str, virtual_seconds: float,
                 fault: Optional[FaultType] = None):
        super().__init__(f"task aborted on {replica_id}")
        self.replica_id = replica_id
        self.virtual_seconds = virtual_seconds
        self.fault = fault


# --------------------------------------------------------------- baselines
@dataclass
class ManagerOverheadModel:
    """Per-op dispatcher overhead in virtual seconds (drives Fig. 6 sims)."""

    base_s: float = 0.002
    per_replica_s: float = 0.004      # queueing delay per managed replica
    inter_group_sync_s: float = 0.05  # semi-decentralized coordination


def design_dispatch_overhead(design: str, n_replicas: int, *,
                             group_size: int = 16,
                             overhead: Optional[ManagerOverheadModel] = None
                             ) -> float:
    """Per-op dispatcher cost (virtual seconds) of a manager design.

    The single calibration the manager baseline classes and the live-engine
    throughput benchmark share: centralized pays queueing that grows with
    the whole fleet, semi pays one group's queueing plus the inter-group
    sync, decentralized pays only the constant local dispatch."""
    m = overhead or ManagerOverheadModel()
    if design == "centralized":
        return m.base_s + m.per_replica_s * n_replicas
    if design == "semi":
        return (m.base_s + m.per_replica_s * min(group_size, n_replicas)
                + m.inter_group_sync_s)
    if design == "decentralized":
        return m.base_s
    raise ValueError(f"unknown manager design {design!r}")


class CentralizedManager:
    """One dispatcher in front of every replica (anti-pattern baseline)."""

    kind = "centralized"

    def __init__(self, managers: list[ReplicaStateManager],
                 overhead: Optional[ManagerOverheadModel] = None):
        self.managers = managers
        self.overhead = overhead or ManagerOverheadModel()
        self._global_lock = threading.Lock()

    def dispatch_overhead(self) -> float:
        return design_dispatch_overhead(self.kind, len(self.managers),
                                        overhead=self.overhead)

    def step(self, idx: int, action: Any):
        with self._global_lock:       # the bottleneck, made explicit
            out = self.managers[idx].step(action)
            return out[:-1] + (out[-1] + self.dispatch_overhead(),)


class SemiDecentralizedManager:
    """Replicas split into groups; one dispatcher per group + group sync."""

    kind = "semi"

    def __init__(self, managers: list[ReplicaStateManager], group_size: int,
                 overhead: Optional[ManagerOverheadModel] = None):
        self.managers = managers
        self.group_size = group_size
        self.overhead = overhead or ManagerOverheadModel()
        n_groups = -(-len(managers) // group_size)
        self._locks = [threading.Lock() for _ in range(n_groups)]

    def dispatch_overhead(self) -> float:
        return design_dispatch_overhead(self.kind, len(self.managers),
                                        group_size=self.group_size,
                                        overhead=self.overhead)

    def step(self, idx: int, action: Any):
        with self._locks[idx // self.group_size]:
            out = self.managers[idx].step(action)
            return out[:-1] + (out[-1] + self.dispatch_overhead(),)


class DecentralizedManager:
    """The paper's design: no shared dispatcher at all."""

    kind = "decentralized"

    def __init__(self, managers: list[ReplicaStateManager],
                 overhead: Optional[ManagerOverheadModel] = None):
        self.managers = managers
        self.overhead = overhead or ManagerOverheadModel()

    def dispatch_overhead(self) -> float:
        return design_dispatch_overhead(self.kind, len(self.managers),
                                        overhead=self.overhead)

    def step(self, idx: int, action: Any):
        out = self.managers[idx].step(action)
        return out[:-1] + (out[-1] + self.dispatch_overhead(),)
