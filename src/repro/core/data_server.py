"""Centralized data server with a single-entry interface (§3.6).

One Python object bridges the training loop and the replica fleet: batched
``reset`` / ``step`` (async via futures, so the training loop never blocks),
internal queuing and load balancing through the gateway, and task-level fault
recovery (reassignment to a fresh runner; the paper's multi-layer retry).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Optional


from repro.core.gateway import Gateway
from repro.core.runner_pool import Runner
from repro.core.state_manager import TaskAborted
from repro.core.telemetry import Telemetry


@dataclass
class Episode:
    """A live environment slot owned by the data server."""

    slot: int
    task: dict
    node: str
    runner: Runner
    obs: Any = None
    done: bool = False
    steps: int = 0
    virtual_seconds: float = 0.0
    reassignments: int = 0


class DataServer:
    """Single-entry, batched, asynchronous access to N OS replicas."""

    def __init__(self, gateway: Gateway, *, max_workers: int = 32,
                 max_reassignments: int = 3,
                 telemetry: Optional[Telemetry] = None):
        self.gateway = gateway
        self.pool = ThreadPoolExecutor(max_workers=max_workers,
                                       thread_name_prefix="dataserver")
        self.max_reassignments = max_reassignments
        self.telemetry = telemetry or Telemetry()
        self._episodes: dict[int, Episode] = {}
        self._lock = threading.Lock()
        self._next_slot = 0

    # ------------------------------------------------------------- public
    def reset(self, tasks: list[dict]) -> list[Any]:
        """Batched reset: assign each task to a runner, configure + reset.

        Returns the initial observations (blocking; reset happens once per
        episode so there is nothing useful to overlap)."""
        futs = [self.pool.submit(self._start_episode, t) for t in tasks]
        return [f.result() for f in futs]

    def step_async(self, actions: dict[int, Any]) -> dict[int, Future]:
        """Batched async step: slot -> action, returns slot -> Future.

        The Future resolves to (obs, reward, done, info). Failed steps are
        transparently reassigned to fresh runners (task-level recovery)."""
        return {slot: self.pool.submit(self._step_episode, slot, a)
                for slot, a in actions.items()}

    def step(self, actions: dict[int, Any]) -> dict[int, tuple]:
        futs = self.step_async(actions)
        return {s: f.result() for s, f in futs.items()}

    def evaluate(self, slots: Optional[list[int]] = None) -> dict[int, float]:
        with self._lock:
            eps = [self._episodes[s] for s in (slots or self._episodes)]
        out = {}
        for ep in eps:
            score, dur = ep.runner.manager.evaluate()
            ep.virtual_seconds += dur
            out[ep.slot] = score
        return out

    def close_episode(self, slot: int) -> None:
        with self._lock:
            ep = self._episodes.pop(slot, None)
        if ep is not None:
            self.gateway.release(ep.node, ep.runner,
                                 task_id=ep.task["task_id"])

    def close(self) -> None:
        with self._lock:
            eps = list(self._episodes.values())
            self._episodes.clear()
        for ep in eps:
            self.gateway.release(ep.node, ep.runner,
                                 task_id=ep.task["task_id"])
        self.pool.shutdown(wait=True)

    def live_slots(self) -> list[int]:
        with self._lock:
            return [s for s, e in self._episodes.items() if not e.done]

    def episode(self, slot: int) -> Episode:
        return self._episodes[slot]

    # ----------------------------------------------------------- internals
    def _assign(self, task: dict) -> tuple[str, Runner]:
        got = self.gateway.acquire(task["task_id"], timeout=5.0)
        if got is None:
            raise RuntimeError("no healthy executor nodes with free runners")
        return got

    def _start_episode(self, task: dict) -> Any:
        node, runner = self._assign(task)
        with self._lock:
            slot = self._next_slot
            self._next_slot += 1
        ep = Episode(slot, task, node, runner)
        dur = runner.manager.configure(task)
        obs, d2 = runner.manager.reset()
        ep.obs, ep.virtual_seconds = obs, dur + d2
        with self._lock:
            self._episodes[slot] = ep
        self.telemetry.count("episodes_started")
        return {"slot": slot, "obs": obs}

    def _step_episode(self, slot: int, action: Any) -> tuple:
        ep = self._episodes[slot]
        for _ in range(self.max_reassignments + 1):
            try:
                obs, rew, done, info, dur = ep.runner.manager.step(action)
                ep.obs, ep.done, ep.steps = obs, done, ep.steps + 1
                ep.virtual_seconds += dur
                self.telemetry.count("steps")
                self.telemetry.observe("step_latency_vs", dur)
                return obs, rew, done, info
            except TaskAborted as e:
                ep.virtual_seconds += e.virtual_seconds
                self.telemetry.count("task_reassignments")
                # return the broken runner (pool recycles/recovers it)
                self.gateway.release(ep.node, ep.runner,
                                     task_id=ep.task["task_id"])
                ep.node, ep.runner = self._assign(ep.task)
                ep.reassignments += 1
                d = ep.runner.manager.configure(ep.task)
                _, d2 = ep.runner.manager.reset()
                ep.virtual_seconds += d + d2
                # episode restarts from the task's initial conditions
                ep.steps = 0
        raise RuntimeError(f"task {ep.task['task_id']} failed after "
                           f"{self.max_reassignments} reassignments")
