"""Deterministic discrete-event virtual-time kernel (the fleet's scale core).

Real threads cap the live rollout stack at ``max_inflight``≈16 on one CPU:
every concurrent episode needs a stack, and backpressure is polled with
``time.sleep``. This module replaces threads with cooperative tasks on a
virtual clock so *thousands* of episodes run concurrently — the paper's
1000+ replica fleets execute end-to-end on one core, in seconds.

Design:

- ``EventLoop`` — a heap-ordered event queue keyed by ``(virtual_time,
  sequence)``. The sequence number breaks ties deterministically, so one
  program produces the identical event order on every run and in every
  process (no hash randomization, no thread scheduling).
- ``Task`` — a cooperative coroutine driven by the loop. A task is a plain
  Python generator that yields scheduling directives:

  - ``yield Sleep(dt)`` — resume ``dt`` virtual seconds later;
  - ``yield other_task`` — join: resume when ``other_task`` finishes;
  - ``ok = yield from cond.wait(timeout)`` — block on a ``Condition``.

  Subroutines compose with ``yield from``, so call trees (gateway acquire
  inside an episode inside a feeder) read like ordinary code.
- ``Condition`` — a virtual-time condition variable with ``notify`` /
  ``notify_all`` and timeouts; the event-loop citizen replacing
  ``threading.Condition`` in the runner pool and gateway.
- **daemon timers** — recurring background work (gateway health sweeps,
  leaked-runner reclamation) that must not keep the loop alive: ``run()``
  returns once every live task has finished and only daemon events remain.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable, Generator, Optional


@dataclass(frozen=True)
class Sleep:
    """Directive: resume the yielding task after ``delay`` virtual seconds."""

    delay: float


class Timer:
    """Handle for one scheduled callback. ``cancel()`` is O(1): the entry
    stays in the heap and is skipped when popped (lazy deletion)."""

    __slots__ = ("at", "seq", "fn", "args", "daemon", "cancelled", "fired",
                 "_loop")

    def __init__(self, loop: "EventLoop", at: float, seq: int,
                 fn: Callable, args: tuple, daemon: bool):
        self._loop = loop
        self.at = at
        self.seq = seq
        self.fn = fn
        self.args = args
        self.daemon = daemon
        self.cancelled = False
        self.fired = False

    def cancel(self) -> None:
        if self.cancelled or self.fired:
            return
        self.cancelled = True
        if not self.daemon:
            self._loop._pending -= 1


class Task:
    """A generator-backed cooperative task; yield other tasks to join them."""

    __slots__ = ("loop", "gen", "name", "done", "value", "error", "_joiners")

    def __init__(self, loop: "EventLoop", gen: Generator, name: str = ""):
        self.loop = loop
        self.gen = gen
        self.name = name or getattr(gen, "__name__", "task")
        self.done = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self._joiners: list[Task] = []

    def result(self) -> Any:
        assert self.done, f"task {self.name!r} still running"
        if self.error is not None:
            raise self.error
        return self.value

    # ------------------------------------------------------------ internals
    def _resume(self, payload: Any = None) -> None:
        if self.done:
            return
        try:
            directive = self.gen.send(payload)
        except StopIteration as s:
            self._finish(s.value, None)
            return
        except BaseException as e:  # noqa: BLE001 — task errors are captured
            self._finish(None, e)
            return
        self._dispatch(directive)

    def _dispatch(self, directive: Any) -> None:
        if isinstance(directive, Sleep):
            self.loop.call_later(directive.delay, self._resume, None)
        elif isinstance(directive, Task):
            if directive.done:
                self.loop.call_later(0.0, self._resume, directive)
            else:
                directive._joiners.append(self)
        elif isinstance(directive, _Waiter):
            directive.task = self
        else:
            self._finish(None, TypeError(
                f"task {self.name!r} yielded {directive!r}; expected Sleep, "
                f"Task, or Condition.wait()"))

    def _finish(self, value: Any, error: Optional[BaseException]) -> None:
        self.done = True
        self.value = value
        self.error = error
        self.loop._live -= 1
        for joiner in self._joiners:
            self.loop.call_later(0.0, joiner._resume, self)
        self._joiners.clear()
        if error is not None:
            self.loop.errors.append((self.name, error))


class _Waiter:
    """One parked task on a Condition (plus its optional timeout timer)."""

    __slots__ = ("task", "timer")

    def __init__(self):
        self.task: Optional[Task] = None
        self.timer: Optional[Timer] = None


class Condition:
    """Virtual-time condition variable. FIFO wakeups, deterministic order."""

    def __init__(self, loop: "EventLoop"):
        self._loop = loop
        self._waiters: list[_Waiter] = []

    def wait(self, timeout: Optional[float] = None):
        """``ok = yield from cond.wait(timeout)`` — True if notified, False
        on timeout. Re-check the guarded predicate after waking: another
        waiter may have consumed the resource (classic condvar contract)."""
        w = _Waiter()
        self._waiters.append(w)
        if timeout is not None:
            w.timer = self._loop.call_later(timeout, self._on_timeout, w)
        ok = yield w
        return ok

    def _on_timeout(self, w: _Waiter) -> None:
        if w in self._waiters:
            self._waiters.remove(w)
            w.task._resume(False)

    def notify(self, n: int = 1) -> None:
        while n > 0 and self._waiters:
            w = self._waiters.pop(0)
            if w.timer is not None:
                w.timer.cancel()
            self._loop.call_later(0.0, w.task._resume, True)
            n -= 1

    def notify_all(self) -> None:
        self.notify(len(self._waiters))

    @property
    def n_waiters(self) -> int:
        return len(self._waiters)


class EventLoop:
    """Deterministic single-threaded discrete-event scheduler."""

    def __init__(self):
        self.now = 0.0
        self.errors: list[tuple[str, BaseException]] = []
        self._heap: list[tuple[float, int, Timer]] = []
        self._seq = 0
        self._pending = 0      # scheduled, non-daemon, not cancelled/fired
        self._live = 0         # spawned tasks not yet finished

    # ------------------------------------------------------------ scheduling
    def call_at(self, at: float, fn: Callable, *args,
                daemon: bool = False) -> Timer:
        self._seq += 1
        t = Timer(self, max(at, self.now), self._seq, fn, args, daemon)
        heapq.heappush(self._heap, (t.at, t.seq, t))
        if not daemon:
            self._pending += 1
        return t

    def call_later(self, delay: float, fn: Callable, *args,
                   daemon: bool = False) -> Timer:
        return self.call_at(self.now + delay, fn, *args, daemon=daemon)

    def spawn(self, gen: Generator, name: str = "") -> Task:
        """Start a cooperative task; its first resume runs at ``now``."""
        task = Task(self, gen, name)
        self._live += 1
        self.call_later(0.0, task._resume, None)
        return task

    def condition(self) -> Condition:
        return Condition(self)

    # --------------------------------------------------------------- driving
    def run(self, until: Optional[float] = None) -> float:
        """Process events in virtual-time order.

        Returns when every live task has finished and no non-daemon event
        remains (daemon timers — health sweeps, reclamation — never keep
        the loop alive), or when the clock would pass ``until``. Returns
        the final virtual time."""
        while self._heap:
            if self._pending == 0 and self._live == 0:
                break
            at, _seq, timer = self._heap[0]
            if until is not None and at > until:
                self.now = until
                return self.now
            heapq.heappop(self._heap)
            if timer.cancelled:
                continue
            self.now = at
            timer.fired = True
            if not timer.daemon:
                self._pending -= 1
            timer.fn(*timer.args)
        return self.now

    @property
    def n_scheduled(self) -> int:
        return len(self._heap)

    @property
    def n_live_tasks(self) -> int:
        return self._live
