"""Deterministic discrete-event virtual-time kernel (the fleet's scale core).

Real threads cap the live rollout stack at ``max_inflight``≈16 on one CPU:
every concurrent episode needs a stack, and backpressure is polled with
``time.sleep``. This module replaces threads with cooperative tasks on a
virtual clock so *thousands* of episodes run concurrently — the paper's
1000+ replica fleets execute end-to-end on one core, in seconds.

Two interchangeable kernels implement the same contract:

- ``ScalarEventLoop`` — the original heap-ordered queue keyed by
  ``(virtual_time, sequence)``: one ``heappush``/``heappop`` per event.
  Retained as the *parity oracle*: simple enough to audit by eye.
- ``BatchedEventLoop`` (default) — a bucketed time wheel. Events land in
  fixed-span virtual-time buckets; a bucket is sorted **once** with
  ``np.lexsort`` when the clock enters it, so the hot path is one heap
  interaction per batch rather than per event. Insertions that fall inside
  the already-active bucket go to a small overflow heap merged head-to-head
  on pop, so the global ``(time, seq)`` order is *bit-identical* to the
  scalar kernel's: buckets partition virtual time and timers never schedule
  into the past, hence no event can sort before an already-activated batch.

``EventLoop(...)`` is the factory: ``EventLoop()`` builds the batched
kernel, ``EventLoop(kernel="scalar")`` the oracle, and the
``REPRO_KERNEL`` environment variable overrides the default (used by the
parity suite and ``benchmarks/kernel_scaling.py``). ``isinstance(loop,
EventLoop)`` holds for both.

Shared task machinery (identical on both kernels):

- ``Task`` — a cooperative coroutine driven by the loop. A task is a plain
  Python generator that yields scheduling directives:

  - ``yield Sleep(dt)`` — resume ``dt`` virtual seconds later;
  - ``yield other_task`` — join: resume when ``other_task`` finishes;
  - ``ok = yield from cond.wait(timeout)`` — block on a ``Condition``.

  Subroutines compose with ``yield from``, so call trees (gateway acquire
  inside an episode inside a feeder) read like ordinary code.
- ``Condition`` — a virtual-time condition variable with ``notify`` /
  ``notify_all`` and timeouts; the event-loop citizen replacing
  ``threading.Condition`` in the runner pool and gateway.
- **daemon timers** — recurring background work (gateway health sweeps,
  leaked-runner reclamation) that must not keep the loop alive: ``run()``
  returns once every live task has finished and only daemon events remain.
- ``VecTimer`` — the batched kernel's array-valued primitive: schedule a
  whole numpy array of event times in one call; all elements that land in
  one bucket are delivered back as a single callback with ``(times,
  indices)`` arrays. The scalar oracle implements the same API one element
  at a time, so vectorized workloads can be replayed against it.
"""

from __future__ import annotations

import heapq
import os
from dataclasses import dataclass
from typing import Any, Callable, Generator, Optional

import numpy as np


@dataclass(frozen=True)
class Sleep:
    """Directive: resume the yielding task after ``delay`` virtual seconds."""

    delay: float


class Timer:
    """Handle for one scheduled callback. ``cancel()`` is O(1): the entry
    stays in the queue and is skipped when popped (lazy deletion)."""

    __slots__ = ("at", "seq", "fn", "args", "daemon", "cancelled", "fired", "_loop")

    def __init__(
        self,
        loop: "EventLoop",
        at: float,
        seq: int,
        fn: Callable,
        args: tuple,
        daemon: bool,
    ):
        self._loop = loop
        self.at = at
        self.seq = seq
        self.fn = fn
        self.args = args
        self.daemon = daemon
        self.cancelled = False
        self.fired = False

    def cancel(self) -> None:
        if self.cancelled or self.fired:
            return
        self.cancelled = True
        if not self.daemon:
            self._loop._pending -= 1


class Task:
    """A generator-backed cooperative task; yield other tasks to join them."""

    __slots__ = ("loop", "gen", "name", "done", "value", "error", "_joiners")

    def __init__(self, loop: "EventLoop", gen: Generator, name: str = ""):
        self.loop = loop
        self.gen = gen
        self.name = name or getattr(gen, "__name__", "task")
        self.done = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self._joiners: list[Task] = []

    def result(self) -> Any:
        assert self.done, f"task {self.name!r} still running"
        if self.error is not None:
            raise self.error
        return self.value

    # ------------------------------------------------------------ internals
    def _resume(self, payload: Any = None) -> None:
        if self.done:
            return
        try:
            directive = self.gen.send(payload)
        except StopIteration as s:
            self._finish(s.value, None)
            return
        except BaseException as e:  # noqa: BLE001 — task errors are captured
            self._finish(None, e)
            return
        self._dispatch(directive)

    def _dispatch(self, directive: Any) -> None:
        if isinstance(directive, Sleep):
            self.loop.call_later(directive.delay, self._resume, None)
        elif isinstance(directive, Task):
            if directive.done:
                self.loop.call_later(0.0, self._resume, directive)
            else:
                directive._joiners.append(self)
        elif isinstance(directive, _Waiter):
            directive.task = self
        else:
            self._finish(
                None,
                TypeError(
                    f"task {self.name!r} yielded {directive!r}; expected Sleep, "
                    f"Task, or Condition.wait()"
                ),
            )

    def _finish(self, value: Any, error: Optional[BaseException]) -> None:
        self.done = True
        self.value = value
        self.error = error
        self.loop._live -= 1
        for joiner in self._joiners:
            self.loop.call_later(0.0, joiner._resume, self)
        self._joiners.clear()
        if error is not None:
            self.loop.errors.append((self.name, error))


class _Waiter:
    """One parked task on a Condition (plus its optional timeout timer)."""

    __slots__ = ("task", "timer")

    def __init__(self):
        self.task: Optional[Task] = None
        self.timer: Optional[Timer] = None


class Condition:
    """Virtual-time condition variable. FIFO wakeups, deterministic order."""

    def __init__(self, loop: "EventLoop"):
        self._loop = loop
        self._waiters: list[_Waiter] = []

    def wait(self, timeout: Optional[float] = None):
        """``ok = yield from cond.wait(timeout)`` — True if notified, False
        on timeout. Re-check the guarded predicate after waking: another
        waiter may have consumed the resource (classic condvar contract)."""
        w = _Waiter()
        self._waiters.append(w)
        if timeout is not None:
            w.timer = self._loop.call_later(timeout, self._on_timeout, w)
        ok = yield w
        return ok

    def _on_timeout(self, w: _Waiter) -> None:
        if w in self._waiters:
            self._waiters.remove(w)
            w.task._resume(False)

    def notify(self, n: int = 1) -> None:
        while n > 0 and self._waiters:
            w = self._waiters.pop(0)
            if w.timer is not None:
                w.timer.cancel()
            self._loop.call_later(0.0, w.task._resume, True)
            n -= 1

    def notify_all(self) -> None:
        self.notify(len(self._waiters))

    @property
    def n_waiters(self) -> int:
        return len(self._waiters)


class VecTimer:
    """A *family* of array-scheduled events sharing one callback.

    ``schedule(ats, idx)`` books one event per array element in a single
    kernel interaction. On the batched kernel every element of one family
    that lands in the same time-wheel bucket is delivered back as **one**
    callback ``fn(ats, idx)`` (numpy arrays sorted by ``(time, seq)``),
    with ``loop.now`` set to the batch's earliest time; per-element times
    travel in the ``ats`` array. The scalar oracle delivers the same
    elements one at a time (length-1 arrays) in exact ``(time, seq)``
    order, so a vectorized workload can be replayed element-for-element
    against it: the delivered ``(time, index)`` pairs are identical on
    both kernels, only the grouping differs.

    Batch delivery is bucket-atomic: don't combine with ``run(until=...)``
    finer than the wheel span. Exact cross-family ordering is only
    guaranteed at bucket granularity — use plain timers when two families'
    callbacks are order-sensitive within ~``span`` virtual seconds.
    """

    __slots__ = ("loop", "fn", "daemon", "fid", "n_booked", "n_delivered")

    def __init__(self, loop: "EventLoop", fn: Callable, daemon: bool = False):
        self.loop = loop
        self.fn = fn
        self.daemon = daemon
        self.fid = loop._next_fid()
        self.n_booked = 0
        self.n_delivered = 0

    def schedule(self, ats, idx=None) -> int:
        """Book one event per element of ``ats`` (clamped to ``now``).

        ``idx`` (default ``arange(len(ats))``) is the caller's payload —
        typically lane/replica indices — handed back verbatim with each
        delivery. Returns the number of events booked."""
        ats = np.maximum(np.asarray(ats, dtype=np.float64), self.loop.now)
        n = len(ats)
        if n == 0:
            return 0
        if idx is None:
            idx = np.arange(n, dtype=np.int64)
        else:
            idx = np.asarray(idx, dtype=np.int64)
        base = self.loop._seq + 1
        self.loop._seq += n
        seqs = np.arange(base, base + n, dtype=np.int64)
        self.n_booked += n
        if not self.daemon:
            self.loop._pending += n
        self.loop._insert_vec(self, ats, seqs, idx)
        return n


class _VecSingle:
    """One vec-timer element that fell inside the already-active bucket
    (or onto the scalar oracle): delivered as a length-1 batch."""

    __slots__ = ("family", "at", "idx")

    def __init__(self, family: VecTimer, at: float, idx: int):
        self.family = family
        self.at = at
        self.idx = idx


class EventLoop:
    """Deterministic single-threaded discrete-event scheduler (factory).

    ``EventLoop()`` returns the batched kernel; ``EventLoop(kernel=
    "scalar")`` the oracle. The ``REPRO_KERNEL`` environment variable
    ("batched" | "scalar") overrides the default for whole-process flips
    — e.g. ``REPRO_KERNEL=scalar pytest`` replays the entire tier-1 suite
    on the oracle. Both kernels expose the identical API and, for
    non-vectorized workloads, the identical event order, virtual times,
    and counters (the bit-exact parity contract enforced by
    ``tests/test_kernel_parity.py``)."""

    KERNELS = ("batched", "scalar")

    def __new__(cls, kernel: Optional[str] = None):
        if cls is EventLoop:
            name = kernel or os.environ.get("REPRO_KERNEL") or "batched"
            if name == "batched":
                cls = BatchedEventLoop
            elif name == "scalar":
                cls = ScalarEventLoop
            else:
                raise ValueError(
                    f"unknown event kernel {name!r}; "
                    f"expected one of {EventLoop.KERNELS}"
                )
        return object.__new__(cls)

    def __init__(self, kernel: Optional[str] = None):
        self.now = 0.0
        self.errors: list[tuple[str, BaseException]] = []
        self._seq = 0
        self._pending = 0  # scheduled, non-daemon, not cancelled/fired
        self._live = 0  # spawned tasks not yet finished
        self._fid = 0  # vec-timer family ids
        self.n_processed = 0  # events delivered (vec batches count per elem)

    # ------------------------------------------------------------ scheduling
    def call_at(self, at: float, fn: Callable, *args, daemon: bool = False) -> Timer:
        raise NotImplementedError

    def call_later(
        self, delay: float, fn: Callable, *args, daemon: bool = False
    ) -> Timer:
        return self.call_at(self.now + delay, fn, *args, daemon=daemon)

    def spawn(self, gen: Generator, name: str = "") -> Task:
        """Start a cooperative task; its first resume runs at ``now``."""
        task = Task(self, gen, name)
        self._live += 1
        self.call_later(0.0, task._resume, None)
        return task

    def condition(self) -> Condition:
        return Condition(self)

    def vec_timer(self, fn: Callable, daemon: bool = False) -> VecTimer:
        """Create an array-scheduled timer family (see :class:`VecTimer`)."""
        return VecTimer(self, fn, daemon)

    # --------------------------------------------------------------- driving
    def run(self, until: Optional[float] = None) -> float:
        """Process events in virtual-time order.

        Returns when every live task has finished and no non-daemon event
        remains (daemon timers — health sweeps, reclamation — never keep
        the loop alive), or when the clock would pass ``until``. Returns
        the final virtual time."""
        raise NotImplementedError

    # ------------------------------------------------------------- internals
    def _next_fid(self) -> int:
        self._fid += 1
        return self._fid

    def _insert_vec(
        self, family: VecTimer, ats: np.ndarray, seqs: np.ndarray, idx: np.ndarray
    ) -> None:
        raise NotImplementedError

    @property
    def kernel(self) -> str:
        raise NotImplementedError

    @property
    def n_scheduled(self) -> int:
        raise NotImplementedError

    @property
    def n_live_tasks(self) -> int:
        return self._live


class ScalarEventLoop(EventLoop):
    """The original heap kernel: one heap interaction per event (oracle)."""

    def __init__(self, kernel: Optional[str] = None):
        super().__init__(kernel)
        self._heap: list[tuple[float, int, Any]] = []

    @property
    def kernel(self) -> str:
        return "scalar"

    # ------------------------------------------------------------ scheduling
    def call_at(self, at: float, fn: Callable, *args, daemon: bool = False) -> Timer:
        self._seq += 1
        t = Timer(self, max(at, self.now), self._seq, fn, args, daemon)
        heapq.heappush(self._heap, (t.at, t.seq, t))
        if not daemon:
            self._pending += 1
        return t

    def _insert_vec(
        self, family: VecTimer, ats: np.ndarray, seqs: np.ndarray, idx: np.ndarray
    ) -> None:
        # element-at-a-time: the oracle's per-event limit of batch delivery
        for at, seq, i in zip(ats.tolist(), seqs.tolist(), idx.tolist()):
            heapq.heappush(self._heap, (at, seq, _VecSingle(family, at, i)))

    # --------------------------------------------------------------- driving
    def run(self, until: Optional[float] = None) -> float:
        while self._heap:
            if self._pending == 0 and self._live == 0:
                break
            at, _seq, entry = self._heap[0]
            if until is not None and at > until:
                self.now = until
                return self.now
            heapq.heappop(self._heap)
            if isinstance(entry, Timer):
                if entry.cancelled:
                    continue
                self.now = at
                entry.fired = True
                if not entry.daemon:
                    self._pending -= 1
                self.n_processed += 1
                entry.fn(*entry.args)
            else:  # _VecSingle
                self.now = at
                fam = entry.family
                if not fam.daemon:
                    self._pending -= 1
                fam.n_delivered += 1
                self.n_processed += 1
                fam.fn(np.array([at]), np.array([entry.idx], dtype=np.int64))
        return self.now

    @property
    def n_scheduled(self) -> int:
        return len(self._heap)


class _Bucket:
    """Pending events for one span of virtual time, unsorted until the
    clock enters the span."""

    __slots__ = ("scalars", "vecs")

    def __init__(self):
        # scalar timers as (at, seq, Timer) tuples — sortable without a key
        self.scalars: list[tuple[float, int, Timer]] = []
        # family id -> (family, [(ats, seqs, idx), ...]) chunks
        self.vecs: dict[int, tuple[VecTimer, list]] = {}


class BatchedEventLoop(EventLoop):
    """Bucketed time-wheel kernel: one sort per batch, not one heap op per
    event.

    Events are appended (O(1), unsorted) to fixed-``span`` virtual-time
    buckets; a min-heap orders only the *bucket keys*. When the clock
    enters a bucket, its scalar timers are sorted once and each vec-timer
    family's elements are lexsorted into a single delivery batch. Because
    ``call_at`` clamps to ``now`` and buckets partition time, nothing can
    ever schedule *before* the active batch — so the pop order for scalar
    timers is bit-identical to the scalar kernel's ``(time, seq)`` heap
    order. Insertions landing inside the already-active span (zero-delay
    resumes, condition notifies) go to a small overflow heap consulted
    head-to-head on every pop, preserving exactness there too.
    """

    #: bucket width in virtual seconds. Replica op latencies are ~1-12 vs,
    #: so at fleet scale each span holds thousands of events — one sort
    #: amortized over all of them. Correctness does not depend on the value.
    SPAN = 0.5

    def __init__(self, kernel: Optional[str] = None):
        super().__init__(kernel)
        self.span = float(self.SPAN)
        self._buckets: dict[int, _Bucket] = {}
        self._bucket_heap: list[int] = []  # keys of future buckets
        self._active = -1  # highest activated bucket key
        self._overflow: list[tuple[float, int, Any]] = []
        # activated batch (sorted, consumed by pointer):
        self._cur_scalars: list[tuple[float, int, Timer]] = []
        self._cur_si = 0
        # vec delivery units: (at0, seq0, family, ats, idx)
        self._cur_units: list[tuple] = []
        self._cur_ui = 0
        self._n_sched = 0
        self.n_batches = 0  # bucket activations (heap interactions per batch)

    @property
    def kernel(self) -> str:
        return "batched"

    # ------------------------------------------------------------ scheduling
    def call_at(self, at: float, fn: Callable, *args, daemon: bool = False) -> Timer:
        self._seq += 1
        t = Timer(self, max(at, self.now), self._seq, fn, args, daemon)
        key = int(t.at // self.span)
        if key <= self._active:
            heapq.heappush(self._overflow, (t.at, t.seq, t))
        else:
            b = self._buckets.get(key)
            if b is None:
                b = self._buckets[key] = _Bucket()
                heapq.heappush(self._bucket_heap, key)
            b.scalars.append((t.at, t.seq, t))
        self._n_sched += 1
        if not daemon:
            self._pending += 1
        return t

    def _insert_vec(
        self, family: VecTimer, ats: np.ndarray, seqs: np.ndarray, idx: np.ndarray
    ) -> None:
        keys = (ats // self.span).astype(np.int64)
        self._n_sched += len(ats)
        live = keys > self._active
        if not live.all():
            # stragglers inside the active span: exact-order overflow path
            for at, seq, i in zip(
                ats[~live].tolist(), seqs[~live].tolist(), idx[~live].tolist()
            ):
                heapq.heappush(self._overflow, (at, seq, _VecSingle(family, at, i)))
            ats, seqs, idx, keys = ats[live], seqs[live], idx[live], keys[live]
            if len(ats) == 0:
                return
        order = np.argsort(keys, kind="stable")
        keys_s = keys[order]
        bounds = np.flatnonzero(np.diff(keys_s)) + 1
        starts = np.concatenate(([0], bounds))
        ends = np.concatenate((bounds, [len(keys_s)]))
        for s, e in zip(starts.tolist(), ends.tolist()):
            key = int(keys_s[s])
            sel = order[s:e]
            b = self._buckets.get(key)
            if b is None:
                b = self._buckets[key] = _Bucket()
                heapq.heappush(self._bucket_heap, key)
            ent = b.vecs.get(family.fid)
            if ent is None:
                ent = b.vecs[family.fid] = (family, [])
            ent[1].append((ats[sel], seqs[sel], idx[sel]))

    # --------------------------------------------------------------- driving
    def _activate_next(self) -> bool:
        """Sort the earliest future bucket into the current batch (the one
        heap interaction per batch). Returns False if none remain."""
        if not self._bucket_heap:
            return False
        key = heapq.heappop(self._bucket_heap)
        b = self._buckets.pop(key)
        self._active = key
        b.scalars.sort()
        self._cur_scalars = b.scalars
        self._cur_si = 0
        units = []
        for family, chunks in b.vecs.values():
            if len(chunks) == 1:
                ats, seqs, idx = chunks[0]
            else:
                ats = np.concatenate([c[0] for c in chunks])
                seqs = np.concatenate([c[1] for c in chunks])
                idx = np.concatenate([c[2] for c in chunks])
            order = np.lexsort((seqs, ats))
            ats, seqs, idx = ats[order], seqs[order], idx[order]
            units.append((float(ats[0]), int(seqs[0]), family, ats, idx))
        units.sort(key=lambda u: (u[0], u[1]))
        self._cur_units = units
        self._cur_ui = 0
        self.n_batches += 1
        return True

    def _peek(self):
        """Earliest pending entry as (at, seq, source) — source 0 = current
        scalar batch, 1 = vec unit, 2 = overflow — or None when drained.
        Activates buckets as needed."""
        while True:
            best = None
            if self._cur_si < len(self._cur_scalars):
                at, seq, _t = self._cur_scalars[self._cur_si]
                best = (at, seq, 0)
            if self._cur_ui < len(self._cur_units):
                u = self._cur_units[self._cur_ui]
                if best is None or (u[0], u[1]) < (best[0], best[1]):
                    best = (u[0], u[1], 1)
            if self._overflow:
                o = self._overflow[0]
                if best is None or (o[0], o[1]) < (best[0], best[1]):
                    best = (o[0], o[1], 2)
            if best is not None:
                return best
            if not self._activate_next():
                return None

    def run(self, until: Optional[float] = None) -> float:
        while True:
            if self._pending == 0 and self._live == 0:
                break
            head = self._peek()
            if head is None:
                break
            at, _seq, source = head
            if until is not None and at > until:
                self.now = until
                return self.now
            if source == 0:
                _at, _s, timer = self._cur_scalars[self._cur_si]
                self._cur_si += 1
                self._fire_scalar(at, timer)
            elif source == 1:
                unit = self._cur_units[self._cur_ui]
                self._cur_ui += 1
                self._fire_unit(unit)
            else:
                entry = heapq.heappop(self._overflow)[2]
                if isinstance(entry, Timer):
                    self._fire_scalar(at, entry)
                else:
                    self._fire_single(entry)
        return self.now

    def _fire_scalar(self, at: float, timer: Timer) -> None:
        self._n_sched -= 1
        if timer.cancelled:
            return
        self.now = at
        timer.fired = True
        if not timer.daemon:
            self._pending -= 1
        self.n_processed += 1
        timer.fn(*timer.args)

    def _fire_unit(self, unit: tuple) -> None:
        at0, _seq0, family, ats, idx = unit
        n = len(ats)
        self._n_sched -= n
        self.now = at0
        if not family.daemon:
            self._pending -= n
        family.n_delivered += n
        self.n_processed += n
        family.fn(ats, idx)

    def _fire_single(self, entry: _VecSingle) -> None:
        self._n_sched -= 1
        self.now = entry.at
        fam = entry.family
        if not fam.daemon:
            self._pending -= 1
        fam.n_delivered += 1
        self.n_processed += 1
        fam.fn(np.array([entry.at]), np.array([entry.idx], dtype=np.int64))

    @property
    def n_scheduled(self) -> int:
        return self._n_sched
