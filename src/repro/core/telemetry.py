"""Counters / histograms / gauges / timers for throughput, latency,
recovery, and the online actor-learner pipeline (staleness accounting)."""
from __future__ import annotations

import statistics
import threading
import time
from collections import defaultdict
from contextlib import contextmanager


def p95(xs: list[float]) -> float:
    """The fleet's one p95 definition (nearest-rank on the sorted list);
    shared by metric summaries and the autoscaler's pressure signal so
    the two can never diverge. Returns 0.0 on an empty series."""
    if not xs:
        return 0.0
    return sorted(xs)[int(0.95 * (len(xs) - 1))]


def p99(xs: list[float]) -> float:
    """Nearest-rank p99, same convention as :func:`p95`; used by the
    multi-tenant SLO gates (per-tenant acquire-wait p99 vs the tenant's
    SLO target). Returns 0.0 on an empty series."""
    if not xs:
        return 0.0
    return sorted(xs)[int(0.99 * (len(xs) - 1))]


class Telemetry:
    """Thread-safe metric sink shared across the fleet and the learner.

    - ``count``    — monotonic counters (episodes, reassignments, drops);
    - ``observe``  — value series summarized as mean/p50/p95/max
      (latencies, staleness, losses);
    - ``gauge``    — last-write-wins instantaneous values (buffer depth,
      policy version);
    - ``timer``    — context manager observing wall seconds into a series.
    """

    def __init__(self):
        self._counters: dict[str, int] = defaultdict(int)
        self._series: dict[str, list[float]] = defaultdict(list)
        self._gauges: dict[str, float] = {}
        self._lock = threading.Lock()

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] += n

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._series[name].append(value)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    @contextmanager
    def timer(self, name: str):
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.observe(name, time.monotonic() - t0)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def counters(self, prefix: str) -> dict:
        """Counters whose name starts with ``prefix``, keyed by the
        suffix after it (e.g. ``counters("wan_bytes:")`` → per-link WAN
        byte totals), sorted for stable output."""
        with self._lock:
            matched = {k[len(prefix):]: v
                       for k, v in self._counters.items()
                       if k.startswith(prefix)}
        return {k: matched[k] for k in sorted(matched)}

    def gauge_value(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    def series(self, name: str) -> list[float]:
        with self._lock:
            return list(self._series.get(name, []))

    def summary(self, name: str) -> dict:
        with self._lock:
            xs = list(self._series.get(name, []))
        return self._summarize(xs)

    def summaries(self, prefix: str) -> dict:
        """Summaries of every series whose name starts with ``prefix``
        (e.g. ``summaries("recovery_mttr_vs:")`` → per-layer MTTR). Keys
        are the suffixes after the prefix, sorted for stable output."""
        with self._lock:
            matched = {k[len(prefix):]: list(v)
                       for k, v in self._series.items()
                       if k.startswith(prefix)}
        return {k: self._summarize(matched[k]) for k in sorted(matched)}

    @staticmethod
    def _summarize(xs: list[float]) -> dict:
        if not xs:
            return {"n": 0}
        return {
            "n": len(xs),
            "mean": statistics.fmean(xs),
            "p50": statistics.median(xs),
            "p95": p95(xs),
            "p99": p99(xs),
            "max": max(xs),
        }

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            series = {k: list(v) for k, v in self._series.items()}
        return {
            "counters": counters,
            "gauges": gauges,
            "series": {k: self._summarize(v) for k, v in series.items()},
        }
