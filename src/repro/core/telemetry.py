"""Counters / histograms / timelines for throughput, latency and recovery."""
from __future__ import annotations

import statistics
import threading
from collections import defaultdict
from dataclasses import dataclass, field


class Telemetry:
    def __init__(self):
        self._counters: dict[str, int] = defaultdict(int)
        self._series: dict[str, list[float]] = defaultdict(list)
        self._lock = threading.Lock()

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] += n

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._series[name].append(value)

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def summary(self, name: str) -> dict:
        xs = self._series.get(name, [])
        if not xs:
            return {"n": 0}
        return {
            "n": len(xs),
            "mean": statistics.fmean(xs),
            "p50": statistics.median(xs),
            "p95": sorted(xs)[int(0.95 * (len(xs) - 1))],
            "max": max(xs),
        }

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "series": {k: self.summary(k) for k in self._series},
            }
