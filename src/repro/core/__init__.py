"""OSGym core: scalable OS-environment infrastructure (the paper's
contribution). Decentralized state management, hardware-aware orchestration,
CoW disk management, robust runner pools, gateway, and the centralized
single-entry data server."""
from repro.core.cow_store import CowStore, DiskImage, BlobStore
from repro.core.data_server import DataServer
from repro.core.event_loop import (Condition, EventLoop, Sleep, Task, Timer,
                                   BatchedEventLoop, ScalarEventLoop,
                                   VecTimer)
from repro.core.faults import FaultInjector, FaultType, ReplicaError, RetryPolicy
from repro.core.gateway import Gateway, NoRunnerAvailable
from repro.core.replica import SimOSReplica, LatencyModel
from repro.core.runner_pool import RunnerPool, SimHost, HostSpec, ResourceGuard
from repro.core.seeding import lognorm_jitter, stable_seed
from repro.core.state_manager import (ReplicaStateManager, TaskAborted,
                                      CentralizedManager,
                                      SemiDecentralizedManager,
                                      DecentralizedManager,
                                      design_dispatch_overhead)
from repro.core.tasks import TaskSuite, TaskSpec, TABLE3_ROWS
from repro.core.telemetry import Telemetry
