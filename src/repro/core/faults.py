"""Stochastic fault model and retry policies (§3.4 of the paper).

Replicas fail in the same ways the paper enumerates: connection errors,
timeouts, runtime operation failures (retryable at the step level), crashes
and hangs (recoverable by the replica's own state manager), and *silent*
failures — the failure mode caused by exhausted kernel limits, which succeed
apparently but corrupt the result.
"""
from __future__ import annotations

import enum
import random
from dataclasses import dataclass, field
from typing import Optional


class FaultType(enum.Enum):
    CONNECTION = "connection"
    TIMEOUT = "timeout"
    RUNTIME = "runtime"
    CRASH = "crash"
    HANG = "hang"
    SILENT = "silent"


# step-retryable faults (paper: retry covers connection/timeout/runtime)
STEP_RETRYABLE = (FaultType.CONNECTION, FaultType.TIMEOUT, FaultType.RUNTIME)


class ReplicaError(RuntimeError):
    def __init__(self, fault: FaultType, msg: str = ""):
        super().__init__(f"{fault.value}: {msg}")
        self.fault = fault


@dataclass
class RetryPolicy:
    """Step-level retry (paper default: 10 retries)."""

    max_retries: int = 10
    retry_on: tuple = STEP_RETRYABLE
    backoff_base: float = 0.05     # virtual seconds
    backoff_factor: float = 1.5

    def backoff(self, attempt: int) -> float:
        return self.backoff_base * (self.backoff_factor ** attempt)

    def should_retry(self, fault: FaultType, attempt: int) -> bool:
        return attempt < self.max_retries and fault in self.retry_on


# default per-step fault probabilities (stochastic software errors, §1)
DEFAULT_RATES = {
    FaultType.CONNECTION: 0.010,
    FaultType.TIMEOUT: 0.008,
    FaultType.RUNTIME: 0.012,
    FaultType.CRASH: 0.002,
    FaultType.HANG: 0.001,
}


@dataclass
class FaultInjector:
    """Deterministic, seeded fault sampler."""

    rates: dict = field(default_factory=lambda: dict(DEFAULT_RATES))
    seed: int = 0
    enabled: bool = True

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def sample(self) -> Optional[FaultType]:
        if not self.enabled:
            return None
        u = self._rng.random()
        acc = 0.0
        for fault, rate in self.rates.items():
            acc += rate
            if u < acc:
                return fault
        return None

    def scaled(self, factor: float) -> "FaultInjector":
        return FaultInjector(
            rates={f: r * factor for f, r in self.rates.items()},
            seed=self._rng.randrange(1 << 30), enabled=self.enabled)
