"""Stochastic fault model and retry policies (§3.4 of the paper).

Replicas fail in the same ways the paper enumerates: connection errors,
timeouts, runtime operation failures (retryable at the step level), crashes
and hangs (recoverable by the replica's own state manager), and *silent*
failures — the failure mode caused by exhausted kernel limits, which succeed
apparently but corrupt the result.
"""
from __future__ import annotations

import enum
import random
from dataclasses import dataclass, field
from typing import Optional

from repro.core.seeding import stable_seed


class FaultType(enum.Enum):
    CONNECTION = "connection"
    TIMEOUT = "timeout"
    RUNTIME = "runtime"
    CRASH = "crash"
    HANG = "hang"
    SILENT = "silent"
    # spot/preemptible capacity reclaimed mid-episode: the VM is *gone*,
    # not merely crashed — recovery is an L2 respawn from the base image
    # (possibly on another host or region), never an in-place L1 repair
    PREEMPT = "preempt"


# step-retryable faults (paper: retry covers connection/timeout/runtime)
STEP_RETRYABLE = (FaultType.CONNECTION, FaultType.TIMEOUT, FaultType.RUNTIME)


class ReplicaError(RuntimeError):
    def __init__(self, fault: FaultType, msg: str = ""):
        super().__init__(f"{fault.value}: {msg}")
        self.fault = fault


@dataclass
class RetryPolicy:
    """Step-level retry (paper default: 10 retries)."""

    max_retries: int = 10
    retry_on: tuple = STEP_RETRYABLE
    backoff_base: float = 0.05     # virtual seconds
    backoff_factor: float = 1.5

    def backoff(self, attempt: int) -> float:
        return self.backoff_base * (self.backoff_factor ** attempt)

    def should_retry(self, fault: FaultType, attempt: int) -> bool:
        return attempt < self.max_retries and fault in self.retry_on


# default per-step fault probabilities (stochastic software errors, §1)
DEFAULT_RATES = {
    FaultType.CONNECTION: 0.010,
    FaultType.TIMEOUT: 0.008,
    FaultType.RUNTIME: 0.012,
    FaultType.CRASH: 0.002,
    FaultType.HANG: 0.001,
}


def spot_rates(preempt_rate: float, *, base: Optional[dict] = None) -> dict:
    """Rate table for a spot/preemptible tier: the base software-fault
    rates plus a per-step reclaim probability. The preempt entry rides
    through the same ``__post_init__`` validation as every other rate
    (negative or rates summing past 1.0 raise)."""
    rates = dict(DEFAULT_RATES if base is None else base)
    rates[FaultType.PREEMPT] = preempt_rate
    return rates


# floating-point slack for the sum-of-rates validation: a rate vector
# that sums to exactly 1.0 (e.g. {CRASH: 1.0}) must stay legal
_RATE_SUM_EPS = 1e-9


@dataclass
class FaultInjector:
    """Deterministic, seeded fault sampler.

    Rates are validated at construction: ``sample()`` walks the rate
    table cumulatively against one uniform draw, so a table whose rates
    sum past 1.0 silently truncates the tail — faults listed after the
    saturation point can never fire. That is exactly how a large
    ``scaled()`` factor used to misbehave unnoticed; now it raises."""

    rates: dict = field(default_factory=lambda: dict(DEFAULT_RATES))
    seed: int = 0
    enabled: bool = True

    def __post_init__(self):
        total = 0.0
        for fault, rate in self.rates.items():
            if rate < 0.0:
                raise ValueError(
                    f"fault rate for {fault} is negative ({rate})")
            total += rate
        if total > 1.0 + _RATE_SUM_EPS:
            raise ValueError(
                f"fault rates sum to {total:.6g} > 1: faults past the "
                f"saturation point would be unreachable (check scaled() "
                f"factors)")
        self._rng = random.Random(self.seed)
        self._n_children = 0

    def sample(self) -> Optional[FaultType]:
        if not self.enabled:
            return None
        u = self._rng.random()
        acc = 0.0
        for fault, rate in self.rates.items():
            acc += rate
            if u < acc:
                return fault
        return None

    def scaled(self, factor: float) -> "FaultInjector":
        """A child injector with every rate scaled by ``factor``.

        Child seeds derive from the parent's *configured* seed plus a
        monotone counter — never from the parent's RNG stream. Drawing
        the child seed from ``self._rng`` perturbed the parent's future
        fault sequence on every call, so fault streams depended on
        runner-creation order (prewarm vs a later ``grow()`` produced
        different faults fleet-wide). Now the k-th child of a given
        parent is identical however the other children interleave with
        the parent's own ``sample()`` calls."""
        child_seed = stable_seed(self.seed, "scaled", self._n_children)
        self._n_children += 1
        return FaultInjector(
            rates={f: r * factor for f, r in self.rates.items()},
            seed=child_seed, enabled=self.enabled)
