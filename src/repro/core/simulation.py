"""Discrete-event fleet simulation for the Figure-6 experiments.

Real threads cannot scale to 1024 replicas on this container, so the
scalability / latency / recovery experiments run in virtual time: each
replica emits step events with the calibrated latency model; the manager
design (centralized / semi / decentralized) contributes dispatcher queueing
delay modeled as M/M/1 around the measured dispatch overheads.
"""
from __future__ import annotations

import math
import random
import statistics
from dataclasses import dataclass
from typing import Optional

from repro.core.seeding import lognorm_jitter, stable_seed


@dataclass
class SimConfig:
    step_mean_s: float = 2.15           # matches replica.LatencyModel
    step_sigma: float = 0.35
    dispatch_service_s: float = 0.005   # centralized dispatcher service time
    semi_group_size: int = 64
    inter_group_sync_s: float = 0.05
    boot_s: float = 12.0
    configure_s: float = 3.0
    boot_jitter_sigma: float = 0.3
    boot_concurrency_per_node: int = 32  # disk-bandwidth bound on node
    replicas_per_node: int = 128


def _mm1_wait(arrival_rate: float, service_s: float,
              rng: random.Random) -> float:
    """Expected queueing delay for one op through a shared dispatcher."""
    rho = min(arrival_rate * service_s, 0.999)
    wait = service_s * rho / max(1.0 - rho, 1e-3)
    return max(rng.gauss(wait, 0.1 * wait), 0.0) + service_s


def dispatch_extra(design: str, n_replicas: int, per_replica_rate: float,
                   cfg: SimConfig, rng: random.Random) -> float:
    """Per-op dispatcher overhead for one manager design.

    ``per_replica_rate`` is each replica's op issue rate (ops/s); the
    centralized dispatcher sees the whole fleet's arrivals, the semi
    variant one group's plus an inter-group sync term, the decentralized
    design pays only the service time. Shared by the Fig-6 step-throughput
    sweep and the trajectory-throughput benchmark so the pricing model
    cannot drift between them."""
    if design == "centralized":
        return _mm1_wait(n_replicas * per_replica_rate,
                         cfg.dispatch_service_s, rng)
    if design == "semi":
        group_rate = (min(cfg.semi_group_size, n_replicas)
                      * per_replica_rate)
        return (_mm1_wait(group_rate, cfg.dispatch_service_s, rng)
                + cfg.inter_group_sync_s)
    return cfg.dispatch_service_s


def run_throughput(n_replicas: int, design: str, *, sim_seconds: float = 120.0,
                   seed: int = 0, cfg: Optional[SimConfig] = None) -> dict:
    """Simulate `sim_seconds` of fleet operation; return throughput/latency."""
    cfg = cfg or SimConfig()
    rng = random.Random(stable_seed(seed, n_replicas, design))

    total_steps = 0
    latencies = []
    for _ in range(n_replicas):
        t = rng.uniform(0, cfg.step_mean_s)      # desynchronized start
        while t < sim_seconds:
            step = cfg.step_mean_s * lognorm_jitter(rng, cfg.step_sigma)
            extra = dispatch_extra(design, n_replicas, 1.0 / cfg.step_mean_s,
                                   cfg, rng)
            lat = step + extra
            t += lat
            if t < sim_seconds:
                total_steps += 1
                latencies.append(lat)
    return {
        "design": design, "replicas": n_replicas,
        "steps_per_s": total_steps / sim_seconds,
        "latency_mean_s": statistics.fmean(latencies) if latencies else 0.0,
        "latency_p95_s": (sorted(latencies)[int(0.95 * (len(latencies) - 1))]
                          if latencies else 0.0),
    }


def sweep_throughput(designs=("centralized", "semi", "decentralized"),
                     sizes=(16, 32, 64, 128, 256, 512, 1024),
                     seeds: int = 10, cfg: Optional[SimConfig] = None
                     ) -> list[dict]:
    rows = []
    for design in designs:
        for n in sizes:
            runs = [run_throughput(n, design, seed=s, cfg=cfg)
                    for s in range(seeds)]
            rows.append({
                "design": design, "replicas": n,
                "steps_per_s_mean": statistics.fmean(
                    r["steps_per_s"] for r in runs),
                "steps_per_s_std": statistics.pstdev(
                    [r["steps_per_s"] for r in runs]),
                "latency_mean_s": statistics.fmean(
                    r["latency_mean_s"] for r in runs),
                "latency_std_s": statistics.pstdev(
                    [r["latency_mean_s"] for r in runs]),
            })
    return rows


def run_recovery(n_replicas: int, *, seed: int = 0,
                 cfg: Optional[SimConfig] = None,
                 resolution_s: float = 1.0) -> dict:
    """Fig. 6 right: full crash at t=0, every manager recovers autonomously.

    Recovery = reflink re-clone (0.8 s) + boot + configure, with per-node
    boot concurrency bounded by disk bandwidth. Returns the healthy-fraction
    timeline and the full-recovery time."""
    cfg = cfg or SimConfig()
    rng = random.Random(stable_seed(seed, n_replicas))
    n_nodes = max(1, math.ceil(n_replicas / cfg.replicas_per_node))
    finish = []
    for node in range(n_nodes):
        k = min(cfg.replicas_per_node, n_replicas - node * cfg.replicas_per_node)
        # waves of `boot_concurrency` parallel boots per node
        lanes = [0.0] * cfg.boot_concurrency_per_node
        for i in range(k):
            lane = min(range(len(lanes)), key=lanes.__getitem__)
            dur = (0.8 + (cfg.boot_s + cfg.configure_s)
                   * lognorm_jitter(rng, cfg.boot_jitter_sigma))
            lanes[lane] += dur
            finish.append(lanes[lane])
    finish.sort()
    t_full = finish[-1]
    timeline = []
    t = 0.0
    while t <= t_full + resolution_s:
        healthy = sum(1 for f in finish if f <= t) / n_replicas
        timeline.append((round(t, 1), round(healthy, 4)))
        t += resolution_s
    return {"replicas": n_replicas, "full_recovery_s": round(t_full, 1),
            "t50_s": round(finish[len(finish) // 2], 1),
            "timeline": timeline}


def recovery_stats(n_replicas: int = 1024, seeds: int = 10,
                   cfg: Optional[SimConfig] = None) -> dict:
    runs = [run_recovery(n_replicas, seed=s, cfg=cfg) for s in range(seeds)]
    fulls = [r["full_recovery_s"] for r in runs]
    return {
        "replicas": n_replicas,
        "full_recovery_mean_s": statistics.fmean(fulls),
        "full_recovery_std_s": statistics.pstdev(fulls),
        "t50_mean_s": statistics.fmean(r["t50_s"] for r in runs),
        "example_timeline": runs[0]["timeline"][::5],
    }
