"""Hardware-aware replica orchestration + cost model (§3.2, Fig. 3, Table 1).

The paper's insight: pack K replicas per server. At small K every replica is
CPU-bound (burst demand exceeds its server's cores); at large K bursts
multiplex and RAM becomes the binding constraint — and RAM is 5-10x cheaper
per unit of hosting than CPU. We model replica CPU demand as
idle + Bernoulli(duty) * burst and compute overload fractions by Monte Carlo,
and we calibrate the price model so Table 1 reproduces exactly
(0.727/0.80/0.073 USD per core-day for 8275CL / 8259CL / E5-2699;
0.03 USD per GB-day DDR4 — a 16-core CPU then costs ~8-13x a 32 GB DIMM,
matching the paper's "10-20%" remark).
"""
from __future__ import annotations

import math
import random
import statistics
from dataclasses import dataclass
from typing import Optional

from repro.core.seeding import stable_seed

# ------------------------------------------------------------- price model
CORE_USD_PER_DAY = {
    "8275CL": 0.727,   # modern Xeon (on-demand cloud)
    "8259CL": 0.800,
    "E5-2699": 0.073,  # previous-gen bare metal — the paper's cheap pick
    "small-vm": 0.550, # small-instance pricing (2-8 vCPU shapes)
}
RAM_USD_PER_GB_DAY = 0.03   # DDR4
HOST_RAM_OVERHEAD_GB = 12.0
RAM_PER_REPLICA_GB = 5.0
MAX_REPLICAS_PER_NODE = 128  # pool default


@dataclass(frozen=True)
class MachineSpec:
    cores: int
    ram_gb: int
    cpu_type: str
    ram_type: str = "DDR4"
    # physical CoW disk budget this machine contributes to the shared
    # reflink store (repro.cluster draws replica placements against it)
    disk_gb: int = 240

    def price_per_day(self) -> float:
        return (CORE_USD_PER_DAY[self.cpu_type] * self.cores
                + RAM_USD_PER_GB_DAY * self.ram_gb)

    def replica_capacity(self) -> int:
        by_ram = int((self.ram_gb - HOST_RAM_OVERHEAD_GB)
                     // RAM_PER_REPLICA_GB)
        return max(min(by_ram, MAX_REPLICAS_PER_NODE), 0)


# Table 1 machines
TABLE1_MACHINES = [
    MachineSpec(96, 192, "8275CL"),
    MachineSpec(96, 768, "8259CL"),
    MachineSpec(88, 768, "E5-2699"),
]


def table1() -> list[dict]:
    rows = []
    for m in TABLE1_MACHINES:
        cap = m.replica_capacity()
        rows.append({
            "cores": m.cores, "ram_gb": m.ram_gb, "cpu": m.cpu_type,
            "ram_type": m.ram_type, "replicas": cap,
            "machine_usd_day": round(m.price_per_day(), 2),
            "usd_per_replica_day": round(m.price_per_day() / cap, 2),
        })
    return rows


# ------------------------------------------------------- CPU demand model
@dataclass(frozen=True)
class ReplicaDemand:
    idle_cores: float = 0.30
    burst_cores: float = 3.0
    duty: float = 0.25          # fraction of time slots at burst


def overload_fraction(K: int, cores: float, demand: ReplicaDemand,
                      *, slots: int = 20, trials: int = 200,
                      rng: Optional[random.Random] = None) -> float:
    """Fraction of replicas that hit CPU starvation within a window.

    A slot starves its bursting replicas when total demand exceeds cores.
    The default RNG is blake2b-seeded from the call's parameters (see
    ``core.seeding.stable_seed``), so Fig. 3 / Table 1 artifacts are
    bit-identical across processes, platforms, and Python versions."""
    rng = rng or random.Random(
        stable_seed("overload", K, cores, slots, trials))
    overloaded = 0
    total = 0
    for _ in range(trials):
        hit = [False] * K
        for _ in range(slots):
            bursting = [rng.random() < demand.duty for _ in range(K)]
            load = (demand.idle_cores * K
                    + demand.burst_cores * sum(bursting) + 0.5)
            if load > cores:
                for i, b in enumerate(bursting):
                    if b:
                        hit[i] = True
        overloaded += sum(hit)
        total += K
    return overloaded / total


def utilizations(K: int, spec: "MachineSpec") -> tuple[float, float]:
    """(cpu_util, ram_util) of K replicas on `spec` (mean CPU demand)."""
    d = ReplicaDemand()
    mean = d.idle_cores + d.burst_cores * d.duty
    cpu = (K * mean + 0.5) / spec.cores
    overhead = 2.0 if spec.cpu_type == "small-vm" else HOST_RAM_OVERHEAD_GB
    ram = (overhead + K * RAM_PER_REPLICA_GB) / spec.ram_gb
    return cpu, ram


# -------------------------------------------------- Fig. 3 configurations
def server_for_group(K: int) -> MachineSpec:
    """Pick the cheapest adequate server for K replicas.

    Small K -> small modern-CPU instances provisioned for burst peaks
    (no multiplexing); large K -> big-RAM previous-gen machines provisioned
    near the demand mean."""
    d = ReplicaDemand()
    if K <= 8:
        # small instances: provision for burst peaks, modern-CPU pricing
        ram = int(math.ceil(2.0 + K * RAM_PER_REPLICA_GB))
        cores = int(math.ceil(K * (d.idle_cores + d.burst_cores) + 0.5))
        return MachineSpec(cores, max(ram, 8), "small-vm")
    ram = int(math.ceil(HOST_RAM_OVERHEAD_GB + K * RAM_PER_REPLICA_GB))
    mean = d.idle_cores + d.burst_cores * d.duty
    cores = int(math.ceil(K * mean * 1.25 + 1))
    return MachineSpec(cores, ram, "E5-2699")


def fig3_sweep(n_replicas: int = 128, seeds: int = 10) -> list[dict]:
    """Reproduce Fig. 3's bottom plots: overload fraction and cost vs K."""
    rows = []
    ks = [k for k in (1, 2, 4, 8, 16, 32, 64, 128) if k <= n_replicas]
    for K in ks:
        servers = n_replicas // K
        # fixed-total-CPU variant for the overload plot (paper freezes N and
        # total CPU, varying only the grouping)
        cores_fixed = 2 * K
        fracs = [overload_fraction(K, cores_fixed, ReplicaDemand(),
                                   rng=random.Random(
                                       stable_seed("fig3", K, s)))
                 for s in range(seeds)]
        spec = server_for_group(K)
        cpu_util, ram_util = utilizations(K, spec)
        cost = servers * spec.price_per_day()
        rows.append({
            "K": K, "servers": servers,
            "overload_frac_mean": statistics.fmean(fracs),
            "overload_frac_std": (statistics.pstdev(fracs)
                                  if len(fracs) > 1 else 0.0),
            "cpu_util": round(cpu_util, 3),
            "ram_util": round(ram_util, 3),
            "bottleneck": bottleneck(K),
            "server": f"{spec.cores}c/{spec.ram_gb}g/{spec.cpu_type}",
            "usd_per_day": round(cost, 1),
            "usd_per_replica_day": round(cost / n_replicas, 3),
        })
    return rows


def bottleneck(K: int) -> str:
    """The paper's Remark: small K -> CPU-bound; large K -> RAM-bound."""
    frac = overload_fraction(K, 2 * K, ReplicaDemand())
    spec = server_for_group(K)
    cpu_util, ram_util = utilizations(K, spec)
    return "cpu" if (frac > 0.2 or cpu_util > ram_util) else "ram"
