"""Reflink copy-on-write block store (§3.3).

Two clients share this store:

1. **Replica disk images** — a bootable base image is a sequence of block
   content-IDs; ``clone()`` is an O(1) metadata copy (the reflink), and only
   blocks a VM writes are physically allocated. Reproduces Table 2
   (physical-disk reduction, provisioning speedup).

2. **Training checkpoints** — real byte payloads are chunked and
   content-addressed, so consecutive step snapshots share every unchanged
   block (the paper's disk insight applied to the training plane).

Reference-counted; freeing a clone releases only blocks no image still uses.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass
from typing import Optional

DEFAULT_BLOCK = 4 * 1024 * 1024  # 4 MiB


def _hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


@dataclass
class _Block:
    size: int
    refs: int = 0
    payload: Optional[bytes] = None   # None for virtual (disk-model) blocks


class CowStore:
    """Content-addressed, refcounted block store."""

    def __init__(self, block_size: int = DEFAULT_BLOCK):
        self.block_size = block_size
        self._blocks: dict[str, _Block] = {}
        self._lock = threading.Lock()
        # timing model (calibrated to the paper's Table 2: 24 GB image,
        # 30 s full copy vs 0.8 s reflink)
        self.copy_bw_bytes_per_s = 24e9 / 30.0
        self.reflink_latency_s = 0.8
        # provisioning counters: how many overlays were created each way.
        # The recovery ladder re-clones overlays on every L1/L2 repair and
        # L3 recreation, so the Fig. 6 benchmark reports reflink traffic
        # during a mass-recovery event from here.
        self.reflink_clones = 0
        self.full_copies = 0

    # ---------------------------------------------------------- block API
    def put_virtual(self, content_id: str, size: Optional[int] = None) -> str:
        with self._lock:
            blk = self._blocks.get(content_id)
            if blk is None:
                self._blocks[content_id] = _Block(size or self.block_size, 1)
            else:
                blk.refs += 1
        return content_id

    def put_bytes(self, data: bytes) -> str:
        cid = _hash(data)
        with self._lock:
            blk = self._blocks.get(cid)
            if blk is None:
                self._blocks[cid] = _Block(len(data), 1, data)
            else:
                blk.refs += 1
        return cid

    def get_bytes(self, cid: str) -> bytes:
        blk = self._blocks[cid]
        assert blk.payload is not None, "virtual block has no payload"
        return blk.payload

    def release(self, cid: str) -> None:
        with self._lock:
            blk = self._blocks.get(cid)
            if blk is None:
                return
            blk.refs -= 1
            if blk.refs <= 0:
                del self._blocks[cid]

    # ------------------------------------------------------------ metrics
    def physical_bytes(self) -> int:
        with self._lock:
            return sum(b.size for b in self._blocks.values())

    def n_blocks(self) -> int:
        return len(self._blocks)


class DiskImage:
    """A bootable disk: list of block content-IDs in a CowStore."""

    def __init__(self, store: CowStore, block_ids: list[str], name: str = ""):
        self.store = store
        self.blocks = list(block_ids)
        self.name = name
        self._closed = False

    @classmethod
    def create_base(cls, store: CowStore, name: str, size_bytes: int
                    ) -> "DiskImage":
        n = -(-size_bytes // store.block_size)
        ids = [store.put_virtual(f"{name}/base/{i}") for i in range(n)]
        return cls(store, ids, name)

    def clone(self, name: str = "") -> tuple["DiskImage", float]:
        """Reflink copy. Returns (image, provisioning_seconds)."""
        for cid in self.blocks:
            self.store.put_virtual(cid)
        with self.store._lock:
            self.store.reflink_clones += 1
        return (DiskImage(self.store, self.blocks, name or f"{self.name}+"),
                self.store.reflink_latency_s)

    def full_copy(self, name: str = "") -> tuple["DiskImage", float]:
        """Naive duplication baseline (no reflink)."""
        ids = [self.store.put_virtual(f"{name}/copy/{i}")
               for i in range(len(self.blocks))]
        secs = self.logical_bytes() / self.store.copy_bw_bytes_per_s
        with self.store._lock:
            self.store.full_copies += 1
        return DiskImage(self.store, ids, name), secs

    def write_block(self, idx: int, content: str) -> None:
        """CoW: writing allocates a private block; the shared one is released."""
        assert not self._closed
        old = self.blocks[idx]
        new = self.store.put_virtual(f"{self.name}/w/{idx}/{content}")
        self.store.release(old)
        self.blocks[idx] = new

    def logical_bytes(self) -> int:
        return len(self.blocks) * self.store.block_size

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for cid in self.blocks:
            self.store.release(cid)


class BlobStore:
    """Chunked, deduplicated byte storage on a CowStore (checkpoints)."""

    def __init__(self, store: Optional[CowStore] = None,
                 chunk: int = 1 << 20):
        self.store = store or CowStore(block_size=chunk)
        self.chunk = chunk
        self._manifests: dict[str, list[str]] = {}

    def put(self, key: str, data: bytes) -> dict:
        chunks = [data[i:i + self.chunk]
                  for i in range(0, max(len(data), 1), self.chunk)]
        ids = [self.store.put_bytes(c) for c in chunks]
        old = self._manifests.get(key)
        self._manifests[key] = ids
        if old:
            for cid in old:
                self.store.release(cid)
        return {"key": key, "n_chunks": len(ids),
                "logical": len(data),
                "physical_total": self.store.physical_bytes()}

    def get(self, key: str) -> bytes:
        return b"".join(self.store.get_bytes(c)
                        for c in self._manifests[key])

    def delete(self, key: str) -> None:
        for cid in self._manifests.pop(key, []):
            self.store.release(cid)

    def keys(self):
        return list(self._manifests)
