"""Live hardware-aware cluster control plane (§3.2, promoted from the
offline cost model): hosts with RAM/CoW-disk budgets, bin-packed
placement, live CPU-contention tracking, elastic autoscaling, and
load-aware routing over the event-driven fleet."""

from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.cluster import DEFAULT_MACHINE, Cluster, default_specs
from repro.cluster.host import (
    EST_COW_PER_REPLICA_BYTES,
    Host,
    HostDemand,
)
from repro.cluster.placement import Placement, PlacementError, Placer
from repro.core.orchestrator import MachineSpec

__all__ = [
    "Autoscaler",
    "AutoscalerConfig",
    "Cluster",
    "DEFAULT_MACHINE",
    "EST_COW_PER_REPLICA_BYTES",
    "Host",
    "HostDemand",
    "MachineSpec",
    "Placement",
    "PlacementError",
    "Placer",
    "default_specs",
]
