"""Placer: bin-pack replica capacity onto hosts under hard budgets.

Placement is refused — loudly, with :class:`PlacementError` — when the
requested capacity cannot fit the fleet's RAM or physical CoW-disk
budgets; a failed placement rolls its partial reservations back, so the
hosts are left exactly as found.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.cluster.host import Host, ReplicaFootprint


class PlacementError(RuntimeError):
    """The requested capacity exceeds the fleet's RAM/disk budgets."""


@dataclass(frozen=True)
class Placement:
    host: Host
    n: int


class Placer:
    """First-fit packer: deterministic, budget-respecting, rollback-safe."""

    def __init__(self, hosts: Sequence[Host]):
        self.hosts = list(hosts)

    def place(self, n_replicas: int, *, pool_size: int = 32,
              footprint: ReplicaFootprint = None) -> list[Placement]:
        """Reserve ``n_replicas`` across hosts; one plan entry per host.

        Hosts are filled in their given order (first fit), which keeps
        placement deterministic for a fixed host list. ``pool_size`` is
        the *preferred* per-host granularity: a first pass spreads pools
        of that size across the hosts, and only when the host list is
        exhausted does a second pass pack hosts up to their full RAM/disk
        capacity — so any request within the fleet's hard budgets
        succeeds. Reservations are committed on the hosts as the plan is
        built and fully rolled back if the request cannot be satisfied.

        ``footprint`` is the per-replica RAM/CoW demand being placed
        (heterogeneous backends pack very different counts per machine);
        ``None`` keeps the default SimOS footprint, bit-identical to the
        pre-footprint behavior. Hosts already dedicated to a different
        footprint report zero headroom and are skipped."""
        assert n_replicas > 0, "place at least one replica"
        counts: dict[int, int] = {}  # host index -> replicas placed
        remaining = n_replicas
        for cap_to_pool_size in (True, False):
            for i, host in enumerate(self.hosts):
                if remaining == 0:
                    break
                take = min(host.headroom_for(footprint), remaining)
                if cap_to_pool_size:
                    take = min(take, pool_size - counts.get(i, 0))
                if take <= 0:
                    continue
                host.reserve(take, footprint=footprint)
                counts[i] = counts.get(i, 0) + take
                remaining -= take
        if remaining:
            for i, n in counts.items():
                self.hosts[i].release_placement(n)
            total = sum(h.replica_capacity() for h in self.hosts)
            raise PlacementError(
                f"cannot place {n_replicas} replicas: {remaining} left "
                f"over after exhausting RAM/CoW-disk budgets "
                f"({len(self.hosts)} hosts, {total} total capacity)"
            )
        return [Placement(self.hosts[i], n) for i, n in counts.items()]

    def spare_capacity(self) -> int:
        return sum(h.headroom() for h in self.hosts)
