"""Hosts: machines that own replica capacity budgets and feel CPU load.

This is the live half of the paper's §3.2 hardware-aware orchestration.
``core/orchestrator.py`` keeps the *offline* cost model (Table 1 /
Fig. 3); a :class:`Host` promotes one `MachineSpec` from that model into
a control-plane citizen:

- **budgets** — replica placements draw against the machine's RAM (at
  the live container limit, with the resource guard's headroom reserved)
  and against its physical CoW-disk budget on the shared reflink store,
  charged at the worst case of a replica dirtying its whole base image;
- **live contention** — the mean-field port of
  ``orchestrator.overload_fraction``'s burst-multiplexing model: the
  expected CPU demand of the replicas currently *stepping* versus the
  machine's cores yields a latency multiplier (>= 1.0) that inflates
  every replica operation in virtual time, so overcommitting a host
  degrades trajectories/min instead of only a side report.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.cow_store import CowStore
from repro.core.orchestrator import MAX_REPLICAS_PER_NODE, MachineSpec
from repro.core.runner_pool import (
    HOST_OS_BASELINE_GB,
    HostSpec,
    RunnerPool,
    SimHost,
)

# Worst-case physical CoW footprint of one replica: every block of the
# 64 MiB base image dirtied. Placement charges this against the host's
# disk budget so the shared store can never physically overflow.
EST_COW_PER_REPLICA_BYTES = 64 << 20

# Live per-container RAM accounting (mirrors ReplicaResources): each VM
# is capped at 6 GB, and the pool's ResourceGuard keeps 8 GB absolute
# headroom free on the host.
REPLICA_RAM_LIMIT_GB = 6.0
GUARD_HEADROOM_GB = 8.0
GUARD_HEADROOM_FRAC = 0.10


@dataclass(frozen=True)
class ReplicaFootprint:
    """Per-replica placement demand of one environment backend.

    Heterogeneous fleets (``repro.envs``) bin-pack per-backend demand:
    a container-free SWE sandbox reserves 1.5 GB RAM and an 8 MiB CoW
    delta where an OS VM reserves 6 GB and 64 MiB, so the same machine
    holds very different replica counts depending on what it serves.
    The default footprint is the SimOS profile — legacy single-backend
    placement is bit-identical to the pre-footprint code path."""

    ram_limit_gb: float = REPLICA_RAM_LIMIT_GB
    cow_bytes: int = EST_COW_PER_REPLICA_BYTES

    @classmethod
    def for_backend(cls, backend) -> "ReplicaFootprint":
        """The footprint an ``EnvBackend`` declares (resources + CoW)."""
        return cls(ram_limit_gb=backend.ram_limit_gb(),
                   cow_bytes=backend.est_cow_bytes)


DEFAULT_FOOTPRINT = ReplicaFootprint()


@dataclass(frozen=True)
class HostDemand:
    """Per-replica CPU demand: idle + Bernoulli(duty) * burst.

    The same shape as ``orchestrator.ReplicaDemand`` but with the live
    fleet's ``ReplicaResources`` defaults (0.1 idle / 2.0 burst cores at
    20% duty), so a well-provisioned paper-shaped host sits at factor
    1.0 and only genuine overcommit inflates latency."""

    idle_cores: float = 0.1
    burst_cores: float = 2.0
    duty: float = 0.2
    os_cores: float = 0.5

    def mean_cores(self, placed: int, stepping: int) -> float:
        """Expected demand: every placed replica idles, stepping ones
        additionally burst at their duty cycle."""
        burst = self.burst_cores * self.duty * stepping
        return self.idle_cores * placed + burst + self.os_cores


class Host:
    """One machine in the cluster: budgets, a pool slot, live contention."""

    def __init__(
        self,
        host_id: str,
        spec: MachineSpec,
        store: CowStore,
        *,
        demand: Optional[HostDemand] = None,
    ):
        self.host_id = host_id
        self.spec = spec
        self.store = store
        self.demand = demand or HostDemand()
        # regional price-sheet scale: a Region prices its hosts off the
        # Table-1 model times this factor (regional market premium, and a
        # deep discount on spot/preemptible tiers). 1.0 = the spec price.
        self.price_multiplier = 1.0
        self.sim = SimHost(HostSpec(cores=spec.cores, ram_gb=float(spec.ram_gb)))
        self.disk_budget_bytes = spec.disk_gb << 30
        self.placed = 0  # replicas reserved on this host (incl. booting)
        # the footprint this host's placements reserve at: set on first
        # reserve, cleared when the host empties. One host serves one
        # backend at a time (a pool is single-backend), so mixed fleets
        # dedicate hosts rather than interleave footprints.
        self.footprint: Optional[ReplicaFootprint] = None
        self.pool: Optional[RunnerPool] = None
        # L4: an evicted host is unschedulable — the recovery ladder
        # declared it exhausted (kernel limits), so replacement capacity
        # must land elsewhere
        self.evicted = False

    # ------------------------------------------------------------- budgets
    def capacity_for(self, footprint: ReplicaFootprint) -> int:
        """Replicas of one footprint this machine can hold before RAM or
        CoW disk binds."""
        usable_ram = self.spec.ram_gb * (1.0 - GUARD_HEADROOM_FRAC)
        usable_ram -= HOST_OS_BASELINE_GB + GUARD_HEADROOM_GB
        by_ram = int(usable_ram // footprint.ram_limit_gb)
        by_disk = int(self.disk_budget_bytes // footprint.cow_bytes)
        return max(min(by_ram, by_disk, MAX_REPLICAS_PER_NODE), 0)

    def replica_capacity(self) -> int:
        """Capacity at the host's current footprint (SimOS by default)."""
        return self.capacity_for(self.footprint or DEFAULT_FOOTPRINT)

    def headroom(self) -> int:
        if self.evicted:
            return 0
        return self.replica_capacity() - self.placed

    def headroom_for(self, footprint: Optional[ReplicaFootprint]) -> int:
        """Headroom for *one backend's* footprint.

        A host already serving a different footprint reports zero: pools
        are single-backend, so mixed fleets dedicate whole hosts instead
        of interleaving RAM/disk demand shapes on one machine."""
        fp = footprint or DEFAULT_FOOTPRINT
        if self.evicted:
            return 0
        if self.placed and self.footprint is not None \
                and self.footprint != fp:
            return 0
        return self.capacity_for(fp) - self.placed

    def reserve(self, n: int,
                footprint: Optional[ReplicaFootprint] = None) -> None:
        fp = footprint or DEFAULT_FOOTPRINT
        assert n <= self.headroom_for(fp), (
            f"{self.host_id}: reserving {n} replicas exceeds headroom "
            f"{self.headroom_for(fp)}"
        )
        self.footprint = fp
        self.placed += n

    def release_placement(self, n: int) -> None:
        self.placed = max(self.placed - n, 0)
        if self.placed == 0:
            self.footprint = None

    # ---------------------------------------------------------- contention
    def contention_factor(self) -> float:
        """Live step-latency multiplier from CPU overcommit (>= 1.0).

        Mean-field version of ``orchestrator.overload_fraction``: the
        expected core demand of the host's current occupancy (placed
        replicas idling, leased ones bursting at duty) divided by the
        machine's cores. Below 1.0 bursts multiplex cleanly and latency
        is unchanged; above it the host is CPU-starved and every
        operation stretches proportionally in virtual time."""
        if self.pool is None:
            return 1.0
        mean = self.demand.mean_cores(self.pool.size, self.pool.n_busy)
        return max(mean / self.spec.cores, 1.0)

    # ------------------------------------------------------------- metrics
    def utilization(self) -> dict:
        """Instantaneous utilization for telemetry gauges."""
        placed = self.pool.size if self.pool is not None else 0
        busy = self.pool.n_busy if self.pool is not None else 0
        cpu = self.demand.mean_cores(placed, busy) / self.spec.cores
        ram = self.sim.ram_used_gb / self.spec.ram_gb
        budget = max(self.disk_budget_bytes, 1)
        cow = (self.footprint or DEFAULT_FOOTPRINT).cow_bytes
        disk = self.placed * cow / budget
        return {
            "host": self.host_id,
            "replicas": placed,
            "busy": busy,
            "cpu_util": cpu,
            "ram_util": ram,
            "disk_frac": disk,
            "contention": self.contention_factor(),
        }

    def price_per_day(self) -> float:
        """USD/day for this machine (the Table-1 price model, live),
        scaled by the regional/spot price multiplier."""
        return self.spec.price_per_day() * self.price_multiplier
