"""Cluster: the live §3.2 control plane over the event-driven fleet.

Where ``core/orchestrator.py`` *prices* hardware-aware packing offline,
a ``Cluster`` runs it: a list of `MachineSpec`s becomes `Host`s with RAM
and CoW-disk budgets, a `Placer` bin-packs `RunnerPool` capacity onto
them, the `Gateway` routes least-loaded over the live pools, per-host
contention trackers inflate step latency when a machine is CPU
overcommitted, and an optional `Autoscaler` grows and drains the fleet
at runtime from gateway pressure signals. The cluster also keeps the
books: a replica-seconds integral of provisioned capacity over virtual
time and USD/replica-day gauges computed from the Table-1 price model.
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.host import Host, HostDemand, ReplicaFootprint
from repro.cluster.placement import Placer
from repro.core.cow_store import CowStore, DiskImage
from repro.core.event_loop import EventLoop, Timer
from repro.core.faults import FaultInjector
from repro.core.gateway import Gateway
from repro.core.orchestrator import MachineSpec
from repro.core.replica import LatencyModel
from repro.core.runner_pool import RunnerPool
from repro.core.seeding import stable_seed
from repro.core.telemetry import Telemetry

# The paper's cheap large-RAM pick (Table 1): 88-core / 768 GB E5-2699.
DEFAULT_MACHINE = MachineSpec(88, 768, "E5-2699")

SECONDS_PER_DAY = 86400.0


def default_specs(n_replicas: int, *, runners_per_node: int = 32) -> list[MachineSpec]:
    """Enough default machines to host ``n_replicas`` at the given pool
    granularity (one pool per host)."""
    n_hosts = max(math.ceil(n_replicas / runners_per_node), 1)
    return [DEFAULT_MACHINE] * n_hosts


class Cluster:
    """Hosts + placement + routing + contention + elasticity, as one unit."""

    def __init__(
        self,
        specs: Sequence[MachineSpec],
        n_replicas: int,
        *,
        runners_per_node: int = 32,
        seed: int = 0,
        routing: str = "least_loaded",
        node_prefix: str = "node",
        faults: bool = True,
        latency: Optional[LatencyModel] = None,
        demand: Optional[HostDemand] = None,
        autoscaler: Optional[AutoscalerConfig] = None,
        telemetry: Optional[Telemetry] = None,
        sample_interval_vs: float = 10.0,
        fault_profile: Optional[Callable[[Host], Optional[dict]]] = None,
        backends: Optional[Sequence[tuple]] = None,
    ):
        self.seed = seed
        self.node_prefix = node_prefix
        self.faults = faults
        # per-host fault-rate override: called with the Host at pool build
        # time; a dict return replaces DEFAULT_RATES for that host's
        # injector (regions use this to give spot-tier hosts a preempt
        # rate), None keeps the defaults. Seeds are unchanged either way.
        self.fault_profile = fault_profile
        self.latency = latency
        self.telemetry = telemetry or Telemetry()
        self.sample_interval_vs = sample_interval_vs
        self.store = CowStore(block_size=1 << 20)
        self.base = DiskImage.create_base(self.store, "ubuntu", 64 << 20)
        self.hosts = [
            Host(f"host{i}", spec, self.store, demand=demand)
            for i, spec in enumerate(specs)
        ]
        self._pool_seq = 0
        if backends is None:
            plan = Placer(self.hosts).place(n_replicas, pool_size=runners_per_node)
            pools = [self._build_pool(p.host, p.n) for p in plan]
        else:
            # heterogeneous fleet: each (backend_name, count) group is
            # bin-packed at its own per-replica footprint. Pools (and
            # therefore hosts) are single-backend, so headroom_for skips
            # hosts already dedicated to a different demand shape; the
            # per-group placement order is deterministic. ``n_replicas``
            # is ignored — capacity is the sum of the group counts.
            from repro.envs.base import get_backend  # lazy: avoid cycles
            pools = []
            for backend_name, count in backends:
                backend = get_backend(backend_name)
                fp = ReplicaFootprint.for_backend(backend)
                plan = Placer(self.hosts).place(
                    count, pool_size=runners_per_node, footprint=fp)
                pools.extend(
                    self._build_pool(p.host, p.n, backend=backend)
                    for p in plan
                )
        self.gateway = Gateway(pools, routing=routing, telemetry=self.telemetry)
        self.autoscaler: Optional[Autoscaler] = None
        if autoscaler is not None:
            self.autoscaler = Autoscaler(self, autoscaler, telemetry=self.telemetry)
        self._loop: Optional[EventLoop] = None
        self._sampler: Optional[Timer] = None
        # boot-delayed grow timers in flight: (timer, host, n, backend).
        # Flushed on detach so a reservation whose boot the loop never ran
        # is returned instead of leaking as phantom placed capacity.
        self._pending_grows: list[tuple] = []
        # pools dropped from routing by L4 eviction: their hosts no longer
        # reference them, but close() must still shut their managers down
        self._evicted_pools: list[RunnerPool] = []
        # replica-seconds integral of *provisioned* capacity (the cost
        # the fleet is paying for, whether or not a runner is leased)
        self._rs_integral = 0.0
        self._rs_last_vt = 0.0
        self._rs_size = self.placed_replicas
        self.peak_placed = self._rs_size  # capacity high-water mark

    # ---------------------------------------------------------------- build
    def _build_pool(self, host: Host, n: int, backend=None) -> RunnerPool:
        """One pre-warmed pool on ``host`` (its placement already holds).

        Fault rates resolve in override order: the cluster's
        ``fault_profile`` (per-host, e.g. spot tiers) wins, then the
        backend's calibrated ``fault_rates`` mix, then the SimOS
        defaults. Seeds are unchanged in every case."""
        i = self._pool_seq
        self._pool_seq += 1
        rates = None
        if self.fault_profile is not None:
            rates = self.fault_profile(host)
        if rates is None and backend is not None:
            rates = backend.fault_rates
        if rates is None:
            injector = FaultInjector(seed=stable_seed(self.seed, "faults", i))
        else:
            injector = FaultInjector(
                rates=rates, seed=stable_seed(self.seed, "faults", i))
        if not self.faults:
            injector = FaultInjector(enabled=False)
        pool = RunnerPool(
            f"{self.node_prefix}{i}",
            self.base,
            size=n,
            host=host.sim,
            faults=injector,
            seed=stable_seed(self.seed, "pool", i),
            latency=self.latency,
            backend=backend,
        )
        if pool.size < n:  # resource guard refused part of the placement
            host.release_placement(n - pool.size)
        pool.latency_scale_fn = host.contention_factor
        host.pool = pool
        return pool

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop: EventLoop) -> None:
        """Bind the whole control plane to an event loop: gateway + pools,
        the autoscaler daemon, the telemetry sampler, and the
        replica-seconds clock."""
        if self._loop is loop:
            return
        if self._loop is not None:
            self.detach_loop()
        self._loop = loop
        self.gateway.attach_loop(loop)
        # L4 sink: canary-driven node eviction replaces capacity on the
        # remaining hosts instead of just dropping it
        self.gateway.on_evict = self.evict_host
        self._rs_last_vt = loop.now
        self._rs_size = self.placed_replicas
        if self.autoscaler is not None:
            self.autoscaler.attach_loop(loop)
        self._sampler = loop.call_later(
            self.sample_interval_vs, self._sample_tick, daemon=True
        )

    def detach_loop(self) -> None:
        """Unbind from the loop, folding the final capacity segment into
        the replica-seconds integral first."""
        if self._loop is None:
            return
        # cancel boot-delayed grows the loop will never run and hand their
        # reservations back — the capacity never booted, so letting it
        # linger would both bill forever and block future scale-ups
        for timer, host, n, _backend in self._pending_grows:
            timer.cancel()
            host.release_placement(n)
        self._pending_grows.clear()
        self._note_capacity()
        if self._sampler is not None:
            self._sampler.cancel()
            self._sampler = None
        if self.autoscaler is not None:
            self.autoscaler.detach_loop()
        self.gateway.detach_loop()
        self._loop = None

    def close(self) -> None:
        self.detach_loop()
        self.gateway.stop()
        for host in self.hosts:
            if host.pool is not None:
                host.pool.close()
        for pool in self._evicted_pools:
            pool.close()

    # ----------------------------------------------------------- elasticity
    def request_grow(self, n: int, *, delay_vs: float = 0.0,
                     backend=None) -> int:
        """Reserve up to ``n`` replicas against host budgets; returns how
        many were granted. Capacity is charged to the replica-seconds
        integral immediately (provisioning costs money) but only serves
        after ``delay_vs`` virtual seconds of boot lag.

        ``backend`` scopes the grow to hosts that can hold that
        backend's footprint (mixed fleets replace evicted SWE capacity
        with SWE capacity, never a different environment kind); ``None``
        grows at the default SimOS footprint."""
        fp = ReplicaFootprint.for_backend(backend) if backend is not None \
            else None
        granted = 0
        for host in self.hosts:
            if granted >= n:
                break
            take = min(host.headroom_for(fp), n - granted)
            if take <= 0:
                continue
            host.reserve(take, footprint=fp)
            if self._loop is not None and delay_vs > 0:
                timer = self._loop.call_later(
                    delay_vs, self._boot_grown, host, take, backend,
                    daemon=True
                )
                self._pending_grows.append((timer, host, take, backend))
            else:
                self._grow_host(host, take, backend)
            granted += take
        if granted:
            self._note_capacity()
        return granted

    def _boot_grown(self, host: Host, n: int, backend=None) -> None:
        # timers fire in schedule order, so the first match is this one
        for i, p in enumerate(self._pending_grows):
            if p[1] is host and p[2] == n:
                del self._pending_grows[i]
                break
        self._grow_host(host, n, backend)

    def _grow_host(self, host: Host, n: int, backend=None) -> None:
        if host.evicted:
            # raced with an L4 eviction: the reservation was already
            # released by evict_host and the node must never rejoin
            # routing — booting a pool here would serve born-broken
            # runners from the exhausted host
            return
        if host.pool is None:
            self.gateway.add_pool(self._build_pool(host, n, backend=backend))
        else:
            created = host.pool.grow(n)
            if created < n:  # resource guard refused part of the grant
                host.release_placement(n - created)
                self._note_capacity()

    # ------------------------------------------------------------- L4 evict
    REPLACEMENT_BOOT_VS = 12.0   # provisioning lag for evicted capacity

    def evict_host(self, node_id: str) -> int:
        """L4 of the recovery ladder: a node whose recreations keep
        coming back broken is exhausted (kernel limits) — remove it from
        routing, mark its host unschedulable, and request replacement
        capacity on the remaining hosts (charged the usual provisioning
        boot lag). In-flight leases on the node drain through the
        gateway's retired-pool path; its silently-broken runners are
        quarantined on release. Returns how many replacement replicas
        were granted."""
        host = next((h for h in self.hosts
                     if h.pool is not None
                     and h.pool.node_id == node_id), None)
        if host is None:
            return 0
        pool = host.pool
        pool.evicted = True
        if node_id in self.gateway.pools:
            self.gateway.remove_pool(node_id)
        # boot-delayed grows reserved on this host will never boot: cancel
        # them so the timer cannot rebuild a pool on the exhausted node
        # (their reservation is part of host.placed, released below)
        for i in range(len(self._pending_grows) - 1, -1, -1):
            timer, h = self._pending_grows[i][0], self._pending_grows[i][1]
            if h is host:
                timer.cancel()
                del self._pending_grows[i]
        # replace the host's full placement, not just the runners still
        # registered: canary quarantines may already have shrunk the pool
        # (broken recreations never made it back into service)
        lost = host.placed
        host.evicted = True
        host.release_placement(host.placed)
        host.pool = None
        self._evicted_pools.append(pool)
        self.telemetry.count("cluster_nodes_evicted")
        self._note_capacity()
        # replacement capacity keeps the evicted pool's environment kind:
        # a drained SWE node is backfilled with SWE replicas, never with
        # a different backend's footprint
        granted = self.request_grow(
            lost, delay_vs=self.REPLACEMENT_BOOT_VS, backend=pool.backend)
        if granted > 0:
            # node-level MTTR: replacement capacity serves after its boot.
            # No observation when nothing was granted — an unreplaced
            # eviction is lost capacity, not a 12 vs recovery
            self.telemetry.observe(
                "recovery_mttr_vs:l4", self.REPLACEMENT_BOOT_VS
            )
        if granted < lost:
            self.telemetry.count("evicted_replicas_unreplaced", lost - granted)
        return granted

    def scale_down(self, n: int) -> int:
        """Retire up to ``n`` *free* replicas (leases are never touched),
        draining the newest hosts first; empty pools leave the gateway.
        Returns how many replicas were actually retired."""
        removed = 0
        for host in reversed(self.hosts):
            if removed >= n:
                break
            pool = host.pool
            if pool is None:
                continue
            got = pool.shrink(min(pool.n_free, n - removed))
            host.release_placement(got)
            removed += got
            if pool.size == 0 and len(self.gateway.pools) > 1:
                self.gateway.remove_pool(pool.node_id)
                host.pool = None
        if removed:
            self._note_capacity()
        return removed

    # ------------------------------------------------------------- metrics
    @property
    def pools(self) -> list[RunnerPool]:
        return [h.pool for h in self.hosts if h.pool is not None]

    @property
    def n_replicas(self) -> int:
        """Live (booted) replicas across all hosts."""
        return sum(p.size for p in self.pools)

    @property
    def placed_replicas(self) -> int:
        """Provisioned replicas, including ones still booting."""
        return sum(h.placed for h in self.hosts)

    def _now(self) -> float:
        return self._loop.now if self._loop is not None else self._rs_last_vt

    def _note_capacity(self) -> None:
        """Fold the elapsed segment into the integral at the old size,
        then start a new segment at the current provisioned size."""
        now = self._now()
        self._rs_integral += self._rs_size * (now - self._rs_last_vt)
        self._rs_last_vt = now
        self._rs_size = self.placed_replicas
        self.peak_placed = max(self.peak_placed, self._rs_size)
        self.telemetry.gauge("cluster_replicas_placed", float(self._rs_size))
        self.telemetry.gauge("cluster_replicas_live", float(self.n_replicas))

    def replica_seconds(self) -> float:
        """Integral of provisioned replicas over virtual time so far."""
        tail = self._rs_size * (self._now() - self._rs_last_vt)
        return self._rs_integral + tail

    def replica_days(self) -> float:
        return self.replica_seconds() / SECONDS_PER_DAY

    def price_per_day(self) -> float:
        """USD/day of the machines currently hosting capacity."""
        return sum(h.price_per_day() for h in self.hosts if h.placed > 0)

    def usd_per_replica_day(self) -> float:
        placed = self.placed_replicas
        return self.price_per_day() / placed if placed else 0.0

    def disk_physical_frac(self) -> float:
        """Physical bytes in the shared CoW store vs the fleet budget."""
        budget = sum(h.disk_budget_bytes for h in self.hosts)
        return self.store.physical_bytes() / budget if budget else 0.0

    def _sample_tick(self) -> None:
        self.sample_gauges()
        self._sampler = self._loop.call_later(
            self.sample_interval_vs, self._sample_tick, daemon=True
        )

    def sample_gauges(self) -> None:
        """Publish host-utilization and pricing gauges to telemetry."""
        active = sum(1 for h in self.hosts if h.pool is not None)
        self.telemetry.gauge("cluster_hosts_active", float(active))
        self.telemetry.gauge("cluster_replicas_live", float(self.n_replicas))
        placed = float(self.placed_replicas)
        self.telemetry.gauge("cluster_replicas_placed", placed)
        self.telemetry.gauge("cluster_usd_per_day", self.price_per_day())
        usd_rd = self.usd_per_replica_day()
        self.telemetry.gauge("cluster_usd_per_replica_day", usd_rd)
        self.telemetry.gauge("cluster_disk_frac", self.disk_physical_frac())
        for h in self.hosts:
            u = h.utilization()
            self.telemetry.gauge(f"host_cpu_util:{h.host_id}", u["cpu_util"])
            self.telemetry.gauge(f"host_ram_util:{h.host_id}", u["ram_util"])
            name = f"host_contention:{h.host_id}"
            self.telemetry.gauge(name, u["contention"])

    def health(self) -> dict:
        """One control-plane snapshot (hosts, capacity, pricing)."""
        return {
            "hosts": [h.utilization() for h in self.hosts],
            "replicas_live": self.n_replicas,
            "replicas_placed": self.placed_replicas,
            "replica_days": self.replica_days(),
            "usd_per_day": self.price_per_day(),
            "usd_per_replica_day": self.usd_per_replica_day(),
            "disk_frac": self.disk_physical_frac(),
        }
