"""Elastic autoscaler: an event-loop daemon that sizes the live fleet.

Every ``interval_vs`` virtual seconds the daemon drains the gateway's
tenant-tagged acquire-wait window and computes the fleet's **SLO burn**:
each tenant's wait p95 divided by that tenant's SLO target, maxed over
tenants. Burn > 1.0 means some tenant is out of SLO — the fleet grows
even if the *aggregate* p95 looks healthy (one starved tenant hiding
under a quiet majority is exactly the case a global signal misses).
Untagged samples form the single-tenant special case: their burn is the
old global ``p95 / wait_p95_high_vs`` ratio, so fleets without tenancy
scale bit-identically to the pre-tenant autoscaler.

Growth is placed against host budgets — a fleet that is out of RAM or
CoW disk refuses to scale and counts the refusal — and new capacity only
serves after a boot delay in virtual time, so scaling decisions pay a
realistic provisioning lag. Draining still keys off the aggregate
signal: idleness is a fleet-wide property (no waiters anywhere, most
runners free), not a per-tenant one.

Determinism contract: every decision reads deterministic fleet state on
the deterministic event loop (virtual clock, tagged wait window, queue
depth), so an autoscaled run — including every grow, drain, and refusal
— is exactly reproducible per seed in any process.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.event_loop import EventLoop, Timer
from repro.core.telemetry import Telemetry, p95


def slo_burn(
    tagged_waits: list[tuple[Optional[str], float]],
    default_slo_vs: float,
    tenant_slos: Optional[dict[str, float]] = None,
) -> float:
    """Max over tenants of (wait p95 / SLO target) for one window.

    ``tagged_waits`` is the gateway's drained ``(tenant, waited_vs)``
    window; untagged samples (tenant ``None``) burn against
    ``default_slo_vs``, which makes the no-tenant fleet the single-tenant
    special case: ``slo_burn([(None, w), ...], high) > 1.0`` iff the old
    global ``p95 > high`` test fired. Returns 0.0 on an empty window.

    >>> slo_burn([(None, 20.0)] * 20, 10.0)
    2.0
    >>> slo_burn([("a", 4.0), ("b", 4.0)], 10.0, {"b": 2.0})
    2.0
    """
    if not tagged_waits:
        return 0.0
    slos = tenant_slos or {}
    by_tenant: dict[Optional[str], list[float]] = {}
    for tenant, w in tagged_waits:
        by_tenant.setdefault(tenant, []).append(w)
    burn = 0.0
    for tenant, waits in by_tenant.items():
        slo = slos.get(tenant, default_slo_vs) if tenant is not None else default_slo_vs
        if slo <= 0.0:
            continue
        burn = max(burn, p95(waits) / slo)
    return burn


@dataclass
class AutoscalerConfig:
    interval_vs: float = 5.0  # tick period on the virtual clock
    wait_p95_high_vs: float = 10.0  # default per-tenant SLO: grow past this
    wait_p95_low_vs: float = 1.0  # drain below this (and idle)
    queue_high: int = 1  # grow when this many acquires are parked
    grow_step: int = 16  # replicas added per scale-up
    shrink_step: int = 8  # replicas retired per scale-down
    idle_free_frac: float = 0.6  # drain only when this fraction is free
    boot_delay_vs: float = 12.0  # provisioning lag for new replicas
    cooldown_vs: float = 15.0  # minimum virtual time between scalings
    min_replicas: int = 8
    max_replicas: int = 2048
    # per-tenant SLO overrides (tenant id -> acquire-wait p95 target, vs);
    # tenants not listed burn against wait_p95_high_vs. Wire from a
    # FairShareScheduler with ``tenant_slos=scheduler.slo_map()``.
    tenant_slos: dict[str, float] = field(default_factory=dict)


class Autoscaler:
    """Grow/drain daemon over one cluster's tenant-tagged gateway signals."""

    def __init__(
        self,
        cluster,
        cfg: Optional[AutoscalerConfig] = None,
        *,
        telemetry: Optional[Telemetry] = None,
    ):
        self.cluster = cluster
        self.cfg = cfg or AutoscalerConfig()
        self.telemetry = telemetry or Telemetry()
        self.scale_ups = 0
        self.scale_downs = 0
        self.blocked = 0  # scale-ups refused by host budgets
        self._loop: Optional[EventLoop] = None
        self._timer: Optional[Timer] = None
        self._last_scale_vt = float("-inf")

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop: EventLoop) -> None:
        """Arm the tick daemon on ``loop``'s virtual clock. Idempotent per
        run: ``detach_loop`` cancels the timer so a cluster can bind to a
        fresh loop (a new engine run) with clean cooldown state."""
        self._loop = loop
        self._last_scale_vt = float("-inf")
        self._timer = loop.call_later(self.cfg.interval_vs, self._tick, daemon=True)

    def detach_loop(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._loop = None

    # ----------------------------------------------------------------- tick
    def _tick(self) -> None:
        """One sizing decision on the virtual clock.

        Pressure = per-tenant SLO burn (see :func:`slo_burn`) or queued
        acquires; idleness = aggregate p95 under the low-water mark with
        no waiters and most runners free. Exactly one of grow/drain can
        fire per tick, and only after the cooldown."""
        cfg = self.cfg
        gw = self.cluster.gateway
        tagged = gw.drain_wait_samples_tagged()
        waits = [w for _t, w in tagged]
        wait_p95 = p95(waits)
        burn = slo_burn(tagged, cfg.wait_p95_high_vs, cfg.tenant_slos)
        depth = gw.n_waiting
        placed = self.cluster.placed_replicas
        live = self.cluster.n_replicas
        free = sum(p.n_free for p in gw.pools.values())
        free_frac = free / live if live else 0.0
        self.telemetry.gauge("autoscaler_wait_p95_vs", wait_p95)
        self.telemetry.gauge("autoscaler_slo_burn", burn)
        self.telemetry.gauge("autoscaler_queue_depth", float(depth))

        now = self._loop.now
        cooled = now - self._last_scale_vt >= cfg.cooldown_vs
        pressured = burn > 1.0 or depth >= cfg.queue_high
        idle = (
            wait_p95 < cfg.wait_p95_low_vs
            and depth == 0
            and free_frac >= cfg.idle_free_frac
        )
        if pressured and cooled and placed < cfg.max_replicas:
            want = min(cfg.grow_step, cfg.max_replicas - placed)
            granted = self.cluster.request_grow(want, delay_vs=cfg.boot_delay_vs)
            if granted > 0:
                self.scale_ups += 1
                self._last_scale_vt = now
                self.telemetry.count("autoscaler_scale_ups")
                self.telemetry.count("autoscaler_replicas_added", granted)
            else:
                self.blocked += 1
                self.telemetry.count("autoscaler_blocked")
        elif idle and cooled and placed > cfg.min_replicas:
            want = min(cfg.shrink_step, placed - cfg.min_replicas)
            removed = self.cluster.scale_down(want)
            if removed > 0:
                self.scale_downs += 1
                self._last_scale_vt = now
                self.telemetry.count("autoscaler_scale_downs")
                self.telemetry.count("autoscaler_replicas_removed", removed)
        self._timer = self._loop.call_later(cfg.interval_vs, self._tick, daemon=True)
