"""Elastic autoscaler: an event-loop daemon that sizes the live fleet.

Watches the gateway's virtual acquire-wait p95 and queue depth every
``interval_vs`` virtual seconds and asks the cluster to grow when demand
outruns capacity (waiters queueing, p95 above the high-water mark) or to
drain when the fleet idles (no waiters, p95 under the low-water mark,
most runners free). Growth is placed against host budgets — a fleet
that is out of RAM or CoW disk refuses to scale and counts the refusal —
and new capacity only serves after a boot delay in virtual time, so
scaling decisions pay a realistic provisioning lag.

Every decision reads deterministic fleet state on the deterministic
event loop, so an autoscaled run is exactly reproducible per seed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.event_loop import EventLoop, Timer
from repro.core.telemetry import Telemetry, p95


@dataclass
class AutoscalerConfig:
    interval_vs: float = 5.0  # tick period on the virtual clock
    wait_p95_high_vs: float = 10.0  # grow above this acquire-wait p95
    wait_p95_low_vs: float = 1.0  # drain below this (and idle)
    queue_high: int = 1  # grow when this many acquires are parked
    grow_step: int = 16  # replicas added per scale-up
    shrink_step: int = 8  # replicas retired per scale-down
    idle_free_frac: float = 0.6  # drain only when this fraction is free
    boot_delay_vs: float = 12.0  # provisioning lag for new replicas
    cooldown_vs: float = 15.0  # minimum virtual time between scalings
    min_replicas: int = 8
    max_replicas: int = 2048


class Autoscaler:
    """Grow/drain daemon over one cluster's gateway signals."""

    def __init__(
        self,
        cluster,
        cfg: Optional[AutoscalerConfig] = None,
        *,
        telemetry: Optional[Telemetry] = None,
    ):
        self.cluster = cluster
        self.cfg = cfg or AutoscalerConfig()
        self.telemetry = telemetry or Telemetry()
        self.scale_ups = 0
        self.scale_downs = 0
        self.blocked = 0  # scale-ups refused by host budgets
        self._loop: Optional[EventLoop] = None
        self._timer: Optional[Timer] = None
        self._last_scale_vt = float("-inf")

    # ------------------------------------------------------------ lifecycle
    def attach_loop(self, loop: EventLoop) -> None:
        self._loop = loop
        self._last_scale_vt = float("-inf")
        self._timer = loop.call_later(self.cfg.interval_vs, self._tick, daemon=True)

    def detach_loop(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._loop = None

    # ----------------------------------------------------------------- tick
    def _tick(self) -> None:
        cfg = self.cfg
        gw = self.cluster.gateway
        waits = gw.drain_wait_samples()
        wait_p95 = p95(waits)
        depth = gw.n_waiting
        placed = self.cluster.placed_replicas
        live = self.cluster.n_replicas
        free = sum(p.n_free for p in gw.pools.values())
        free_frac = free / live if live else 0.0
        self.telemetry.gauge("autoscaler_wait_p95_vs", wait_p95)
        self.telemetry.gauge("autoscaler_queue_depth", float(depth))

        now = self._loop.now
        cooled = now - self._last_scale_vt >= cfg.cooldown_vs
        pressured = wait_p95 > cfg.wait_p95_high_vs or depth >= cfg.queue_high
        idle = (
            wait_p95 < cfg.wait_p95_low_vs
            and depth == 0
            and free_frac >= cfg.idle_free_frac
        )
        if pressured and cooled and placed < cfg.max_replicas:
            want = min(cfg.grow_step, cfg.max_replicas - placed)
            granted = self.cluster.request_grow(want, delay_vs=cfg.boot_delay_vs)
            if granted > 0:
                self.scale_ups += 1
                self._last_scale_vt = now
                self.telemetry.count("autoscaler_scale_ups")
                self.telemetry.count("autoscaler_replicas_added", granted)
            else:
                self.blocked += 1
                self.telemetry.count("autoscaler_blocked")
        elif idle and cooled and placed > cfg.min_replicas:
            want = min(cfg.shrink_step, placed - cfg.min_replicas)
            removed = self.cluster.scale_down(want)
            if removed > 0:
                self.scale_downs += 1
                self._last_scale_vt = now
                self.telemetry.count("autoscaler_scale_downs")
                self.telemetry.count("autoscaler_replicas_removed", removed)
        self._timer = self._loop.call_later(cfg.interval_vs, self._tick, daemon=True)
