"""Mobile backend: device-emulator episodes.

Each replica is a mobile-device emulator restored from a CoW snapshot:
the slowest backend to provision (cold boot dominates, which is why the
pre-warmed pool layer matters most here) and the heaviest non-VM disk
delta. Steps are UI events against the emulated device; faults skew
toward app crashes and ANR-style timeouts. Resource demand is closer to
an OS VM (~4 GB RAM limit) but with a distinct CPU envelope, so mixed
placement cannot treat it as either a SimOS VM or a browser process.

The canary replays a scripted home-screen wake whose frame is
precomputed from the backend-salted digest.
"""

from __future__ import annotations

from repro.core.faults import FaultType
from repro.core.replica import LatencyModel, ReplicaResources
from repro.envs.base import BackendReplica, EnvBackend, RewardSpec


class MobileReplica(BackendReplica):
    """Device emulator restored from a CoW snapshot."""

    backend_name = "mobile"


class MobileBackend(EnvBackend):
    """Mobile device emulator (app / settings episodes)."""

    name = "mobile"
    description = "device emulator (UI events, app-crash/ANR fault mix)"
    replica_cls = MobileReplica
    reward_scale = 0.9
    est_cow_bytes = 128 << 20  # emulator snapshot delta

    # app crashes and ANR timeouts dominate
    fault_rates = {
        FaultType.CONNECTION: 0.006,
        FaultType.TIMEOUT: 0.015,  # ANR: activity not responding
        FaultType.RUNTIME: 0.010,
        FaultType.CRASH: 0.006,  # app crash
        FaultType.HANG: 0.004,
    }

    reward_defaults = {
        "mobile_app": RewardSpec(success_threshold=0.50, step_penalty=0.009),
        "mobile_settings": RewardSpec(success_threshold=0.60, step_penalty=0.006),
    }

    def latency(self) -> LatencyModel:
        return LatencyModel(
            boot_s=25.0,  # emulator cold boot — prewarming matters most here
            configure_s=4.0,  # app install
            reset_s=2.5,  # activity restart
            step_s=1.6,  # UI event round-trip
            evaluate_s=1.2,  # UI-state assertion
            sigma=0.40,
            hang_timeout_s=25.0,
            canary_s=0.30,
        )

    def resources(self) -> ReplicaResources:
        return ReplicaResources(
            ram_gb=3.0,
            ram_limit_gb=4.0,
            cpu_peak_cores=3.0,
            cpu_duty=0.35,
            cpu_idle_cores=0.2,
        )
