"""SimOS backend: the extracted oracle.

This is the original full-OS-in-a-VM environment (``SimOSReplica``)
repackaged behind the :class:`~repro.envs.base.EnvBackend` protocol. The
extraction is a pure re-plumbing — every hook returns ``None`` (keep the
replica's own calibrated defaults) and the factory forwards its arguments
verbatim, so a SimOS fleet built through the backend is **bit-identical**
to the pre-protocol stack: same RNG streams, same event order, same
committed benchmark baselines. ``tests/test_envs.py`` holds that line.

The per-family reward defaults that used to be duplicated as a dict
literal inside ``rollout/scenarios.py`` now live here (the backend is the
single source of truth); the scenario registry reads them via
``reward_spec``, which raises on an unknown family.
"""

from __future__ import annotations

from repro.envs.base import EnvBackend, RewardSpec
from repro.core.replica import SimOSReplica


class SimOSBackend(EnvBackend):
    """Full simulated OS sandbox with GUI (KVM-VM stand-in)."""

    name = "simos"
    description = "full OS VM with GUI apps (office/browser/terminal/...)"
    replica_cls = SimOSReplica
    # the fleet defaults *are* this backend's calibration: latency() and
    # resources() stay None so the factory path is byte-for-byte the old
    # direct SimOSReplica construction
    fault_rates = None
    reward_scale = 1.0
    est_cow_bytes = 64 << 20  # == cluster.host.EST_COW_PER_REPLICA_BYTES

    # Per-family reward shaping (previously the ``rewards`` dict literal
    # in ``default_registry``): step penalties track the family's step
    # cost (slow browser/image steps are expensive; terminal steps are
    # cheap), thresholds track how sharply the family's evaluator
    # separates success from failure, and the multi-app workflows give
    # more partial credit because partial completion is still useful.
    reward_defaults = {
        "office": RewardSpec(success_threshold=0.50, step_penalty=0.010),
        "browser": RewardSpec(success_threshold=0.45, step_penalty=0.020),
        "email": RewardSpec(success_threshold=0.50, step_penalty=0.010),
        "media": RewardSpec(success_threshold=0.40, step_penalty=0.008),
        "coding": RewardSpec(success_threshold=0.55, step_penalty=0.012),
        "image": RewardSpec(success_threshold=0.50, step_penalty=0.018),
        "terminal": RewardSpec(success_threshold=0.60, step_penalty=0.005),
        "multi_app": RewardSpec(
            success_threshold=0.35, step_penalty=0.008, partial_weight=0.40
        ),
    }
