"""SWE backend: repo-edit + test-run episodes, container-free.

Modeled on SWE-MiniSandbox (PAPERS.md): episodes run in a lightweight
process sandbox over a CoW repo worktree instead of a full VM, so the
resource profile is radically different from SimOS — ~1.5 GB RAM limit
per replica, an 8 MiB CoW delta, near-instant boot — and one host packs
several times more SWE replicas than OS VMs. Steps are edit/incremental-
test iterations; ``evaluate`` runs the full test suite and grades
**pass/fail**: the score is 1.0 or 0.0, nothing in between, and the
reward defaults give no partial credit.

The fault mix is test-infrastructure shaped: flaky tests (RUNTIME) and
suite timeouts dominate; VM-style crashes are rare because there is no
VM. The canary is the backend-salted known answer — a scripted no-op
checkout whose observation digest is precomputed — so the L3 quarantine
ladder works on SWE pools unchanged.
"""

from __future__ import annotations

import hashlib

from repro.core.faults import FaultType
from repro.core.replica import LatencyModel, ReplicaResources
from repro.envs.base import BackendReplica, EnvBackend, RewardSpec

# evaluate() passes iff the digest byte clears this bar (~37% pass rate
# for an untrained scripted policy — sparse but learnable signal)
PASS_BAR = 160


class SWEReplica(BackendReplica):
    """Process-sandbox replica over a CoW repo worktree."""

    backend_name = "swe"

    def evaluate(self) -> tuple[float, float]:
        """Full test-suite run: deterministic pass/fail, no partial score."""
        self._require_alive()
        h = hashlib.blake2b(
            f"swe/{self.task.get('task_id')}/{self.step_count}".encode(),
            digest_size=4,
        ).digest()
        score = 1.0 if h[0] >= PASS_BAR else 0.0
        return score, self._lat.sample(self.latency.evaluate_s)


class SWEBackend(EnvBackend):
    """Container-free SWE episodes (repo edit -> test run)."""

    name = "swe"
    description = "container-free repo-edit + test-run episodes (pass/fail)"
    replica_cls = SWEReplica
    reward_scale = 0.75  # sparse pass/fail bonuses run hot vs graded scores
    est_cow_bytes = 8 << 20  # worktree delta, not a VM disk

    # flaky tests and suite timeouts dominate; no VM to crash
    fault_rates = {
        FaultType.CONNECTION: 0.004,  # pip / git fetch
        FaultType.TIMEOUT: 0.012,  # suite deadline
        FaultType.RUNTIME: 0.022,  # flaky tests
        FaultType.CRASH: 0.001,
        FaultType.HANG: 0.002,
    }

    reward_defaults = {
        # pass/fail: threshold 1.0 and zero partial credit — a failing
        # suite earns nothing; efficiency bonus rewards small patches
        "swe_bugfix": RewardSpec(
            success_threshold=1.0,
            partial_weight=0.0,
            efficiency_bonus=0.30,
            step_penalty=0.004,
        ),
        "swe_feature": RewardSpec(
            success_threshold=1.0,
            partial_weight=0.0,
            efficiency_bonus=0.20,
            step_penalty=0.006,
        ),
    }

    def latency(self) -> LatencyModel:
        return LatencyModel(
            boot_s=1.8,  # process sandbox + warm venv, no VM boot
            configure_s=2.5,  # repo checkout + dependency cache hit
            reset_s=0.9,  # git clean to the base commit
            step_s=1.4,  # edit + incremental test
            evaluate_s=6.0,  # full suite run
            sigma=0.55,  # test runtimes are heavy-tailed
            hang_timeout_s=30.0,  # suites legitimately run long
            canary_s=0.12,
        )

    def resources(self) -> ReplicaResources:
        return ReplicaResources(
            ram_gb=1.0,
            ram_limit_gb=1.5,
            cpu_peak_cores=4.0,  # parallel test run bursts
            cpu_duty=0.5,
            cpu_idle_cores=0.05,
        )
