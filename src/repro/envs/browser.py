"""Browser backend: headless browser farm episodes.

A dedicated browser-automation environment (distinct from SimOS's
"browser app inside the OS VM" family): each replica is a headless
browser process with its own profile directory on the CoW store. Steps
are DOM actions, so they are fast; the fault mix is network-shaped —
connection failures and page-load timeouts dominate, with the occasional
tab crash. Resource demand sits between SWE sandboxes and OS VMs
(~2 GB RAM limit, 24 MiB profile delta), which is what makes the
heterogeneous bin-packing in ``cluster/placement.py`` non-trivial.

The canary replays a scripted about:blank navigation whose rendered
frame is precomputed from the backend-salted digest.
"""

from __future__ import annotations

from repro.core.faults import FaultType
from repro.core.replica import LatencyModel, ReplicaResources
from repro.envs.base import BackendReplica, EnvBackend, RewardSpec


class BrowserReplica(BackendReplica):
    """Headless browser process with a CoW-backed profile."""

    backend_name = "browser"


class BrowserBackend(EnvBackend):
    """Headless browser farm (navigation / form-filling episodes)."""

    name = "browser"
    description = "headless browser farm (DOM actions, network-bound faults)"
    replica_cls = BrowserReplica
    reward_scale = 1.0
    est_cow_bytes = 24 << 20  # profile dir + cache delta

    # network-shaped: connection errors and load timeouts dominate
    fault_rates = {
        FaultType.CONNECTION: 0.030,
        FaultType.TIMEOUT: 0.018,
        FaultType.RUNTIME: 0.008,
        FaultType.CRASH: 0.004,  # tab / renderer crash
        FaultType.HANG: 0.003,
    }

    reward_defaults = {
        "web_nav": RewardSpec(success_threshold=0.45, step_penalty=0.015),
        "web_form": RewardSpec(
            success_threshold=0.55, step_penalty=0.012, partial_weight=0.30
        ),
    }

    def latency(self) -> LatencyModel:
        return LatencyModel(
            boot_s=3.5,  # browser process + profile load
            configure_s=1.2,  # open the start URL
            reset_s=1.5,  # clear cookies, fresh tab
            step_s=0.9,  # DOM action
            evaluate_s=0.8,  # assert final DOM state
            sigma=0.50,  # network jitter
            hang_timeout_s=15.0,
            canary_s=0.10,
        )

    def resources(self) -> ReplicaResources:
        return ReplicaResources(
            ram_gb=1.6,
            ram_limit_gb=2.0,
            cpu_peak_cores=1.5,
            cpu_duty=0.3,
            cpu_idle_cores=0.05,
        )
