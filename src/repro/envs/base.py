"""The ``EnvBackend`` protocol: pluggable environment backends behind one
control plane.

OSGym's pitch is *general-purpose* computer-use infrastructure: the same
pools / gateway / recovery ladder / learner pipeline should serve any
environment family (cf. Gym-Anything's "turn any software into an agent
environment"). This module defines the contract a backend must satisfy so
every layer above the replica stays backend-agnostic:

- **Lifecycle** — ``make_replica`` returns a replica object implementing
  the SimOS lifecycle: ``boot() -> vs``, ``configure(task) -> vs``,
  ``reset() -> (obs, vs)``, ``step(action) -> (obs, r, done, info, vs)``,
  ``evaluate() -> (score, vs)``, ``close() -> vs``, ``crash()``, plus the
  ``alive`` / ``state`` / ``silent_broken`` / ``step_count`` attributes
  the state manager and recovery ladder read. Snapshots ride on the CoW
  disk layer (``replica.disk``), which every backend inherits.
- **Resources** — per-backend :class:`~repro.core.replica.ReplicaResources`
  (RAM/CPU envelope) and ``est_cow_bytes`` (CoW disk delta per replica),
  so placement can bin-pack heterogeneous demand onto hosts.
- **Latency / fault profile** — a calibrated
  :class:`~repro.core.replica.LatencyModel` and an optional fault-rate
  mix; ``None`` means "use the fleet default", which is how the SimOS
  backend stays bit-identical to the pre-protocol stack.
- **Rewards** — per-family :class:`RewardSpec` defaults live *on the
  backend* (single source of truth; the scenario registry reads them via
  :meth:`EnvBackend.reward_spec`, which raises on an unknown family
  instead of silently falling back), plus a ``reward_scale`` applied at
  ingest so one learner can consume the cross-domain mix without one
  backend's return magnitude dominating.
- **Canary** — a known-answer ``canary_probe`` contract: every backend's
  replica must reproduce a precomputed observation bit-for-bit when
  healthy, so the L3 quarantine layer detects silent corruption on any
  backend without backend-specific probes. Backends get *distinct* known
  answers via :func:`expected_backend_observation` (the backend name
  salts the digest), so a cross-wired probe cannot pass by accident.

``SimOSBackend`` (``repro.envs.simos``) is the extracted oracle; the
calibrated SWE / browser / mobile backends live beside it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.faults import FaultInjector, FaultType
from repro.core.replica import (
    LatencyModel,
    ReplicaResources,
    SimOSReplica,
    expected_observation,
)


@dataclass(frozen=True)
class RewardSpec:
    """Per-family shaping of the scenario outcome into RL rewards.

    ``evaluate()`` returns a raw score in [0, 1]; the spec turns it into
    the learner's objective: a success criterion (``success_threshold``),
    a terminal reward (success bonus + efficiency bonus for finishing
    under the horizon, or partial credit for near-misses), and a per-step
    penalty that prices each environment step so the policy is pushed
    toward short successful episodes — the grounding that makes scenario
    outcomes matter to training (cf. Gym-Anything). Defaults per family
    live on the owning :class:`EnvBackend`."""

    success_threshold: float = 0.5
    success_bonus: float = 1.0
    efficiency_bonus: float = 0.25  # scaled by unused fraction of horizon
    partial_weight: float = 0.25  # credit for sub-threshold scores
    step_penalty: float = 0.01

    def success(self, score: float) -> bool:
        return score >= self.success_threshold

    def terminal_reward(self, score: float, n_steps: int, horizon: int) -> float:
        if self.success(score):
            spare = max(horizon - n_steps, 0) / max(horizon, 1)
            return self.success_bonus + self.efficiency_bonus * spare
        return self.partial_weight * score

    def step_rewards(self, score: float, n_steps: int, horizon: int) -> np.ndarray:
        """Dense per-step reward vector: -step_penalty everywhere, with
        the shaped terminal reward added on the final step."""
        n = max(n_steps, 1)
        r = np.full(n, -self.step_penalty, np.float32)
        r[-1] += self.terminal_reward(score, n_steps, horizon)
        return r

    def episode_return(self, score: float, n_steps: int, horizon: int) -> float:
        return float(self.step_rewards(score, n_steps, horizon).sum())


class UnknownBackendError(KeyError):
    """Lookup of a backend name nobody registered."""


class UnknownFamilyError(KeyError):
    """Reward lookup for a scenario family the backend does not define.

    Raised instead of silently falling back to a generic spec: a family
    string with no reward table is a wiring bug, and training on default
    shaping would hide it."""


def expected_backend_observation(
    backend: str, replica_id: str, obs_nonce: int, step_count: int
) -> np.ndarray:
    """Known-answer observation for a non-SimOS backend's replica.

    Same Philox synthesis as :func:`~repro.core.replica.expected_observation`
    but the backend name salts the digest, so each backend has its own
    known answer: a probe wired to the wrong backend's reference fails
    loudly instead of passing by coincidence."""
    return expected_observation(f"{backend}::{replica_id}", obs_nonce, step_count)


class BackendReplica(SimOSReplica):
    """Base replica for non-SimOS backends.

    Reuses the SimOS machinery wholesale — CoW disk, fault sampling,
    deterministic latency streams, lifecycle states — and swaps in the
    backend-salted known answer, so the canary contract holds with a
    backend-specific reference. Subclasses override class attributes
    (``backend_name``) and, where the episode semantics differ,
    ``evaluate`` (e.g. SWE pass/fail)."""

    backend_name = "abstract"

    def _expected(self) -> np.ndarray:
        return expected_backend_observation(
            self.backend_name, self.replica_id, self.obs_nonce, self.step_count
        )


class EnvBackend:
    """A calibrated environment backend: descriptor + replica factory.

    Stateless by design — one instance can serve any number of pools.
    Subclasses set the class attributes and (optionally) override the
    latency/resources hooks; ``None`` from either hook means "keep the
    replica's own defaults", which is how :class:`SimOSBackend
    <repro.envs.simos.SimOSBackend>` stays bit-identical to the
    pre-protocol stack."""

    #: registry key; also stamped on tasks / pools / telemetry
    name = "abstract"
    #: one-line operator description (docs + health output)
    description = ""
    #: replica class the factory instantiates
    replica_cls: type = SimOSReplica
    #: per-family reward shaping (the scenario registry's source of truth)
    reward_defaults: dict[str, RewardSpec] = {}
    #: fault-rate mix for pools of this backend; None = fleet default
    fault_rates: Optional[dict[FaultType, float]] = None
    #: ingest-time scale on shaped rewards (cross-domain normalization)
    reward_scale: float = 1.0
    #: estimated CoW disk delta per replica (heterogeneous bin-packing)
    est_cow_bytes: int = 64 << 20

    # ------------------------------------------------------------ profiles
    def latency(self) -> Optional[LatencyModel]:
        """Calibrated latency bands; None keeps the replica default."""
        return None

    def resources(self) -> Optional[ReplicaResources]:
        """Per-replica RAM/CPU envelope; None keeps the replica default."""
        return None

    def ram_limit_gb(self) -> float:
        """Placement-visible RAM demand of one replica."""
        res = self.resources()
        return (res or ReplicaResources()).ram_limit_gb

    # ------------------------------------------------------------- factory
    def make_replica(
        self,
        replica_id: str,
        base_image,
        *,
        faults: Optional[FaultInjector] = None,
        seed: int = 0,
        latency: Optional[LatencyModel] = None,
    ):
        """Build one replica. An explicit ``latency`` (a fleet-wide
        calibration override) wins over the backend's own bands."""
        return self.replica_cls(
            replica_id,
            base_image,
            faults=faults,
            seed=seed,
            latency=latency if latency is not None else self.latency(),
            resources=self.resources(),
        )

    # ------------------------------------------------------------- rewards
    def families(self) -> list[str]:
        return list(self.reward_defaults)

    def reward_spec(self, family: str) -> RewardSpec:
        """The family's reward shaping; unknown families raise."""
        try:
            return self.reward_defaults[family]
        except KeyError:
            raise UnknownFamilyError(
                f"backend {self.name!r} has no reward defaults for family "
                f"{family!r} (known: {sorted(self.reward_defaults)})"
            ) from None

    # ------------------------------------------------------------- canary
    def expected_canary(
        self, replica_id: str, obs_nonce: int, step_count: int
    ) -> np.ndarray:
        """The known answer a healthy replica of this backend must
        produce — the reference the conformance suite checks the live
        ``canary_probe`` against."""
        if self.replica_cls is SimOSReplica:
            return expected_observation(replica_id, obs_nonce, step_count)
        return expected_backend_observation(
            self.name, replica_id, obs_nonce, step_count
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<EnvBackend {self.name}>"


# ------------------------------------------------------------------ registry
_BACKENDS: dict[str, EnvBackend] = {}


def register_backend(backend: EnvBackend) -> EnvBackend:
    """Register a backend instance under its name (idempotent per name
    only for the identical instance; a second distinct registration is a
    wiring bug and raises)."""
    existing = _BACKENDS.get(backend.name)
    if existing is not None and existing is not backend:
        raise ValueError(f"backend {backend.name!r} already registered")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> EnvBackend:
    """Look up a registered backend; unknown names raise."""
    # the built-ins self-register when the package initializes; importing
    # lazily here keeps `repro.envs.base` a leaf module (no cycle through
    # the backend modules, which subclass classes defined above)
    if not _BACKENDS:
        import repro.envs  # noqa: F401  (registers the built-ins)
    try:
        return _BACKENDS[name]
    except KeyError:
        raise UnknownBackendError(
            f"no EnvBackend named {name!r} (known: {sorted(_BACKENDS)})"
        ) from None


def backend_names() -> list[str]:
    if not _BACKENDS:
        import repro.envs  # noqa: F401

        assert _BACKENDS, "repro.envs import registered no backends"
    return sorted(_BACKENDS)
