"""Pluggable environment backends (``EnvBackend`` protocol).

One control plane, many calibrated environments: ``simos`` (the original
full-OS VM, bit-identical to the pre-protocol stack), ``swe``
(container-free repo-edit + test-run episodes), ``browser`` (headless
browser farm), and ``mobile`` (device emulator). See
``docs/ENVIRONMENTS.md`` for the protocol contract and calibration
tables."""

from repro.envs.base import (
    BackendReplica,
    EnvBackend,
    RewardSpec,
    UnknownBackendError,
    UnknownFamilyError,
    backend_names,
    expected_backend_observation,
    get_backend,
    register_backend,
)
from repro.envs.simos import SimOSBackend
from repro.envs.swe import SWEBackend, SWEReplica
from repro.envs.browser import BrowserBackend, BrowserReplica
from repro.envs.mobile import MobileBackend, MobileReplica

for _backend in (SimOSBackend(), SWEBackend(), BrowserBackend(), MobileBackend()):
    register_backend(_backend)
del _backend

__all__ = [
    "BackendReplica",
    "BrowserBackend",
    "BrowserReplica",
    "EnvBackend",
    "MobileBackend",
    "MobileReplica",
    "RewardSpec",
    "SWEBackend",
    "SWEReplica",
    "SimOSBackend",
    "UnknownBackendError",
    "UnknownFamilyError",
    "backend_names",
    "expected_backend_observation",
    "get_backend",
    "register_backend",
]
