"""Logical-axis sharding rules (MaxText-style).

Models annotate every parameter / activation dimension with a *logical* axis
name ("embed", "q_dim", "expert", "batch", ...). A rule table maps logical
names onto mesh axes; the engine drops mappings that don't divide the dim or
that would reuse a mesh axis twice in one PartitionSpec. This single
indirection gives DP/FSDP/TP/EP/SP layouts per (arch x shape) without touching
model code.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def pspec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
              mapping: dict[str, tuple[str, ...]],
              mesh: Optional[Mesh]) -> P:
    """Build a PartitionSpec for `shape` from logical axis names.

    Rules: (1) a mesh axis may appear at most once (first dim wins);
    (2) the product of mesh-axis sizes must divide the dim size — non-divisible
    mappings degrade by dropping trailing mesh axes, then to replication.
    """
    sizes = _axis_sizes(mesh) if mesh is not None else {}
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None and name in mapping:
            axes = [a for a in mapping[name] if a not in used and a in sizes]
            # degrade: drop trailing axes until the product divides the dim
            while axes:
                prod = 1
                for a in axes:
                    prod *= sizes[a]
                if prod and dim % prod == 0:
                    break
                axes = axes[:-1]
            if axes:
                assigned = tuple(axes) if len(axes) > 1 else axes[0]
                used.update(axes)
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@dataclass(frozen=True)
class AxisRules:
    """Logical->mesh mapping bound to a mesh (or unbound for single-device)."""

    mapping: dict[str, tuple[str, ...]] = field(default_factory=dict)
    mesh: Optional[Mesh] = None

    def pspec(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        return pspec_for(shape, logical, self.mapping, self.mesh)

    def sharding(self, shape, logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(shape, logical))

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical names; no-op when unbound."""
        if self.mesh is None:
            return x
        spec = self.pspec(x.shape, logical)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def with_overrides(self, **over: tuple[str, ...]) -> "AxisRules":
        m = dict(self.mapping)
        m.update(over)
        return replace(self, mapping=m)


def _dp_axes(mesh: Optional[Mesh]) -> tuple[str, ...]:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def train_rules(mesh: Optional[Mesh] = None, *, fsdp: bool = True,
                expert_parallel: bool = True,
                wide_fsdp: bool = False) -> AxisRules:
    """DP over (pod,data); FSDP params over data (or over pod+data with
    `wide_fsdp`, needed to fit the 300-400B configs); TP over model."""
    dp = _dp_axes(mesh)
    fs = (dp if wide_fsdp else ("data",)) if fsdp else ()
    mapping = {
        "batch": dp,
        "embed": fs,                      # FSDP shard of the d_model dim
        "q_dim": ("model",),
        "kv_dim": ("model",),
        "heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("data",) if expert_parallel else (),
        "expert_mlp": ("model",),
        "ssm_inner": ("model",),
        "seq": (),
        "frontend": fs,
        # MoE dispatch groups follow the token (batch) sharding
        "groups": ("data",),
        "capacity": (),
        # expert-parallel tensors: set by configure_moe() per config
        "moe_g": (),
        "expert_data": ("data",),
    }
    return AxisRules(mapping=mapping, mesh=mesh)


def configure_moe(rules: AxisRules, n_experts: int) -> AxisRules:
    """Per-config expert layout. When the expert count divides the model
    axis, experts live on 'model' (weights AND the expert dim of the
    dispatch activations stay aligned — no resharding, 16x less expert
    activation memory); the per-expert hidden takes 'data'. Otherwise
    (e.g. grok's 8 experts on a 16-wide axis) the expert dim is
    unshardable and the FSDP layout (embed:data, hidden:model) stands."""
    if rules.mesh is None:
        return rules
    sizes = _axis_sizes(rules.mesh)
    if n_experts % sizes.get("model", 1) == 0:
        return rules.with_overrides(
            expert=("model",),
            expert_mlp=rules.mapping.get("embed", ("data",)) or ("data",))
    return rules


def serve_rules(mesh: Optional[Mesh] = None, *, long_context: bool = False) -> AxisRules:
    """Decode/prefill: params TP over model + FSDP over data; cache sharded by
    batch (short contexts) or by sequence (long_context, batch=1 cells)."""
    dp = _dp_axes(mesh)
    mapping = {
        "batch": dp,
        "embed": ("data",),
        "q_dim": ("model",),
        "kv_dim": ("model",),
        "heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("data",),
        "expert_mlp": ("model",),
        "ssm_inner": ("model",),
        "seq": (),
        "frontend": ("data",),
        "groups": ("data",),
        "capacity": (),
        "moe_g": (),
        "expert_data": ("data",),
        # KV cache layout
        "cache_batch": dp,
        "cache_seq": ("data",) if long_context else (),
        "cache_kv": ("model",),
    }
    if long_context:
        mapping["cache_batch"] = ()
    return AxisRules(mapping=mapping, mesh=mesh)


def tree_pspecs(rules: AxisRules, shapes_tree, axes_tree):
    """Map (shapes, logical-axes) trees -> PartitionSpec tree.

    The axes tree mirrors the shapes tree but holds tuples of logical names as
    leaves, so the two trees have different pytree structures; flatten each
    with its own leaf predicate and zip.
    """
    leaves_s, treedef = jax.tree.flatten(shapes_tree)
    leaves_a = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
    if len(leaves_s) != len(leaves_a):
        raise ValueError(
            f"shape/axes tree mismatch: {len(leaves_s)} vs {len(leaves_a)}")
    specs = [rules.pspec(s.shape, a) for s, a in zip(leaves_s, leaves_a)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(rules: AxisRules, shapes_tree, axes_tree):
    specs = tree_pspecs(rules, shapes_tree, axes_tree)
    if rules.mesh is None:
        return specs
    return jax.tree.map(lambda p: NamedSharding(rules.mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))
