"""Distributed checkpointing with CoW block dedup + elastic resharding.

The paper's reflink insight applied to the training plane: checkpoints are
chunked and content-addressed in a ``BlobStore``, so consecutive snapshots
share every unchanged block (optimizer moments change every step, but
embeddings / frozen towers / ints dedup across steps, and identical replicas
across branches cost nothing). Restore is *elastic*: arrays are re-placed
with the shardings of whatever mesh the job restarts on (node loss, pod
resize), independent of the mesh that saved them.

No orbax/tensorstore in this environment — manifests are JSON, payloads are
raw little-endian numpy bytes.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cow_store import BlobStore


# ------------------------------------------------------------- (de)flatten
def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _leaf_bytes(x) -> tuple[bytes, dict]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        payload = arr.view(np.uint16).tobytes()
        meta = {"dtype": "bfloat16", "shape": list(arr.shape)}
    else:
        payload = arr.tobytes()
        meta = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    return payload, meta


def _bytes_leaf(payload: bytes, meta: dict) -> np.ndarray:
    shape = tuple(meta["shape"])
    if meta["dtype"] == "bfloat16":
        arr = np.frombuffer(payload, np.uint16).reshape(shape)
        return jnp.asarray(arr.view(jnp.bfloat16))
    return np.frombuffer(payload, np.dtype(meta["dtype"])).reshape(shape)


class CheckpointManager:
    """Save/restore pytrees with block dedup and elastic restore."""

    def __init__(self, directory: Optional[str] = None,
                 blob_store: Optional[BlobStore] = None,
                 keep: int = 3):
        self.dir = directory
        if directory:
            os.makedirs(directory, exist_ok=True)
        self.blobs = blob_store or BlobStore()
        self.keep = keep
        self._lock = threading.Lock()
        self._steps: list[int] = []

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, name: str = "state") -> dict:
        leaves = _flatten_with_paths(tree)
        manifest = {"step": step, "name": name, "leaves": {}}
        physical_before = self.blobs.store.physical_bytes()
        logical = 0
        for key, leaf in leaves.items():
            payload, meta = _leaf_bytes(leaf)
            logical += len(payload)
            info = self.blobs.put(f"{name}@{step}/{key}", payload)
            manifest["leaves"][key] = {**meta, "n_chunks": info["n_chunks"]}
        with self._lock:
            self._steps.append(step)
            self._steps.sort()
            while len(self._steps) > self.keep:
                old = self._steps.pop(0)
                self._drop(old, name)
        stats = {
            "step": step,
            "logical_bytes": logical,
            "physical_bytes_total": self.blobs.store.physical_bytes(),
            "new_physical_bytes": (self.blobs.store.physical_bytes()
                                   - physical_before),
        }
        if self.dir:
            with open(os.path.join(self.dir, f"{name}-{step}.json"),
                      "w") as f:
                json.dump({**manifest, "stats": stats}, f)
        self._last_manifest = manifest
        return stats

    def _drop(self, step: int, name: str) -> None:
        prefix = f"{name}@{step}/"
        for key in self.blobs.keys():
            if key.startswith(prefix):
                self.blobs.delete(key)

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        with self._lock:
            return self._steps[-1] if self._steps else None

    def restore(self, step: int, like: Any, name: str = "state",
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`. If `shardings` (a matching
        tree of NamedSharding / None) is given, leaves are placed onto that
        mesh — elastic restore onto a different topology."""
        leaves_like = _flatten_with_paths(like)
        flat_shard = (_flatten_with_paths(shardings)
                      if shardings is not None else {})
        out = {}
        for key, leaf in leaves_like.items():
            payload = self.blobs.get(f"{name}@{step}/{key}")
            meta = {"dtype": str(np.asarray(leaf).dtype)
                    if leaf.dtype != jnp.bfloat16 else "bfloat16",
                    "shape": list(leaf.shape)}
            arr = _bytes_leaf(payload, meta)
            sh = flat_shard.get(key)
            out[key] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
        # unflatten into like's structure
        flat, treedef = jax.tree.flatten(like)
        keys = list(_flatten_with_paths(like).keys())
        ordered = [out[k] for k in keys]
        return jax.tree.unflatten(treedef, ordered)

    def dedup_ratio(self) -> float:
        """physical / logical across everything currently retained."""
        logical = sum(len(self.blobs.get(k)) for k in self.blobs.keys())
        phys = self.blobs.store.physical_bytes()
        return phys / max(logical, 1)
