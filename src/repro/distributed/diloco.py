"""DiLoCo-style semi-synchronous multi-pod training (arXiv:2311.08105),
the pod-scale analogue of the paper's decoupled rollout/update pipeline.

Each pod runs H inner steps with gradients reduced only over its intra-pod
axes; every H steps the pods exchange parameter *deltas* (optionally int8-
compressed) and apply an outer Nesterov-momentum update. Cross-pod collective
bytes drop by ~H x relative to per-step all-reduce — measured in §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.collectives import compress_roundtrip


@dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 50          # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    nesterov: bool = True
    compress_int8: bool = True


def init_outer_state(params):
    return {
        "anchor": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def outer_sync(params, outer_state, cfg: DiLoCoConfig, *,
               pod_axis: Optional[str] = None):
    """One outer step. Under pjit on the multi-pod mesh this lowers to the
    only cross-pod collective of the whole cycle (params delta mean).

    delta  = anchor - pod_params          (per pod)
    delta  = mean_over_pods(delta)        [int8-compressed on the wire]
    m      = mu*m + delta
    anchor = anchor - outer_lr * (delta + mu*m  if nesterov else m)

    Pass pod_axis when calling inside shard_map over the pod mesh axis
    (per-pod divergent params); under plain pjit with pod-replicated params
    the mean is a no-op and GSPMD inserts the cross-pod broadcast itself.
    """
    anchor, mom = outer_state["anchor"], outer_state["momentum"]

    def one(a, p, m):
        delta = a - p.astype(jnp.float32)
        if cfg.compress_int8:
            delta = compress_roundtrip(delta)
        if pod_axis is not None:
            delta = jax.lax.pmean(delta, pod_axis)
        m_new = cfg.outer_momentum * m + delta
        step_dir = (delta + cfg.outer_momentum * m_new
                    if cfg.nesterov else m_new)
        a_new = a - cfg.outer_lr * step_dir
        return a_new, m_new

    flat_a, tdef = jax.tree.flatten(anchor)
    outs = [one(a, p, m) for a, p, m in zip(
        flat_a, jax.tree.leaves(params), jax.tree.leaves(mom))]
    new_anchor = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_mom = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_params = jax.tree.map(
        lambda a, p: a.astype(p.dtype), new_anchor, params)
    return new_params, {"anchor": new_anchor, "momentum": new_mom}


def param_count(params) -> int:
    """Total elements in a parameter tree — the ``n_params`` that the
    byte accounting below (and the federation's metered WAN links) use."""
    return sum(int(p.size) for p in jax.tree.leaves(params))


def cross_pod_bytes_per_cycle(n_params: int, cfg: DiLoCoConfig) -> dict:
    """Collective-bytes accounting: per-step all-reduce vs DiLoCo cycle."""
    per_step_allreduce = 2 * n_params * 2           # ring, bf16
    diloco = n_params * (1 if cfg.compress_int8 else 4)
    return {
        "baseline_bytes_per_H_steps": per_step_allreduce * cfg.inner_steps,
        "diloco_bytes_per_H_steps": diloco,
        "reduction_x": per_step_allreduce * cfg.inner_steps / diloco,
    }
