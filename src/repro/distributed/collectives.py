"""Distributed-optimization collectives: int8 gradient compression with
error feedback, and quantized all-reduce building blocks.

At multi-pod scale the pod-axis all-reduce rides the slow inter-pod links,
so we compress there: per-block max-scaled int8 quantization (4x fewer bytes
than bf16 all-gather-based reduction, 8x vs f32), with the quantization
residual fed back into the next step (error feedback keeps SGD convergence;
Karimireddy et al., arXiv:1901.09847). Inside a pod gradients stay exact.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 2048  # quantization block (per-block scales)


def quantize_int8(x: jax.Array, block: int = BLOCK):
    """Per-block symmetric int8 quantization. Returns (q, scales, pad)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q: jax.Array, scale: jax.Array, pad: int, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compress_roundtrip(x: jax.Array) -> jax.Array:
    """Quantize-dequantize (what the wire sees); used for error feedback."""
    q, s, pad = quantize_int8(x)
    return dequantize_int8(q, s, pad, x.shape, jnp.float32)


def make_error_feedback_compressor():
    """Returns (init_state(grads), compress(grads, ef_state)).

    compress applies int8 round-trip per leaf and carries the residual:
        g_hat = Q(g + e);  e' = (g + e) - g_hat
    """

    def init_state(grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(grads, ef_state):
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            g_hat = compress_roundtrip(corrected)
            return g_hat.astype(g.dtype), corrected - g_hat
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef_state)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))

    return init_state, compress


def quantized_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce via int8 all-gather + local sum (inside shard_map).

    Wire bytes: n int8 per device vs 2n bf16 for ring all-reduce — the
    baseline-vs-compressed collective-bytes comparison in §Perf."""
    q, s, pad = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name)          # (n_dev, blocks, BLOCK) i8
    sg = jax.lax.all_gather(s, axis_name)
    parts = qg.astype(jnp.float32) * sg
    total = jnp.sum(parts, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape)
