"""Expert-parallel MoE via shard_map all-to-all (the §Perf alternative).

The baseline MoE keeps activations token-sharded and lets GSPMD gather the
FSDP-sharded expert weights to the tokens — wire bytes scale with *weight*
size (for grok-1, 550 GB of expert weights x 3 passes x microbatches).
This variant keeps expert weights stationary and moves *tokens* through
lax.all_to_all inside shard_map: wire bytes scale with activation size,
~100x smaller at 300B scale.

Layouts (data axis of width R):
  - E >= R (deepseek 64e, jamba 16e): each row owns E/R experts.
  - E <  R (grok 8e): each expert's FFN hidden dim is split across
    fs = R/E consecutive rows (`MoEConfig.ep_fsplit`); tokens are sent to
    all fs rows of their expert and the partial outputs are psum'd within
    the slice group.
The expert hidden dim additionally rides the tensor-parallel (model) axis.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.common import activation
from repro.models.moe import route, capacity
from repro.models.mlp import mlp_apply


def moe_apply_ep(p: dict, cfg: ModelConfig, x: jax.Array, rules,
                 data_axis: str = "data"):
    """x: (B, S, D) -> (y, aux). Requires rules.mesh with `data_axis`."""
    mesh = rules.mesh
    assert mesh is not None
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    R = dict(zip(mesh.axis_names, mesh.devices.shape))[data_axis]
    fs = m.ep_fsplit                       # storage layout is authoritative
    assert (fs == 1 and E % R == 0) or (fs > 1 and E * fs == R), \
        f"EP layout needs E%R==0 or E*fs==R (E={E}, fs={fs}, R={R})"
    epr = (E * fs) // R                    # (expert, slice) pairs per row
    B, S, D = x.shape
    act = activation(cfg.act)

    # per-row token count and capacity (only the data axis shards
    # tokens inside this shard_map)
    T_loc = (B // R) * S
    C = capacity(T_loc, k, E, m.capacity_factor)

    def fn(x_loc, router, w_in, w_out, *w_gate):
        # x_loc: (B/R, S, D) — replicated over the model axis
        wg = w_gate[0] if w_gate else None
        Tl, _ = x_loc.reshape(-1, D).shape
        xt = x_loc.reshape(Tl, D)
        logits = xt.astype(jnp.float32) @ router            # (Tl, E)
        probs, gate_vals, de, dc = route(logits[None], E, k, C)
        gate_vals, de, dc = gate_vals[0], de[0], dc[0]      # strip group dim
        e_idx = jnp.argmax(de, axis=-1)                     # (Tl, k)
        slot = jnp.argmax(dc, axis=-1)                      # (Tl, k)
        kept = dc.max(axis=-1) > 0                          # (Tl, k)

        # ---- build send buffers (E, C, ...) with per-device scatters
        flat_e = e_idx.reshape(-1)
        flat_s = jnp.where(kept.reshape(-1), slot.reshape(-1), C)  # C = drop
        tok_of = jnp.tile(jnp.arange(Tl)[:, None], (1, k)).reshape(-1)
        send = jnp.zeros((E, C + 1, D), xt.dtype).at[flat_e, flat_s].set(
            xt[tok_of], mode="drop")[:, :C]                 # (E, C, D)

        # ---- all_to_all (tiled): tokens to their expert's row(s).
        # Sender row-major layout: row (dest*epr + j) goes to dest; receiver
        # sees recv[src*epr + j] = src's buffer for my j-th local expert.
        if fs > 1:
            send_rows = jnp.repeat(send, fs, axis=0)        # (R, C, D)
        else:
            send_rows = send                                # (R*epr, C, D)
        recv = jax.lax.all_to_all(send_rows, data_axis, 0, 0, tiled=True)

        if fs > 1:
            xin = recv.reshape(R * C, D)                    # my slice's tokens
            h = act(xin @ w_in[0])                          # (R*C, F/fs/TP)
            if wg is not None:
                h = h * (xin @ wg[0])
            y = h @ w_out[0]                                # partial over F
            y = jax.lax.psum(y, "model")
            groups = [list(range(g * fs, (g + 1) * fs))
                      for g in range(R // fs)]
            y = jax.lax.psum(y, data_axis, axis_index_groups=groups)
            y_rows = y.reshape(R, C, D)
        else:
            xin = (recv.reshape(R, epr, C, D)
                   .transpose(1, 0, 2, 3).reshape(epr, R * C, D))
            h = act(jnp.einsum("erd,edf->erf", xin, w_in))
            if wg is not None:
                h = h * jnp.einsum("erd,edf->erf", xin, wg)
            y = jnp.einsum("erf,efd->erd", h, w_out)
            y = jax.lax.psum(y, "model")
            y_rows = (y.reshape(epr, R, C, D)
                      .transpose(1, 0, 2, 3).reshape(R * epr, C, D))

        # ---- return trip (same layout backwards)
        back = jax.lax.all_to_all(y_rows, data_axis, 0, 0, tiled=True)
        if fs > 1:
            y_exp = back[::fs]                              # (E, C, D)
        else:
            y_exp = back[:E]                                # (E, C, D)
        # gather each token's k outputs and combine
        y_exp = jnp.concatenate(
            [y_exp, jnp.zeros((E, 1, D), y_exp.dtype)], axis=1)
        gathered = y_exp[flat_e, flat_s]                    # (Tl*k, D)
        w = (gate_vals * kept).reshape(-1, 1).astype(gathered.dtype)
        y_tok = jnp.sum((gathered * w).reshape(Tl, k, D), axis=1)

        # aux load-balance (local estimate, averaged over rows)
        frac_tokens = jnp.mean(
            jnp.sum(de * kept[..., None].astype(jnp.float32), axis=1), axis=0)
        frac_probs = jnp.mean(probs[0], axis=0)
        aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight
        aux = jax.lax.pmean(aux, data_axis)
        return y_tok.reshape(x_loc.shape), aux

    # param specs: router replicated; expert weights expert-sharded over data
    # + hidden over model (matching the EP storage layout)
    w_in_spec = P(data_axis, None, "model")
    w_out_spec = P(data_axis, "model", None)
    in_specs = [P(data_axis, None, None), P(None, None), w_in_spec,
                w_out_spec]
    if cfg.gated_mlp:
        in_specs.append(w_in_spec)
    args = [x, p["router"], p["w_in"], p["w_out"]]
    if cfg.gated_mlp:
        args.append(p["w_gate"])

    try:
        mapped = shard_map(
            fn, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=(P(data_axis, None, None), P()),
            check_rep=False)
    except TypeError:  # newer jax renamed the kwarg
        mapped = shard_map(
            fn, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=(P(data_axis, None, None), P()),
            check_vma=False)
    y, aux = mapped(*args)
    if m.n_shared:
        y = y + mlp_apply(p["shared"], cfg, x)
    return y, aux.astype(jnp.float32)
