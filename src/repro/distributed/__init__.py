from repro.distributed.sharding import AxisRules, train_rules, serve_rules, pspec_for
