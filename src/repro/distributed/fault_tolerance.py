"""Training-plane fault tolerance: checkpoint/restart loop, preemption
drills, elastic mesh resizing, straggler-tolerant rollout collection.

The environment plane already tolerates replica faults (state managers,
pool reassignment); this module makes the *training job* survive node loss:
every N steps the full (params, opt_state, step) tree snapshots into the
dedup checkpoint store; on restart — possibly with a different device count —
arrays are re-placed with the new mesh's shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional


from repro.distributed.checkpoint import CheckpointManager


@dataclass
class FaultToleranceConfig:
    checkpoint_every: int = 50
    max_failures: int = 10


class ResilientTrainLoop:
    """Run a jitted train_step under simulated preemptions.

    ``preempt_hook(step) -> bool`` injects a failure; the loop restores the
    latest checkpoint and continues, counting lost steps (the re-execution
    cost between the last snapshot and the failure point).
    """

    def __init__(self, train_step: Callable, ckpt: CheckpointManager,
                 cfg: Optional[FaultToleranceConfig] = None,
                 preempt_hook: Optional[Callable[[int], bool]] = None):
        self.train_step = train_step
        self.ckpt = ckpt
        self.cfg = cfg or FaultToleranceConfig()
        self.preempt_hook = preempt_hook
        self.failures = 0
        self.lost_steps = 0
        self.history: list[dict] = []

    def run(self, params, opt_state, batches, *, start_step: int = 0,
            shardings: Any = None):
        step = start_step
        state = {"params": params, "opt": opt_state}
        self.ckpt.save(step, state)
        last_saved = step
        i = 0
        n = len(batches)
        while i < n:
            if self.preempt_hook and self.preempt_hook(step):
                # ---- simulated node loss: restore & replay
                self.failures += 1
                if self.failures > self.cfg.max_failures:
                    raise RuntimeError("too many failures")
                restore_step = self.ckpt.latest_step()
                state = self.ckpt.restore(restore_step, state,
                                          shardings=shardings)
                self.lost_steps += step - restore_step
                i -= step - restore_step
                step = restore_step
                continue
            p, o, metrics = self.train_step(state["params"], state["opt"],
                                            batches[i])
            state = {"params": p, "opt": o}
            step += 1
            i += 1
            self.history.append({"step": step,
                                 "loss": float(metrics["loss"])})
            if step - last_saved >= self.cfg.checkpoint_every:
                self.ckpt.save(step, state)
                last_saved = step
        self.ckpt.save(step, state)
        return state["params"], state["opt"], {
            "final_step": step, "failures": self.failures,
            "lost_steps": self.lost_steps}


def straggler_stats(latencies: list[float], deadline: float) -> dict:
    """Rollout straggler accounting: the data server's timeout-reclaim means
    a batch waits for the deadline, not the slowest replica."""
    done = [x for x in latencies if x <= deadline]
    return {
        "n": len(latencies),
        "stragglers": len(latencies) - len(done),
        "batch_latency_with_reclaim": min(deadline, max(latencies))
        if latencies else 0.0,
        "batch_latency_without": max(latencies) if latencies else 0.0,
    }
