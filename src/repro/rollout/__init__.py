"""Asynchronous trajectory-generation subsystem (§4 pipeline).

``RolloutEngine`` schedules bounded concurrent multi-turn episodes over the
gateway/runner-pool stack, ``ScenarioRegistry`` supplies diverse registered
workload families, and ``TrajectoryWriter`` streams completed episodes into
the SFT/PPO data pipeline."""
from repro.rollout.engine import (EpisodeResult, RolloutConfig, RolloutEngine,
                                  RolloutReport)
from repro.rollout.scenarios import (RewardSpec, Scenario, ScenarioProfile,
                                     ScenarioRegistry, default_registry,
                                     get_default_registry, mixed_registry)
from repro.rollout.writer import (TrajectoryWriter, VirtualWriterGate,
                                  WriterStats)
