"""Asynchronous trajectory-generation engine (§4, Figure 6 pipeline).

Drives many concurrent multi-turn episodes over the ``Gateway`` /
``RunnerPool`` stack:

- **bounded in-flight scheduler** — at most ``max_inflight`` episodes hold
  worker slots at once; submission beyond that blocks the feeder, never
  the workers;
- **backpressure** — before launching an episode the scheduler waits while
  the ``TrajectoryWriter`` backlog is at its high-water mark, so a slow
  consumer (encoder / replay buffer / learner) throttles generation
  instead of ballooning memory;
- **retry-with-failover** — an episode aborted by the fault machinery
  (``TaskAborted``: crash/hang, or retry exhaustion) is re-dispatched to a
  *different* node (the aborting node is excluded from the next attempt's
  affinity order) up to ``max_attempts`` times; the broken runner goes back
  to its pool, which recovers it autonomously.

Episodes follow the paper's unified four-phase task flow: configure →
reset → operate (policy loop) → evaluate.

Two execution modes share these semantics:

- ``run`` — thread-per-episode. Real concurrency, bounded by what one
  machine can thread (``max_inflight`` ≈ 16-64).
- ``run_event_driven`` — episodes are cooperative tasks on a
  ``repro.core.event_loop.EventLoop``; latencies advance a virtual clock
  instead of blocking threads, so *thousands* of episodes run concurrently
  on one core with identical semantics (bounded in-flight, writer
  backpressure via ``VirtualWriterGate``, failover-with-exclusion). This
  is how the paper-scale 1024-replica fleets execute end-to-end.

Event mode optionally serves a **multi-tenant job stream**: pass
``scheduler=FairShareScheduler(...)`` (``repro.tenancy``) and the feeder
routes every arriving task through admission control (explicit
admitted/throttled/rejected verdicts) into per-tenant queues, while a
dispatcher task launches episodes in weighted deficit-round-robin order
whenever worker slots free up. Tenant-tagged tasks thread their tenant id
down into the gateway's acquire-wait telemetry, so per-tenant latency
series exist end to end.

Determinism contract: event-mode runs are bit-identical per (fleet,
seed, task stream) in any process — the virtual clock, the fault
streams, the scheduler's admission verdicts and DRR interleavings, and
every report field replay exactly. Wall-clock fields
(``wall_seconds``) are the only machine-dependent outputs.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core.event_loop import Condition as VirtualCondition
from repro.core.event_loop import EventLoop, Sleep
from repro.core.faults import FaultType
from repro.core.gateway import Gateway
from repro.core.state_manager import TaskAborted
from repro.core.tasks import TaskSpec
from repro.core.telemetry import Telemetry
from repro.data.pipeline import Trajectory, TrajectoryStep
from repro.rollout.scenarios import Scenario, ScenarioRegistry, \
    get_default_registry
from repro.rollout.writer import TrajectoryWriter, VirtualWriterGate


@dataclass
class RolloutConfig:
    max_inflight: int = 16          # bounded worker slots
    max_attempts: int = 4           # episode tries incl. first (failover)
    acquire_timeout_s: float = 5.0  # wait for a free runner per attempt
    # event mode's acquire deadline is *virtual* seconds: episodes hold
    # runners for whole virtual episodes (~40 vs), and waiting is free on a
    # virtual clock, so the guard is generous — it only exists to surface a
    # genuinely dead fleet instead of wedging the loop
    acquire_timeout_vs: float = 600.0
    backpressure_poll_s: float = 0.01
    max_steps: Optional[int] = None  # safety cap above task horizon
    # per-operation dispatch cost in virtual seconds — prices a manager
    # design (see state_manager.design_dispatch_overhead) into the live
    # engine without touching the replica latency model
    op_overhead: Optional[Callable[[], float]] = None
    # event mode: virtual seconds the modeled consumer spends per
    # trajectory (see VirtualWriterGate)
    writer_consume_vs: float = 0.02
    # event mode: stop *launching* new episodes once the virtual clock
    # passes this deadline (in-flight episodes still finish). The online
    # actor/learner pipeline uses it to pace actor rounds in virtual time
    # instead of by a fixed task count.
    virtual_deadline_s: Optional[float] = None


@dataclass
class EpisodeResult:
    task: dict
    ok: bool
    steps: int = 0
    score: float = 0.0
    attempts: int = 1
    nodes: tuple = ()
    virtual_seconds: float = 0.0
    error: str = ""
    # silent-failure audit trail (§3.4): True when any step of the
    # successful attempt reported silent_corruption — the observation
    # stream is garbage even though every call "succeeded"
    corrupted: bool = False
    runner_id: str = ""          # runner that served the successful attempt


@dataclass
class RolloutReport:
    completed: int = 0
    failed: int = 0
    total_steps: int = 0
    reassignments: int = 0
    peak_inflight: int = 0
    backpressure_waits: int = 0
    virtual_seconds: float = 0.0    # summed per-episode env time
    virtual_makespan: float = 0.0   # event mode: fleet clock at completion
    wall_seconds: float = 0.0
    corrupted: int = 0              # trajectories written with corrupt obs
    # event mode: (runner_id, write_vt) per corrupted trajectory — the
    # recovery benchmark audits these against the ladder's quarantine
    # times (nothing may be written *after* its runner was quarantined)
    corrupted_writes: list = field(default_factory=list)
    results: list[EpisodeResult] = field(default_factory=list)

    def trajectories_per_min(self, n_replicas: int) -> float:
        """Virtual-time throughput projection: ``n_replicas`` lanes running
        episodes back-to-back yield completed trajectories at the observed
        completions-per-lane-second rate (failed episodes consume time but
        produce nothing)."""
        if not self.completed or self.virtual_seconds <= 0:
            return 0.0
        return n_replicas * 60.0 * self.completed / self.virtual_seconds


class RolloutEngine:
    """Bounded asynchronous scheduler for multi-turn episode generation."""

    def __init__(self, gateway, writer: TrajectoryWriter, *,
                 registry: Optional[ScenarioRegistry] = None,
                 config: Optional[RolloutConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        # ``gateway`` may be a bare Gateway or a repro.cluster.Cluster —
        # with a cluster, event-driven runs bind the whole control plane
        # (autoscaler daemon, contention gauges, replica-day clock) to
        # the loop, not just the gateway
        self.cluster = None
        if not isinstance(gateway, Gateway):
            self.cluster = gateway
            gateway = gateway.gateway
        self.gateway: Gateway = gateway
        self.writer = writer
        self.registry = registry or get_default_registry()
        self.config = config or RolloutConfig()
        self.telemetry = telemetry or Telemetry()
        self._inflight = 0
        self._lock = threading.Lock()
        self._report = RolloutReport()
        self._stop = threading.Event()
        self._loop: Optional[EventLoop] = None   # set during event runs
        self._scheduler = None                   # set during tenant runs

    # ---------------------------------------------------------------- public
    def run(self, tasks: Sequence) -> RolloutReport:
        """Generate one trajectory per task; returns when all are settled.

        ``tasks`` may be ``TaskSpec`` objects or plain dicts
        (``TaskSpec.to_dict`` shape)."""
        cfg = self.config
        self._report = RolloutReport()
        self._stop.clear()
        t0 = time.monotonic()
        task_dicts = [t.to_dict() if isinstance(t, TaskSpec) else dict(t)
                      for t in tasks]
        with ThreadPoolExecutor(max_workers=cfg.max_inflight,
                                thread_name_prefix="rollout") as ex:
            futs = []
            for task in task_dicts:
                self._throttle()
                if self._stop.is_set():
                    break
                # claim the slot feeder-side so the in-flight bound and the
                # writer-saturation gate apply to *launches*, not to whenever
                # the executor happens to start the episode
                self._enter()
                futs.append(ex.submit(self._episode_with_failover, task))
            for f in futs:
                f.result()      # episode errors are captured, not raised
        self._report.wall_seconds = time.monotonic() - t0
        return self._report

    def stop(self) -> None:
        """Ask the feeder to stop launching new episodes."""
        self._stop.set()

    @property
    def stats(self) -> RolloutReport:
        return self._report

    # ------------------------------------------------------------- scheduling
    def _throttle(self) -> None:
        """Backpressure: hold the feeder while the writer backlog is high
        or every worker slot is busy."""
        cfg = self.config
        waited = False
        while not self._stop.is_set():
            with self._lock:
                slots_free = self._inflight < cfg.max_inflight
            if slots_free and not self.writer.saturated():
                break
            if not waited:
                waited = True
                with self._lock:
                    self._report.backpressure_waits += 1
                self.telemetry.count("backpressure_waits")
            time.sleep(cfg.backpressure_poll_s)

    def _enter(self) -> None:
        with self._lock:
            self._inflight += 1
            self._report.peak_inflight = max(self._report.peak_inflight,
                                             self._inflight)

    def _exit(self) -> None:
        with self._lock:
            self._inflight -= 1

    # --------------------------------------------------------------- episodes
    def _episode_with_failover(self, task: dict) -> EpisodeResult:
        cfg = self.config
        # the feeder already claimed this episode's slot via _enter()
        result = EpisodeResult(task=task, ok=False)
        excluded: set[str] = set()
        traj = None
        try:
            scenario = self.registry.resolve(task)
            for attempt in range(cfg.max_attempts):
                result.attempts = attempt + 1
                backend = task.get("backend")
                got = self.gateway.acquire(
                    task["task_id"], timeout=cfg.acquire_timeout_s,
                    exclude=excluded, backend=backend)
                if got is None and excluded:
                    # every other node is busy/unhealthy: fall back to the
                    # full fleet rather than deadlocking on exclusions
                    # (backend-constrained routing still applies)
                    excluded.clear()
                    got = self.gateway.acquire(
                        task["task_id"], timeout=cfg.acquire_timeout_s,
                        backend=backend)
                if got is None:
                    result.error = f"no runner available ({task['task_id']})"
                    break
                node, runner = got
                result.nodes += (node,)
                try:
                    traj, steps, score, vs = self._attempt(
                        task, scenario, runner,
                        scale=self.gateway.pools[node].latency_scale,
                        result=result)
                    result.ok = True
                    result.steps = steps
                    result.score = score
                    result.virtual_seconds += vs
                    result.runner_id = runner.runner_id
                    break
                except TaskAborted as e:
                    result.virtual_seconds += e.virtual_seconds
                    result.error = str(e)
                    excluded.add(node)
                    with self._lock:
                        self._report.reassignments += 1
                    self.telemetry.count("task_reassignments")
                    if e.fault is FaultType.PREEMPT:
                        self.telemetry.count("preemptions")
                finally:
                    # pool recycles (and autonomously recovers) the runner;
                    # task_id guards against releasing a runner that leak
                    # reclamation already took back and re-issued
                    self.gateway.release(node, runner,
                                         task_id=task["task_id"])
            if traj is not None:
                # runner already released: a blocking write under
                # backpressure must not idle fleet capacity
                self.writer.write(traj)
                self.telemetry.count("episodes_completed")
                if result.corrupted:
                    self.telemetry.count("corrupted_trajectories")
            return result
        except Exception as e:   # keep one bad episode from sinking the run
            result.error = f"{type(e).__name__}: {e}"
            return result
        finally:
            self._exit()
            self._settle(result)

    def _attempt(self, task: dict, scenario: Scenario, runner, *,
                 scale: Callable[[], float] = None, result=None
                 ) -> tuple[Trajectory, int, float, float]:
        """One full configure → reset → operate → evaluate pass.

        ``scale`` is the pool's live CPU-contention factor (>= 1.0):
        every replica operation's virtual latency is multiplied by it,
        so overcommitted hosts stretch episodes in virtual time."""
        cfg = self.config
        oh = cfg.op_overhead or _zero_overhead
        sc = scale or _unit_scale
        mgr = runner.manager
        vs = 0.0
        if result is not None:
            result.corrupted = False    # per-attempt: a clean failover
            #                             retry clears a poisoned attempt
        try:
            vs = mgr.configure(task) * sc() + oh()
            obs, dur = mgr.reset()
            vs += dur * sc() + oh()
            steps: list[TrajectoryStep] = []
            horizon = int(task.get("horizon", 15))
            cap = cfg.max_steps or horizon * 2
            done = False
            while not done and len(steps) < cap:
                thought, action = scenario.policy(obs, len(steps))
                obs, _rew, done, info, dur = mgr.step(action)
                dur = dur * sc() + oh()
                vs += dur
                if info.get("silent_corruption") and result is not None:
                    result.corrupted = True
                steps.append(TrajectoryStep(obs, thought, action))
                self.telemetry.count("steps")
                self.telemetry.observe("step_latency_vs", dur)
            score, dur = mgr.evaluate()
            vs += dur * sc() + oh()
        except TaskAborted as e:
            # charge the attempt's configure/reset and completed steps, not
            # just the aborting step — the throughput projection depends on
            # honest per-episode virtual time under faults
            e.virtual_seconds += vs
            raise
        traj = Trajectory(task["task_id"], task["description"], steps, score,
                          task=task)
        return traj, len(steps), score, vs

    def _settle(self, result: EpisodeResult) -> None:
        with self._lock:
            rep = self._report
            rep.results.append(result)
            rep.virtual_seconds += result.virtual_seconds
            if result.ok:
                rep.completed += 1
                rep.total_steps += result.steps
                if result.corrupted:
                    rep.corrupted += 1
            else:
                rep.failed += 1

    # ------------------------------------------------------------ event mode
    def run_event_driven(self, tasks: Sequence, *,
                         loop: Optional[EventLoop] = None,
                         arrivals: Optional[Sequence[float]] = None,
                         scheduler=None
                         ) -> RolloutReport:
        """Generate one trajectory per task on a virtual-time event loop.

        Identical semantics to ``run`` — bounded in-flight launches, writer
        backpressure, failover-with-exclusion — but episodes are cooperative
        tasks instead of threads, so ``max_inflight`` can equal the fleet
        size: 1024+ episodes run concurrently on one core and the whole run
        is deterministic for a fixed fleet/seed (same event order, same
        report, in any process).

        ``arrivals`` optionally gives each task a virtual arrival time
        (ascending, seconds): the feeder holds task *i* until the clock
        reaches ``arrivals[i]``, which models open-loop bursty workloads
        (the elastic-cluster benchmark's arrival ramps) instead of the
        default fire-everything-at-once closed loop.

        ``scheduler`` (a ``repro.tenancy.FairShareScheduler``) turns the
        stream multi-tenant: instead of launching tasks in arrival order,
        each arriving task is submitted through admission control (the
        verdict lands in ``scheduler.decisions`` — throttled/rejected
        tasks never launch and are NOT counted as failed episodes; they
        were refused at the door, not attempted) and a dispatcher task
        launches admitted jobs in weighted deficit-round-robin order as
        worker slots and writer capacity free up. Global backpressure
        (``max_inflight``, writer gate) applies at dispatch, not at
        submission, so clients always get an immediate verdict. With
        ``virtual_deadline_s`` set, jobs still queued at the deadline are
        dropped and counted per tenant (``queued_at_stop``)."""
        cfg = self.config
        loop = loop or EventLoop()
        self._report = RolloutReport()
        self._stop.clear()
        t0 = time.monotonic()
        task_dicts = [t.to_dict() if isinstance(t, TaskSpec) else dict(t)
                      for t in tasks]
        if arrivals is not None:
            assert len(arrivals) == len(task_dicts), \
                "arrivals must give one virtual time per task"
            assert all(b >= a for a, b in zip(arrivals, arrivals[1:])), \
                "arrivals must be ascending"
        self._loop = loop
        self._scheduler = scheduler
        if self.cluster is not None:
            # binds the gateway too, plus the autoscaler + gauge daemons
            self.cluster.attach_loop(loop)
        else:
            self.gateway.attach_loop(loop)
        # notified on every episode settle and every virtual consume — the
        # feeder's wakeup channel for both gating conditions
        wake = VirtualCondition(loop)
        gate = VirtualWriterGate(loop, self.writer,
                                 consume_vs=cfg.writer_consume_vs,
                                 on_drain=wake.notify_all)

        feeding_done = False

        def feeder():
            nonlocal feeding_done
            for i, task in enumerate(task_dicts):
                if arrivals is not None:
                    delay = arrivals[i] - loop.now
                    if delay > 0:
                        yield Sleep(delay)
                stalled = False
                while not self._stop.is_set() and (
                        self._inflight >= cfg.max_inflight
                        or gate.saturated()):
                    if not stalled:
                        stalled = True
                        self._report.backpressure_waits += 1
                        self.telemetry.count("backpressure_waits")
                    yield from wake.wait()
                if self._stop.is_set():
                    break
                # claim the slot feeder-side, mirroring the threaded path;
                # malformed task dicts must fail inside the episode (as a
                # failed EpisodeResult, like the threaded path), not here
                self._enter()
                loop.spawn(self._episode_ev(task, gate, wake),
                           name=f"episode:{task.get('task_id', i)}")

        def tenant_feeder():
            # multi-tenant plane: the feeder only runs admission — the
            # verdict is immediate and the feeder never parks on fleet
            # backpressure (bounded-in-flight + writer gating move to the
            # dispatcher, where DRR picks what the freed slot runs next)
            nonlocal feeding_done
            for i, task in enumerate(task_dicts):
                if arrivals is not None:
                    delay = arrivals[i] - loop.now
                    if delay > 0:
                        yield Sleep(delay)
                if self._stop.is_set():
                    break
                scheduler.submit(task, now=loop.now)
                wake.notify_all()
            feeding_done = True
            wake.notify_all()
            yield Sleep(0.0)

        def dispatcher():
            # DRR launch pump: woken by submissions, episode settles, and
            # writer drains; exits when the stream is done and the queues
            # are empty (in-flight episodes settle on their own)
            while True:
                if self._stop.is_set():
                    scheduler.mark_stopped(loop.now)
                    break
                budget = cfg.max_inflight - self._inflight
                if budget > 0 and not gate.saturated():
                    for job in scheduler.dispatch(loop.now, budget):
                        self._enter()
                        loop.spawn(
                            self._episode_ev(job, gate, wake),
                            name=f"episode:{job.get('task_id', '?')}")
                elif scheduler.n_queued:
                    self._report.backpressure_waits += 1
                    self.telemetry.count("backpressure_waits")
                if feeding_done and scheduler.n_queued == 0:
                    break
                yield from wake.wait()

        if cfg.virtual_deadline_s is not None:
            # daemon: the deadline must not keep an otherwise-finished
            # loop alive; notify the wake condition so a feeder parked on
            # backpressure re-checks the stop flag immediately
            def _deadline():
                self._stop.set()
                wake.notify_all()
            loop.call_later(cfg.virtual_deadline_s, _deadline, daemon=True)

        if scheduler is not None:
            loop.spawn(tenant_feeder(), name="rollout-feeder")
            loop.spawn(dispatcher(), name="tenant-dispatcher")
        else:
            loop.spawn(feeder(), name="rollout-feeder")
        try:
            loop.run()
            if loop.errors:
                # episodes capture their own exceptions, so anything here
                # is a feeder or kernel failure that silently dropped
                # episodes — surface it like the threaded path would
                name, err = loop.errors[0]
                raise RuntimeError(
                    f"event-loop task {name!r} crashed; "
                    f"{len(loop.errors)} task error(s) total") from err
        finally:
            # restore thread-mode semantics (wall-clock health stamps,
            # pool-local virtual time) for any subsequent run()
            self._loop = None
            self._scheduler = None
            if self.cluster is not None:
                self.cluster.detach_loop()
            else:
                self.gateway.detach_loop()
        self._report.virtual_makespan = loop.now
        self._report.wall_seconds = time.monotonic() - t0
        return self._report

    def _episode_ev(self, task: dict, gate: VirtualWriterGate,
                    wake: VirtualCondition):
        """Cooperative-task twin of ``_episode_with_failover``.

        Tenant-tagged tasks (``task["tenant"]``) thread their id into the
        gateway's acquire-wait telemetry; under a fair-share scheduler the
        episode additionally reports its end-to-end submit->runner wait
        and its settle (slot release + service accounting) back to the
        scheduler."""
        cfg = self.config
        tenant = task.get("tenant")
        result = EpisodeResult(task=task, ok=False)
        excluded: set[str] = set()
        traj = None
        wait_observed = False
        try:
            scenario = self.registry.resolve(task)
            for attempt in range(cfg.max_attempts):
                result.attempts = attempt + 1
                backend = task.get("backend")
                got = yield from self.gateway.acquire_ev(
                    task["task_id"], timeout=cfg.acquire_timeout_vs,
                    exclude=excluded, tenant=tenant, backend=backend)
                if got is None and excluded:
                    # every other node is busy/unhealthy: fall back to the
                    # full fleet rather than deadlocking on exclusions
                    # (backend-constrained routing still applies)
                    excluded.clear()
                    got = yield from self.gateway.acquire_ev(
                        task["task_id"], timeout=cfg.acquire_timeout_vs,
                        tenant=tenant, backend=backend)
                if got is None:
                    result.error = f"no runner available ({task['task_id']})"
                    break
                if (not wait_observed and self._scheduler is not None
                        and tenant is not None and "_submit_vt" in task):
                    # the tenant-facing wait: admission -> first runner
                    # lease (queue time + gateway acquire time)
                    wait_observed = True
                    self._scheduler.observe_wait(
                        tenant, self._loop.now - task["_submit_vt"])
                node, runner = got
                result.nodes += (node,)
                try:
                    traj, steps, score, vs = yield from self._attempt_ev(
                        task, scenario, runner,
                        scale=self.gateway.pools[node].latency_scale,
                        result=result)
                    result.ok = True
                    result.steps = steps
                    result.score = score
                    result.virtual_seconds += vs
                    result.runner_id = runner.runner_id
                    break
                except TaskAborted as e:
                    result.virtual_seconds += e.virtual_seconds
                    result.error = str(e)
                    excluded.add(node)
                    self._report.reassignments += 1
                    self.telemetry.count("task_reassignments")
                    if e.fault is FaultType.PREEMPT:
                        self.telemetry.count("preemptions")
                finally:
                    # pool recycles (and autonomously recovers) the runner;
                    # task_id guards against releasing a runner that leak
                    # reclamation already took back and re-issued
                    self.gateway.release(node, runner,
                                         task_id=task["task_id"])
            if traj is not None:
                def commit(traj=traj, result=result):
                    # runner already released; the gate applies
                    # backpressure in virtual time via the feeder's
                    # saturated() check
                    gate.write(traj)
                    self.telemetry.count("episodes_completed")
                    if result.corrupted:
                        self.telemetry.count("corrupted_trajectories")
                        with self._lock:
                            self._report.corrupted_writes.append(
                                (result.runner_id, self._loop.now))

                # federated fleets ship spilled trajectories back to the
                # task's home region over the metered WAN: the commit then
                # runs at the transfer's virtual arrival time. Local (or
                # non-federated) episodes commit inline — bit-identical to
                # the pre-federation path.
                deliver = (None if self.cluster is None else
                           getattr(self.cluster, "deliver_trajectory", None))
                if deliver is None or not deliver(task, result, traj, commit):
                    commit()
        except Exception as e:   # keep one bad episode from sinking the run
            result.error = f"{type(e).__name__}: {e}"
        finally:
            if result.ok and self._loop is not None:
                # completion timestamps drive windowed throughput metrics
                # (steady-state vs recovery-window rates in Fig. 6)
                self.telemetry.observe("completion_vt", self._loop.now)
                if tenant is not None:
                    self.telemetry.observe(
                        f"completion_vt:{tenant}", self._loop.now)
            if self._scheduler is not None and tenant is not None:
                # free the tenant's quota slot *before* waking the
                # dispatcher, so the freed slot is dispatchable at once
                self._scheduler.task_done(
                    tenant, ok=result.ok,
                    service_vs=result.virtual_seconds)
            self._exit()
            self._settle(result)
            wake.notify_all()

    def _attempt_ev(self, task: dict, scenario: Scenario, runner, *,
                    scale: Callable[[], float] = None, result=None):
        """Cooperative twin of ``_attempt``: each operation's virtual cost
        is slept on the loop, so concurrent episodes interleave exactly as
        a real fleet's latencies would. ``scale`` (the pool's live
        CPU-contention factor) is sampled *per operation* — contention
        rises and falls with concurrent occupancy as the run evolves."""
        cfg = self.config
        oh = cfg.op_overhead or _zero_overhead
        sc = scale or _unit_scale
        mgr = runner.manager
        vs = 0.0
        if result is not None:
            result.corrupted = False    # per-attempt: a clean failover
            #                             retry clears a poisoned attempt
        try:
            dur = mgr.configure(task) * sc() + oh()
            vs += dur
            yield Sleep(dur)
            obs, dur = mgr.reset()
            dur = dur * sc() + oh()
            vs += dur
            yield Sleep(dur)
            steps: list[TrajectoryStep] = []
            horizon = int(task.get("horizon", 15))
            cap = cfg.max_steps or horizon * 2
            done = False
            while not done and len(steps) < cap:
                thought, action = scenario.policy(obs, len(steps))
                obs, _rew, done, info, dur = mgr.step(action)
                dur = dur * sc() + oh()
                vs += dur
                if info.get("silent_corruption") and result is not None:
                    result.corrupted = True
                yield Sleep(dur)
                steps.append(TrajectoryStep(obs, thought, action))
                self.telemetry.count("steps")
                self.telemetry.observe("step_latency_vs", dur)
            score, dur = mgr.evaluate()
            dur = dur * sc() + oh()
            vs += dur
            yield Sleep(dur)
        except TaskAborted as e:
            # the failed attempts + autonomous recovery occupied the runner
            # in virtual time; sleep it before the failover re-dispatch so
            # the fleet clock stays honest under faults
            yield Sleep(e.virtual_seconds)
            e.virtual_seconds += vs
            raise
        traj = Trajectory(task["task_id"], task["description"], steps, score,
                          task=task)
        return traj, len(steps), score, vs


def _zero_overhead() -> float:
    return 0.0


def _unit_scale() -> float:
    return 1.0
