"""Trajectory writer: streams completed episodes into the data pipeline.

A bounded queue decouples rollout workers (producers) from the consumer
thread that encodes trajectories and appends them to the replay buffer —
the same producer/consumer decoupling as the paper's §4.2 semi-online
pipeline. The bounded queue is the engine's backpressure signal: when
downstream (encoding / replay buffer) cannot keep up, ``saturated()``
turns true and the scheduler stops launching new episodes until the
backlog drains.

``VirtualWriterGate`` is the event-driven engine's view of the same
mechanism: the real consumer drains in wall time, which the virtual clock
cannot see, so the gate models consumer throughput in virtual seconds and
makes saturation a deterministic function of virtual time.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.event_loop import EventLoop
from repro.data.pipeline import Trajectory, encode_trajectory
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer


@dataclass
class WriterStats:
    written: int = 0          # trajectories accepted into the queue
    consumed: int = 0         # trajectories drained by the consumer
    encoded_tokens: int = 0
    steps: int = 0


class TrajectoryWriter:
    """Bounded, threaded sink from rollout workers to SFT/PPO consumers."""

    def __init__(self, *, replay: Optional[ReplayBuffer] = None,
                 tokenizer: Optional[ByteTokenizer] = None,
                 vocab_size: int = 151936,
                 capacity: int = 256,
                 retain: bool = True,
                 on_trajectory: Optional[Callable[[Trajectory], None]] = None):
        self.replay = replay
        self.tokenizer = tokenizer
        self.vocab_size = vocab_size
        self.capacity = capacity
        self.retain = retain     # keep consumed trajectories in memory;
        #                          False for benchmark-scale fleets where
        #                          thousands of observation arrays would
        #                          otherwise accumulate
        self.on_trajectory = on_trajectory
        self.stats = WriterStats()
        self.errors: list[str] = []
        self.trajectories: list[Trajectory] = []
        self._q: queue.Queue = queue.Queue(maxsize=capacity)
        self._done = object()
        self._resumed = threading.Event()
        self._resumed.set()
        self._closed = False
        self._lock = threading.Lock()
        # notified after every consumed trajectory, so drain() wakes on the
        # last consume instead of busy-polling the stats counters
        self._consumed_cv = threading.Condition(self._lock)
        self._thread = threading.Thread(target=self._consume, daemon=True,
                                        name="trajectory-writer")
        self._thread.start()

    # -------------------------------------------------------------- produce
    def write(self, traj: Trajectory, timeout: Optional[float] = None) -> None:
        """Blocking put — callers feel backpressure when the queue is full."""
        assert not self._closed, "writer already closed"
        if (not self.retain and self.tokenizer is None
                and self.replay is None and self.on_trajectory is None
                and self._resumed.is_set()):
            # null-sink fast path (benchmark-scale fleets): with no
            # encoder, replay buffer, callback, or retention, the consumer
            # thread would only bump counters — so bump them here and skip
            # the queue round-trip entirely. One producer->consumer
            # handoff costs ~1 ms of GIL ping-pong; at 65k episodes that
            # is a minute of pure queue overhead. pause() disables the
            # fast path so saturation tests still exercise the real queue.
            with self._consumed_cv:
                self.stats.written += 1
                self.stats.consumed += 1
                self.stats.steps += len(traj.steps)
                self._consumed_cv.notify_all()
            return
        self._q.put(traj, timeout=timeout)
        with self._lock:
            self.stats.written += 1

    def saturated(self, high_water: float = 0.75) -> bool:
        """True when the backlog is at/above the high-water mark — the
        rollout scheduler polls this before launching new episodes."""
        return self._q.qsize() >= max(1, int(self.capacity * high_water))

    def backlog(self) -> int:
        return self._q.qsize()

    # -------------------------------------------------------------- consume
    def _consume(self) -> None:
        while True:
            item = self._q.get()
            if item is self._done:
                return
            self._resumed.wait()          # honor pause() deterministically
            try:
                self._handle(item)
            except Exception as e:
                # a bad trajectory (or a raising on_trajectory callback) must
                # not kill the consumer: producers would deadlock on a full
                # queue. Record the error and keep draining.
                with self._consumed_cv:
                    self.errors.append(f"{type(e).__name__}: {e}")
                    self.stats.consumed += 1
                    self._consumed_cv.notify_all()

    def _handle(self, traj: Trajectory) -> None:
        if self.tokenizer is not None:
            ids, mask = encode_trajectory(traj, self.tokenizer,
                                          self.vocab_size)
            if self.replay is not None:
                self.replay.add({"trajectory": traj, "tokens": ids,
                                 "loss_mask": mask})
            with self._lock:
                self.stats.encoded_tokens += len(ids)
        elif self.replay is not None:
            self.replay.add(traj)
        if self.on_trajectory is not None:
            self.on_trajectory(traj)
        with self._consumed_cv:
            if self.retain:
                self.trajectories.append(traj)
            self.stats.consumed += 1
            self.stats.steps += len(traj.steps)
            self._consumed_cv.notify_all()

    # -------------------------------------------------------------- control
    def pause(self) -> None:
        """Stop draining (testing hook: forces saturation deterministically)."""
        self._resumed.clear()

    def resume(self) -> None:
        self._resumed.set()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every accepted trajectory has been consumed.

        Waits on the consumer's condition variable, so it returns promptly
        after the final consume rather than on the next poll tick."""
        with self._consumed_cv:
            return self._consumed_cv.wait_for(
                lambda: self.stats.consumed >= self.stats.written,
                timeout=timeout)

    def close(self, timeout: float = 30.0) -> None:
        if self._closed:
            return
        self._closed = True
        self.resume()
        self._q.put(self._done)
        self._thread.join(timeout=timeout)


class VirtualWriterGate:
    """Virtual-time mirror of ``TrajectoryWriter`` backpressure.

    The event-driven engine runs thousands of episodes on a virtual clock;
    the writer's real consumer thread drains in *wall* time, invisible to
    that clock, so gating on the real queue would make backpressure depend
    on host speed and break determinism. The gate forwards every
    trajectory to the real writer (data still flows to the replay buffer)
    while modeling the consumer as draining one trajectory per
    ``consume_vs`` virtual seconds; ``saturated()`` is then a
    deterministic function of virtual time, with the same capacity and
    high-water semantics as the threaded path."""

    def __init__(self, loop: EventLoop, writer: TrajectoryWriter, *,
                 consume_vs: float = 0.02, high_water: float = 0.75,
                 on_drain: Optional[Callable[[], None]] = None):
        self._loop = loop
        self.writer = writer
        self.capacity = writer.capacity
        self.consume_vs = consume_vs
        self.high_water = high_water
        self.on_drain = on_drain
        self._backlog = 0
        self._draining = False

    def write(self, traj: Trajectory) -> None:
        self.writer.write(traj)
        self._backlog += 1
        if not self._draining:
            self._draining = True
            self._loop.call_later(self.consume_vs, self._drain_one)

    def _drain_one(self) -> None:
        self._backlog -= 1
        if self._backlog > 0:
            self._loop.call_later(self.consume_vs, self._drain_one)
        else:
            self._draining = False
        if self.on_drain is not None:
            self.on_drain()

    def saturated(self) -> bool:
        return self._backlog >= max(1, int(self.capacity * self.high_water))

    def backlog(self) -> int:
        return self._backlog
