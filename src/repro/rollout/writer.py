"""Trajectory writer: streams completed episodes into the data pipeline.

A bounded queue decouples rollout workers (producers) from the consumer
thread that encodes trajectories and appends them to the replay buffer —
the same producer/consumer decoupling as the paper's §4.2 semi-online
pipeline. The bounded queue is the engine's backpressure signal: when
downstream (encoding / replay buffer) cannot keep up, ``saturated()``
turns true and the scheduler stops launching new episodes until the
backlog drains.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.data.pipeline import Trajectory, encode_trajectory
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer


@dataclass
class WriterStats:
    written: int = 0          # trajectories accepted into the queue
    consumed: int = 0         # trajectories drained by the consumer
    encoded_tokens: int = 0
    steps: int = 0


class TrajectoryWriter:
    """Bounded, threaded sink from rollout workers to SFT/PPO consumers."""

    def __init__(self, *, replay: Optional[ReplayBuffer] = None,
                 tokenizer: Optional[ByteTokenizer] = None,
                 vocab_size: int = 151936,
                 capacity: int = 256,
                 on_trajectory: Optional[Callable[[Trajectory], None]] = None):
        self.replay = replay
        self.tokenizer = tokenizer
        self.vocab_size = vocab_size
        self.capacity = capacity
        self.on_trajectory = on_trajectory
        self.stats = WriterStats()
        self.errors: list[str] = []
        self.trajectories: list[Trajectory] = []
        self._q: queue.Queue = queue.Queue(maxsize=capacity)
        self._done = object()
        self._resumed = threading.Event()
        self._resumed.set()
        self._closed = False
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._consume, daemon=True,
                                        name="trajectory-writer")
        self._thread.start()

    # -------------------------------------------------------------- produce
    def write(self, traj: Trajectory, timeout: Optional[float] = None) -> None:
        """Blocking put — callers feel backpressure when the queue is full."""
        assert not self._closed, "writer already closed"
        self._q.put(traj, timeout=timeout)
        with self._lock:
            self.stats.written += 1

    def saturated(self, high_water: float = 0.75) -> bool:
        """True when the backlog is at/above the high-water mark — the
        rollout scheduler polls this before launching new episodes."""
        return self._q.qsize() >= max(1, int(self.capacity * high_water))

    def backlog(self) -> int:
        return self._q.qsize()

    # -------------------------------------------------------------- consume
    def _consume(self) -> None:
        while True:
            item = self._q.get()
            if item is self._done:
                return
            self._resumed.wait()          # honor pause() deterministically
            try:
                self._handle(item)
            except Exception as e:
                # a bad trajectory (or a raising on_trajectory callback) must
                # not kill the consumer: producers would deadlock on a full
                # queue. Record the error and keep draining.
                with self._lock:
                    self.errors.append(f"{type(e).__name__}: {e}")
                    self.stats.consumed += 1

    def _handle(self, traj: Trajectory) -> None:
        if self.tokenizer is not None:
            ids, mask = encode_trajectory(traj, self.tokenizer,
                                          self.vocab_size)
            if self.replay is not None:
                self.replay.add({"trajectory": traj, "tokens": ids,
                                 "loss_mask": mask})
            with self._lock:
                self.stats.encoded_tokens += len(ids)
        elif self.replay is not None:
            self.replay.add(traj)
        if self.on_trajectory is not None:
            self.on_trajectory(traj)
        with self._lock:
            self.trajectories.append(traj)
            self.stats.consumed += 1
            self.stats.steps += len(traj.steps)

    # -------------------------------------------------------------- control
    def pause(self) -> None:
        """Stop draining (testing hook: forces saturation deterministically)."""
        self._resumed.clear()

    def resume(self) -> None:
        self._resumed.set()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every accepted trajectory has been consumed."""
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            with self._lock:
                if self.stats.consumed >= self.stats.written:
                    return True
            time.sleep(0.01)
        return False

    def close(self, timeout: float = 30.0) -> None:
        if self._closed:
            return
        self._closed = True
        self.resume()
        self._q.put(self._done)
        self._thread.join(timeout=timeout)
