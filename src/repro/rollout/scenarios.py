"""Scenario registry: registered families of computer-use workloads.

Generalizes the ad-hoc Table-3 task list in ``core/tasks.py`` into a
uniform env/task interface (cf. Gym-Anything): every scenario declares its
family (office / browser / terminal / coding / media / email / system /
multi_app), its per-step latency profile (driving both the real threaded
engine and the virtual-time throughput benchmark), its horizon range, its
Table-3 sampling weight, and a scripted policy that stands in for the
agent (UI-TARS / Agent-S in the paper's pipeline).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.tasks import TaskSpec, TABLE3_ROWS
# RewardSpec's canonical home is the backend layer: per-family defaults
# are owned by each EnvBackend (single source of truth) and the registry
# looks them up through the backend. Re-exported here so existing
# ``from repro.rollout.scenarios import RewardSpec`` callers keep working.
from repro.envs.base import RewardSpec, get_backend

# (obs, step_idx) -> (thought, action)
Policy = Callable[[object, int], tuple[str, str]]


@dataclass(frozen=True)
class ScenarioProfile:
    """Per-scenario latency/length profile in virtual seconds.

    ``step_mean_s`` feeds the virtual-time throughput simulation; the real
    engine inherits step latency from the replica's ``LatencyModel``, so the
    profile is the calibration target, not a second clock."""

    step_mean_s: float = 2.15
    step_sigma: float = 0.35
    configure_s: float = 3.0
    reset_s: float = 4.0
    evaluate_s: float = 1.0
    horizon: tuple[int, int] = (10, 25)

    def mean_horizon(self) -> float:
        lo, hi = self.horizon
        return (lo + hi) / 2.0

    def mean_trajectory_s(self) -> float:
        """Expected virtual seconds for one full episode."""
        return (self.configure_s + self.reset_s + self.evaluate_s
                + self.step_mean_s * self.mean_horizon())


@dataclass(frozen=True)
class Scenario:
    name: str
    family: str
    domain: str                    # Table-3 application domain
    description: str
    policy: Policy
    profile: ScenarioProfile = field(default_factory=ScenarioProfile)
    weight: float = 1.0            # sampling weight (Table-3 trajectory mix)
    reward: RewardSpec = field(default_factory=RewardSpec)
    backend: str = "simos"         # EnvBackend this family's episodes need

    def make_task(self, index: int, rng: random.Random) -> TaskSpec:
        return TaskSpec(
            task_id=f"{self.name}-{index}",
            task_type=self.family,
            domain=self.domain,
            description=self.description,
            horizon=rng.randint(*self.profile.horizon),
            setup_software=(self.domain,),
            scenario=self.name,
            backend=self.backend)


class ScenarioRegistry:
    """Named scenario families with weighted sampling and dict round-trip."""

    def __init__(self):
        self._scenarios: dict[str, Scenario] = {}

    # -------------------------------------------------------- registration
    def register(self, scenario: Scenario) -> Scenario:
        if scenario.name in self._scenarios:
            raise ValueError(f"scenario {scenario.name!r} already registered")
        # every scenario binds to a real backend — an unregistered backend
        # name would strand its tasks at routing time, so it fails here
        get_backend(scenario.backend)
        self._scenarios[scenario.name] = scenario
        return scenario

    def scenario(self, name: str, family: str, domain: str,
                 description: str, *, profile: Optional[ScenarioProfile] = None,
                 weight: float = 1.0) -> Callable[[Policy], Scenario]:
        """Decorator form: the decorated function is the scripted policy."""
        def deco(policy: Policy) -> Scenario:
            return self.register(Scenario(
                name=name, family=family, domain=domain,
                description=description, policy=policy,
                profile=profile or ScenarioProfile(), weight=weight))
        return deco

    # --------------------------------------------------------------- lookup
    def get(self, name: str) -> Scenario:
        return self._scenarios[name]

    def __contains__(self, name: str) -> bool:
        return name in self._scenarios

    def __len__(self) -> int:
        return len(self._scenarios)

    def __iter__(self) -> Iterator[Scenario]:
        return iter(self._scenarios.values())

    def names(self) -> list[str]:
        return list(self._scenarios)

    def families(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self._scenarios.values():
            seen.setdefault(s.family)
        return list(seen)

    def by_family(self, family: str) -> list[Scenario]:
        return [s for s in self._scenarios.values() if s.family == family]

    def backends(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self._scenarios.values():
            seen.setdefault(s.backend)
        return list(seen)

    def by_backend(self, backend: str) -> list[Scenario]:
        return [s for s in self._scenarios.values() if s.backend == backend]

    def domains(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self._scenarios.values():
            seen.setdefault(s.domain)
        return list(seen)

    # ------------------------------------------------------------- sampling
    def sample(self, n: int, *, seed: int = 0,
               families: Optional[list[str]] = None,
               backends: Optional[list[str]] = None) -> list[TaskSpec]:
        """Weighted sample of task specs across (a subset of) scenarios."""
        rng = random.Random(seed)
        pool = [s for s in self._scenarios.values()
                if (families is None or s.family in families)
                and (backends is None or s.backend in backends)]
        assert pool, "no scenarios match the requested families/backends"
        weights = [s.weight for s in pool]
        picks = rng.choices(pool, weights=weights, k=n)
        return [s.make_task(i, rng) for i, s in enumerate(picks)]

    def tasks_for(self, name: str, n: int, *, seed: int = 0) -> list[TaskSpec]:
        rng = random.Random(seed)
        s = self.get(name)
        return [s.make_task(i, rng) for i in range(n)]

    def resolve(self, task: dict) -> Scenario:
        """Round-trip a task dict (``TaskSpec.to_dict``) back to its scenario.

        Falls back to domain matching for legacy tasks produced before the
        registry existed (no ``scenario`` key)."""
        name = task.get("scenario")
        if name and name in self._scenarios:
            return self._scenarios[name]
        domain = task.get("domain")
        for s in self._scenarios.values():
            if s.domain == domain:
                return s
        raise KeyError(f"no scenario for task {task.get('task_id')!r} "
                       f"(scenario={name!r}, domain={domain!r})")

    # -------------------------------------------------------------- rewards
    def reward_for(self, task: dict) -> RewardSpec:
        """The reward shaping that applies to a task's scenario family."""
        return self.resolve(task).reward

    def shape_rewards(self, task: dict, score: float,
                      n_steps: int) -> np.ndarray:
        """Dense per-step rewards for one finished episode of ``task``."""
        horizon = int(task.get("horizon", 15))
        return self.reward_for(task).step_rewards(score, n_steps, horizon)

    def is_success(self, task: dict, score: float) -> bool:
        return self.reward_for(task).success(score)

    def mean_trajectory_s(self) -> float:
        """Weight-averaged expected episode duration (virtual seconds)."""
        total_w = sum(s.weight for s in self._scenarios.values())
        return sum(s.weight * s.profile.mean_trajectory_s()
                   for s in self._scenarios.values()) / total_w

    def mean_steps_per_trajectory(self) -> float:
        total_w = sum(s.weight for s in self._scenarios.values())
        return sum(s.weight * s.profile.mean_horizon()
                   for s in self._scenarios.values()) / total_w


# --------------------------------------------------------- scripted policies
def _cycle_policy(thoughts_and_actions: list[tuple[str, str]]) -> Policy:
    def policy(obs, step_idx: int) -> tuple[str, str]:
        import numpy as np
        salt = int(np.asarray(obs).sum()) % 997 if obs is not None else 0
        thought, action = thoughts_and_actions[
            step_idx % len(thoughts_and_actions)]
        return f"{thought} (screen state {salt})", action
    return policy


OFFICE_ACTIONS = [
    ("The document is open; I should add the heading",
     "type('Quarterly Report')"),
    ("Formatting the title next", "key('ctrl+b')"),
    ("Moving to the body paragraph", "click(120, 184)"),
    ("Saving progress", "key('ctrl+s')"),
]
BROWSER_ACTIONS = [
    ("I need the search page first", "navigate('https://example.org')"),
    ("Entering the query", "type('osgym scalable os infra')"),
    ("Submitting the search", "key('enter')"),
    ("Opening the top result", "click(96, 240)"),
    ("Scrolling for the relevant section", "scroll(-4)"),
]
TERMINAL_ACTIONS = [
    ("Listing the working directory", "exec('ls -la')"),
    ("Inspecting system state", "exec('systemctl status cron')"),
    ("Editing the config", "exec('sed -i s/old/new/ app.conf')"),
    ("Verifying the change took effect", "exec('grep new app.conf')"),
]
CODING_ACTIONS = [
    ("Opening the failing module", "click(40, 96)"),
    ("Fixing the off-by-one", "type('range(n - 1)')"),
    ("Running the tests", "exec('pytest -x -q')"),
    ("Committing the fix", "exec('git commit -am fix')"),
]
MEDIA_ACTIONS = [
    ("Loading the playlist", "click(64, 300)"),
    ("Adjusting the volume", "drag(420, 40, 460, 40)"),
    ("Skipping the intro", "key('right')"),
]
EMAIL_ACTIONS = [
    ("Opening the compose window", "click(24, 60)"),
    ("Addressing the message", "type('team@example.org')"),
    ("Writing the update", "type('Status: replicas healthy')"),
    ("Sending it", "key('ctrl+enter')"),
]
SYSTEM_ACTIONS = [
    ("Opening system settings", "click(580, 12)"),
    ("Raising the file-descriptor limit", "exec('sysctl fs.file-max=4194304')"),
    ("Confirming the new value", "exec('sysctl fs.file-max')"),
]
MULTI_APP_ACTIONS = (OFFICE_ACTIONS[:2] + BROWSER_ACTIONS[:2]
                     + TERMINAL_ACTIONS[:1] + EMAIL_ACTIONS[:2])


def default_registry() -> ScenarioRegistry:
    """The built-in scenario families.

    Weights are Table 3's trajectory counts so the sampled mix reproduces
    the paper's dataset composition. Horizon bands are *derived from
    Table 3* — ±20% around each domain's measured steps/trajectory,
    clamped to the paper's 10-25 band — so the sampled workload's mean
    episode length matches the dataset's (~15 steps/trajectory), which is
    what lets one latency calibration reproduce both the Table-3
    generation times and the live-engine throughput. Per-family step
    latencies spread around the calibrated mean (browser steps are
    network-bound and slower; terminal steps are fast)."""
    reg = ScenarioRegistry()
    fast = ScenarioProfile(step_mean_s=1.5)
    slow = ScenarioProfile(step_mean_s=2.8)
    mid = ScenarioProfile(step_mean_s=2.15)
    long = ScenarioProfile(step_mean_s=2.4, configure_s=5.0)

    # Per-family reward shaping lives on the backend (the single source
    # of truth — see SimOSBackend.reward_defaults); reward_spec() raises
    # on a family the backend does not define, so a typo'd family string
    # fails registration instead of silently training on generic shaping.
    simos = get_backend("simos")

    rows = {domain: (ttype, desc, weight)
            for ttype, domain, desc, weight, _steps in TABLE3_ROWS}
    steps_per = {domain: steps / traj
                 for _t, domain, _d, traj, steps in TABLE3_ROWS}

    def add(name, family, domain, actions, profile):
        ttype, desc, weight = rows[domain]
        per = steps_per[domain]
        horizon = (max(10, round(0.8 * per)), min(25, round(1.2 * per)))
        reg.register(Scenario(
            name=name, family=family, domain=domain, description=desc,
            policy=_cycle_policy(actions),
            profile=replace(profile, horizon=horizon),
            weight=float(weight),
            reward=simos.reward_spec(family)))

    add("office_writer", "office", "LibreOffice Writer", OFFICE_ACTIONS, mid)
    add("office_calc", "office", "LibreOffice Calc", OFFICE_ACTIONS, mid)
    add("office_impress", "office", "LibreOffice Impress", OFFICE_ACTIONS, mid)
    add("browser_chrome", "browser", "Chrome", BROWSER_ACTIONS, slow)
    add("email_thunderbird", "email", "ThunderBird", EMAIL_ACTIONS, mid)
    add("media_vlc", "media", "VLC", MEDIA_ACTIONS, fast)
    add("coding_vscode", "coding", "VS Code", CODING_ACTIONS, mid)
    add("image_gimp", "image", "GIMP", OFFICE_ACTIONS, slow)
    add("terminal_os", "terminal", "OS", TERMINAL_ACTIONS, fast)
    add("multi_app", "multi_app", "Multi-Apps", MULTI_APP_ACTIONS, long)
    return reg


SWE_ACTIONS = [
    ("Reading the failing test output", "exec('pytest -x -q 2>&1 | tail')"),
    ("Opening the implicated module", "open('src/parser.py')"),
    ("Patching the boundary condition", "edit('src/parser.py', 'n + 1', 'n')"),
    ("Re-running the focused test", "exec('pytest tests/test_parser.py -q')"),
]
WEB_NAV_ACTIONS = [
    ("Loading the landing page", "goto('https://example.org')"),
    ("Querying for the target item", "fill('#search', 'quarterly totals')"),
    ("Submitting the search", "press('#search', 'Enter')"),
    ("Following the top hit", "click('.result a')"),
]
WEB_FORM_ACTIONS = [
    ("Opening the signup form", "goto('https://example.org/signup')"),
    ("Filling the email field", "fill('#email', 'agent@example.org')"),
    ("Accepting the terms", "check('#tos')"),
    ("Submitting the form", "click('#submit')"),
]
MOBILE_ACTIONS = [
    ("Waking the device", "key('wakeup')"),
    ("Opening the target app", "tap(96, 480)"),
    ("Scrolling to the setting", "swipe(160, 600, 160, 200)"),
    ("Toggling the switch", "tap(288, 344)"),
]


def mixed_registry() -> ScenarioRegistry:
    """The default SimOS families plus one scenario per non-SimOS family.

    This is the heterogeneous-fleet task source: every scenario is bound
    to its backend, so the gateway's backend-constrained routing keeps
    each episode on a matching pool. Profiles mirror the backends'
    calibrated latency models (the profile feeds the virtual-time
    calibration; the replica's own ``LatencyModel`` drives the engine),
    and rewards come from each backend's ``reward_defaults``."""
    from repro.envs import get_backend as _gb

    reg = default_registry()

    def add(name, family, backend_name, domain, desc, actions, profile,
            weight):
        reg.register(Scenario(
            name=name, family=family, domain=domain, description=desc,
            policy=_cycle_policy(actions), profile=profile,
            weight=float(weight), reward=_gb(backend_name).reward_spec(family),
            backend=backend_name))

    add("swe_bugfix", "swe_bugfix", "swe", "Git Repo", "Bug Fixing",
        SWE_ACTIONS,
        ScenarioProfile(step_mean_s=1.4, step_sigma=0.55, configure_s=2.5,
                        reset_s=0.9, evaluate_s=6.0, horizon=(6, 14)), 300)
    add("swe_feature", "swe_feature", "swe", "Git Repo", "Feature Patch",
        SWE_ACTIONS,
        ScenarioProfile(step_mean_s=1.4, step_sigma=0.55, configure_s=2.5,
                        reset_s=0.9, evaluate_s=6.0, horizon=(8, 18)), 200)
    add("web_nav", "web_nav", "browser", "Headless Web", "Site Navigation",
        WEB_NAV_ACTIONS,
        ScenarioProfile(step_mean_s=0.9, step_sigma=0.50, configure_s=1.2,
                        reset_s=1.5, evaluate_s=0.8, horizon=(8, 20)), 300)
    add("web_form", "web_form", "browser", "Headless Web", "Form Filling",
        WEB_FORM_ACTIONS,
        ScenarioProfile(step_mean_s=0.9, step_sigma=0.50, configure_s=1.2,
                        reset_s=1.5, evaluate_s=0.8, horizon=(6, 14)), 200)
    add("mobile_app", "mobile_app", "mobile", "Device Emulator", "App Flow",
        MOBILE_ACTIONS,
        ScenarioProfile(step_mean_s=1.6, step_sigma=0.40, configure_s=4.0,
                        reset_s=2.5, evaluate_s=1.2, horizon=(8, 18)), 300)
    add("mobile_settings", "mobile_settings", "mobile", "Device Emulator",
        "Settings Change", MOBILE_ACTIONS,
        ScenarioProfile(step_mean_s=1.6, step_sigma=0.40, configure_s=4.0,
                        reset_s=2.5, evaluate_s=1.2, horizon=(6, 12)), 200)
    return reg


_DEFAULT: Optional[ScenarioRegistry] = None


def get_default_registry() -> ScenarioRegistry:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = default_registry()
    return _DEFAULT
