"""jit-ready kernel entry points with backend dispatch.

Models call these; the implementation is chosen by ``repro_kernel_mode``:
  - "ref":       pure-jnp oracle (CPU path; what the dry-run lowers)
  - "pallas":    pl.pallas_call TPU kernels (the deployment path)
  - "interpret": Pallas kernels in interpret mode (CPU correctness tests)
Default: "pallas" on TPU backends, else "ref".
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref

_mode_override: Optional[str] = None


def set_mode(mode: Optional[str]) -> None:
    """Force a kernel backend: "ref" | "pallas" | "interpret" | None (auto)."""
    global _mode_override
    assert mode in (None, "ref", "pallas", "interpret"), mode
    _mode_override = mode


def kernel_mode() -> str:
    if _mode_override is not None:
        return _mode_override
    env = os.environ.get("REPRO_KERNELS", "").strip()
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    softmax_scale=None):
    mode = kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            softmax_scale=softmax_scale, interpret=(mode == "interpret"))
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, softmax_scale=softmax_scale)


def decode_attention(q, k_cache, v_cache, cache_len, *, softmax_scale=None):
    mode = kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k_cache, v_cache, cache_len, softmax_scale=softmax_scale,
            interpret=(mode == "interpret"))
    return _ref.decode_attention_ref(q, k_cache, v_cache, cache_len,
                                     softmax_scale=softmax_scale)


def ssd_scan(x, dt, A, B_in, C_in, D, *, chunk=256, initial_state=None,
             return_state=False):
    mode = kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import ssd_scan as ssd
        return ssd.ssd_scan(
            x, dt, A, B_in, C_in, D, chunk=chunk, initial_state=initial_state,
            return_state=return_state, interpret=(mode == "interpret"))
    return _ref.ssd_ref(x, dt, A, B_in, C_in, D, chunk=chunk,
                        initial_state=initial_state, return_state=return_state)


def ssd_decode(x, dt, A, B_in, C_in, D, state):
    # O(1)-state single-token update; jnp is already optimal here.
    return _ref.ssd_decode_ref(x, dt, A, B_in, C_in, D, state)


def causal_conv1d(x, w, bias=None):
    return _ref.causal_conv1d_ref(x, w, bias)


def rmsnorm(x, scale, eps: float = 1e-6):
    mode = kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import rmsnorm as rn
        return rn.rmsnorm(x, scale, eps, interpret=(mode == "interpret"))
    return _ref.rmsnorm_ref(x, scale, eps)
