"""Mamba2 SSD chunked scan (TPU Pallas).

The SSD recurrence is computed chunk-by-chunk: intra-chunk interactions are a
(chunk x chunk) masked matmul (MXU-friendly), and the cross-chunk recurrent
state (N x P per head) lives in VMEM scratch, carried along the sequential
"arbitrary" grid dimension — the TPU analogue of the paper's
chunk-parallel-then-state-pass GPU kernel. Heads are tiled so the per-step
working set (x, B, C, scores, state for `block_h` heads) fits VMEM.

Grid: (batch, head_blocks, chunks); chunks sequential. ngroups == 1 only
(all assigned configs); the wrapper falls back to the jnp oracle otherwise.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed the TPU compiler-params struct from TPUCompilerParams to
# CompilerParams (jax 0.5): accept either so the kernels (and their
# interpret-mode tests) run on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_H = 8


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, init_ref,
                y_ref, fin_ref, state_scr, *, n_c: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = init_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)            # (c, hb, P)
    dt = dt_ref[0].astype(jnp.float32)          # (c, hb)
    Bm = B_ref[0].astype(jnp.float32)           # (c, N)
    Cm = C_ref[0].astype(jnp.float32)           # (c, N)
    A = A_ref[...].astype(jnp.float32)          # (hb,)
    D = D_ref[...].astype(jnp.float32)          # (hb,)

    dA = dt * A[None, :]                        # (c, hb)
    cum = jnp.cumsum(dA, axis=0)                # inclusive
    state = state_scr[...]                      # (hb, N, P)

    # intra-chunk: masked decay-weighted (C B^T) @ x
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    diff = cum[:, None, :] - cum[None, :, :]    # (i, j, hb)
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ltmask = (i_idx >= j_idx)[:, :, None]
    scores = jnp.where(ltmask, cb[:, :, None] * decay * dt[None, :, :], 0.0)
    y = jnp.einsum("ijh,jhp->ihp", scores, x,
                   preferred_element_type=jnp.float32)

    # inter-chunk: carried state contribution
    Ce = Cm[:, None, :] * jnp.exp(cum)[:, :, None]          # (c, hb, N)
    y = y + jnp.einsum("ihn,hnp->ihp", Ce, state,
                       preferred_element_type=jnp.float32)

    # state update
    last = cum[-1:, :]                                       # (1, hb)
    w = jnp.exp(last - cum) * dt                             # (c, hb)
    Bw = Bm[:, None, :] * w[:, :, None]                      # (c, hb, N)
    new_contrib = jnp.einsum("jhn,jhp->hnp", Bw, x,
                             preferred_element_type=jnp.float32)
    state_scr[...] = jnp.exp(last[0])[:, None, None] * state + new_contrib

    y_ref[0] = (y + D[None, :, None] * x).astype(y_ref.dtype)

    @pl.when(ci == n_c - 1)
    def _finish():
        fin_ref[0] = state_scr[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_in: jax.Array,
             C_in: jax.Array, D: jax.Array, *, chunk: int = 256,
             initial_state: Optional[jax.Array] = None,
             return_state: bool = False, block_h: int = DEFAULT_BLOCK_H,
             interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A,D: (H,); B_in/C_in: (B,S,G,N)."""
    Bb, S, H, P = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    if G != 1:
        from repro.kernels import ref
        return ref.ssd_ref(x, dt, A, B_in, C_in, D, chunk=chunk,
                           initial_state=initial_state,
                           return_state=return_state)
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n_c = S // c
    hb = min(block_h, H)
    assert H % hb == 0, (H, hb)
    n_h = H // hb

    init = (jnp.zeros((Bb, H, N, P), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    Bs = B_in[:, :, 0]                                       # (B, S, N)
    Cs = C_in[:, :, 0]

    kernel = functools.partial(_ssd_kernel, n_c=n_c, chunk=c)
    y, fin = pl.pallas_call(
        kernel,
        grid=(Bb, n_h, n_c),
        in_specs=[
            pl.BlockSpec((1, c, hb, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, c, hb), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((hb,), lambda b, h, i: (h,)),
            pl.BlockSpec((1, c, N), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((hb,), lambda b, h, i: (h,)),
            pl.BlockSpec((1, hb, N, P), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, hb, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, hb, N, P), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bs, Cs, D, init)
    if return_state:
        return y, fin
    return y
