"""Pure-jnp oracles for every Pallas kernel.

These are also the CPU execution path for the models (the dry-run lowers
these), so they are written to be memory-efficient at 32k-500k contexts:
attention is chunked over query blocks (banded for sliding-window), the SSD
scan is chunked with an O(1) carried state.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# The dry-run sets this so internal chunk scans are unrolled and XLA's
# cost_analysis (which counts a while-loop body once) sees every chunk.
SCAN_UNROLL = False


# ---------------------------------------------------------------- attention
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0, q_chunk: int = 1024,
                  softmax_scale: Optional[float] = None) -> jax.Array:
    """Multi-head attention with GQA, causal masking, optional sliding window.

    q: (B, Sq, H, hd); k, v: (B, Sk, KVH, hd). `q_offset` is the absolute
    position of q[0] (prefill continuation / decode). Chunked over q so the
    (Sq x Sk) score matrix is never materialized.
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    def attend(qc, kc, vc, qpos, kpos):
        # qc: (B, n, H, hd); kc/vc: (B, m, KVH, hd); positions absolute
        n, m = qc.shape[1], kc.shape[1]
        qg = qc.reshape(qc.shape[0], n, KVH, G, hd)
        s = jnp.einsum("bnkgd,bmkd->bkgnm", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((n, m), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # rows where everything is masked produce uniform garbage; zero them
        p = jnp.where(mask.any(axis=-1)[None, None, None, :, None], p, 0.0)
        o = jnp.einsum("bkgnm,bmkd->bnkgd", p.astype(vc.dtype), vc)
        return o.reshape(qc.shape[0], n, H, hd)

    if Sq <= q_chunk:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        return attend(q, k, v, qpos, kpos)

    assert Sq % q_chunk == 0, (Sq, q_chunk)
    nq = Sq // q_chunk
    qs = q.reshape(B, nq, q_chunk, H, hd)

    banded = bool(window) and Sk > 2 * window
    if banded:
        # Sliding window: each q chunk only sees a band of the keys.
        band = window + q_chunk
        band = min(_round_up(band, q_chunk), Sk)

        def body(_, i):
            qc = qs[:, i]
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            start = jnp.clip(i * q_chunk + q_chunk - band, 0, Sk - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = start + jnp.arange(band)
            return None, attend(qc, kc, vc, qpos, kpos)
    else:
        def body(_, i):
            qc = qs[:, i]
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            kpos = jnp.arange(Sk)
            return None, attend(qc, k, v, qpos, kpos)

    _, out = jax.lax.scan(body, None, jnp.arange(nq),
                          unroll=nq if SCAN_UNROLL else 1)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len, *, softmax_scale: Optional[float] = None
                         ) -> jax.Array:
    """Single-token decode attention. q: (B, 1, H, hd); caches: (B, S, KVH, hd);
    cache_len: (B,) or scalar number of valid cache entries."""
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bhgd,bmhd->bhgm", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        cl = jnp.full((B,), cl)
    valid = jnp.arange(S)[None] < cl[:, None]                 # (B, S)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgm,bmhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd)


# ----------------------------------------------------------------- conv1d
def causal_conv1d_ref(x: jax.Array, w: jax.Array, bias: Optional[jax.Array]
                      = None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


# -------------------------------------------------------------------- SSD
def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B_in: jax.Array,
            C_in: jax.Array, D: jax.Array, *, chunk: int = 256,
            initial_state: Optional[jax.Array] = None,
            return_state: bool = False):
    """Mamba2 SSD chunked scan (arXiv:2405.21060 listing 1 semantics).

    x: (B, S, H, P); dt: (B, S, H) (already softplus'd); A: (H,) negative;
    B_in/C_in: (B, S, G, N); D: (H,). Returns y (B, S, H, P) and optionally
    the final state (B, H, N, P).
    """
    Bb, S, H, P = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    rep = H // G
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c

    xr = x.reshape(Bb, nc, c, H, P)
    dtr = dt.reshape(Bb, nc, c, H).astype(jnp.float32)
    Br = B_in.reshape(Bb, nc, c, G, N)
    Cr = C_in.reshape(Bb, nc, c, G, N)
    Af = A.astype(jnp.float32)

    dA = dtr * Af                                             # (B,nc,c,H)
    cum = jnp.cumsum(dA, axis=2)                              # inclusive

    h0 = (jnp.zeros((Bb, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    idx = jnp.arange(c)
    ltmask = idx[:, None] >= idx[None, :]                     # j <= i

    def body(h, inputs):
        xc, dtc, Bc, Cc, cumc = inputs                        # per-chunk
        # heads share their group's B/C
        Bh = jnp.repeat(Bc, rep, axis=2)                      # (B,c,H,N)
        Ch = jnp.repeat(Cc, rep, axis=2)
        # ---- intra-chunk (quadratic within chunk)
        cb = jnp.einsum("bihn,bjhn->bhij", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))               # (B,H,c,c)
        diff = (cumc.transpose(0, 2, 1)[:, :, :, None]
                - cumc.transpose(0, 2, 1)[:, :, None, :])     # (B,H,i,j)
        decay = jnp.exp(jnp.minimum(diff, 0.0))  # exact on j<=i; avoids inf
        scores = cb * decay * dtc.transpose(0, 2, 1)[:, :, None, :]
        scores = jnp.where(ltmask[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores,
                             xc.astype(jnp.float32))
        # ---- contribution of the carried state
        state_decay = jnp.exp(cumc)                           # (B,c,H)
        y_inter = jnp.einsum("bihn,bhnp->bihp",
                             Ch.astype(jnp.float32) * state_decay[..., None],
                             h)
        # ---- update state
        last = cumc[:, -1:, :]                                # (B,1,H)
        w = jnp.exp(last - cumc) * dtc                        # (B,c,H)
        new_contrib = jnp.einsum("bjhn,bjhp->bhnp",
                                 Bh.astype(jnp.float32) * w[..., None],
                                 xc.astype(jnp.float32))
        h_new = jnp.exp(last[:, 0, :])[:, :, None, None] * h + new_contrib
        return h_new, (y_intra + y_inter).astype(x.dtype)

    xs = (xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
          Br.transpose(1, 0, 2, 3, 4), Cr.transpose(1, 0, 2, 3, 4),
          cum.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(body, h0, xs,
                               unroll=nc if SCAN_UNROLL else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    y = y + (D.astype(jnp.float32)[:, None] * x.astype(jnp.float32)).astype(x.dtype)
    if return_state:
        return y, h_final
    return y


def ssd_decode_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B_in: jax.Array,
                   C_in: jax.Array, D: jax.Array, state: jax.Array):
    """One-token SSD update. x: (B, H, P); dt: (B, H); B_in/C_in: (B, G, N);
    state: (B, H, N, P). Returns (y, new_state)."""
    H = x.shape[1]
    G = B_in.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_in, rep, axis=1).astype(jnp.float32)    # (B,H,N)
    Ch = jnp.repeat(C_in, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                 # (B,H)
    xf = x.astype(jnp.float32)
    new_state = (dA[:, :, None, None] * state.astype(jnp.float32)
                 + jnp.einsum("bhn,bhp->bhnp", Bh * dtf[..., None], xf))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------------ rmsnorm
def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
